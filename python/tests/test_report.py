"""tools/report.py renders every experiment document shape."""

import json
import os
import subprocess
import sys

TOOLS = os.path.join(os.path.dirname(__file__), "..", "..", "tools")
REPO = os.path.join(os.path.dirname(__file__), "..", "..")


def run_report(tmp_path, docs):
    for name, doc in docs.items():
        (tmp_path / name).write_text(json.dumps(doc))
    proc = subprocess.run(
        [sys.executable, os.path.join(TOOLS, "report.py"), str(tmp_path)],
        capture_output=True,
        text=True,
        timeout=60,
    )
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


def test_renders_known_shapes(tmp_path):
    docs = {
        "eps.json": {
            "experiment": "epsilon-study",
            "rows": [
                {"eps": 0.5, "i_min": 105, "objective": -1.09, "err_a": 1e-16,
                 "err_b": 0.0, "collapsed": False, "budget": 10, "trace": []}
            ],
        },
        "timing.json": {
            "experiment": "timing",
            "rows": [{"nodes": 2, "comp_mean": 0.1, "comp_std": 0.01,
                      "comm_mean": 0.2, "comm_std": 0.02, "per_node": []}],
        },
        "finance.json": {
            "experiment": "finance",
            "paper_example": [
                {"variant": "sync-a2a", "rho_worst": -0.48, "inner_iters": 26,
                 "secs": 0.01, "converged": True, "transport_cost": 0.08}
            ],
        },
    }
    out = run_report(tmp_path, docs)
    assert "epsilon-study" in out
    assert "-0.48" in out
    assert "| nodes |" in out


def test_unknown_shape_falls_back(tmp_path):
    out = run_report(tmp_path, {"x.json": {"experiment": "new-thing", "n": 5}})
    assert "new-thing" in out


def test_real_results_render_if_present(tmp_path):
    results = os.path.join(REPO, "results")
    if not os.path.isdir(results) or not os.listdir(results):
        import pytest

        pytest.skip("no results/ yet")
    proc = subprocess.run(
        [sys.executable, os.path.join(TOOLS, "report.py"), results],
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stderr
    assert "#" in proc.stdout
