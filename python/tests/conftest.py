"""Make the `compile` package importable from any invocation directory.

CI runs pytest from `rust/` (`python3 -m pytest ../python/tests/... -q`);
developers run it from the repo root or from `python/`. Pin sys.path to
the package parent so all three work.
"""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
