"""L2 model checks: shapes, impl equivalence, and Sinkhorn semantics."""

from __future__ import annotations

import pytest

jax = pytest.importorskip("jax")

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile import model  # noqa: E402

OPS = list(model.FACTORIES) + ["sinkhorn_sweep"]


def _args_for(op, m, n, N, dtype=jnp.float64, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for s in model.signature(op, m, n, N, dtype):
        arr = rng.uniform(0.1, 1.0, s.shape).astype(s.dtype)
        out.append(jnp.asarray(arr))
    return out


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("m,n,N", [(8, 8, 1), (4, 8, 3), (16, 16, 5)])
def test_impls_agree(op, m, n, N):
    """pallas-built and xla-built L2 functions compute the same values."""
    if op in ("block_objective", "plan_block"):
        pytest.skip("single-impl cold-path ops")
    args = _args_for(op, m, n, N)
    if op == "sinkhorn_sweep":
        if m != n:
            pytest.skip("sweep is square")
        f_p = model.build(op, impl="pallas", w=3)
        f_x = model.build(op, impl="xla", w=3)
    else:
        f_p = model.build(op, impl="pallas")
        f_x = model.build(op, impl="xla")
    got = jax.tree_util.tree_leaves(f_p(*args))
    want = jax.tree_util.tree_leaves(f_x(*args))
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), rtol=1e-10)


@pytest.mark.parametrize("op", OPS)
def test_signature_shapes_jit(op):
    """Every op jits and produces outputs at its manifest shape."""
    m, n, N = (8, 8, 2)
    args = _args_for(op, m, n, N)
    fn = model.build(op, impl="xla", w=2 if op == "sinkhorn_sweep" else None)
    out = jax.jit(fn)(*args)
    leaves = jax.tree_util.tree_leaves(out)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)
    if op in ("client_update", "client_update_mat"):
        assert leaves[0].shape == (m, N)
    elif op == "server_matvec":
        assert leaves[0].shape == (m, N)
    elif op.startswith("block_marginal"):
        assert leaves[0].shape == (N,)
    elif op == "block_objective":
        assert leaves[0].shape == (1,)
    elif op == "plan_block":
        assert leaves[0].shape == (m, n)
    elif op == "sinkhorn_sweep":
        assert leaves[0].shape == (n, N) and leaves[1].shape == (n, N)


def test_sweep_converges_on_small_problem():
    """w=200 fused iterations drive the marginal error to ~0 (paper §III)."""
    n = 4
    a = jnp.array([0.3, 0.2, 0.1, 0.4])
    b = jnp.array([0.2, 0.3, 0.3, 0.2])[:, None]
    C = jnp.array(
        [[0.0, 1, 2, 3], [1, 0, 3, 2], [2, 3, 0, 1], [3, 2, 1, 0]]
    )
    eps = 0.5
    K = jnp.exp(-C / eps)
    sweep = model.build("sinkhorn_sweep", impl="xla", w=200)
    u, v = sweep(K, a, b, jnp.ones((n, 1)), jnp.ones((n, 1)), jnp.asarray([1.0]))
    P = u[:, 0][:, None] * K * v[:, 0][None, :]
    # The sweep ends on a v-update: the b-marginal is exact, the a-marginal
    # converges linearly (paper §III observes exactly this asymmetry).
    np.testing.assert_allclose(np.asarray(P.sum(1)), np.asarray(a), atol=1e-12)
    np.testing.assert_allclose(np.asarray(P.sum(0)), np.asarray(b[:, 0]), atol=1e-13)


def test_objective_matches_direct_formula():
    """Stable rewrite == direct ⟨P,C⟩ + εΣP(logP−1) when P has no zeros."""
    rng = np.random.default_rng(5)
    m, n, eps = 6, 6, 0.5
    C = rng.uniform(0.1, 1.0, (m, n))
    K = np.exp(-C / eps)
    u = rng.uniform(0.5, 1.5, m)
    v = rng.uniform(0.5, 1.5, n)
    P = u[:, None] * K * v[None, :]
    direct = (P * C).sum() + eps * (P * (np.log(P) - 1)).sum()
    fn = model.build("block_objective", impl="xla")
    got = fn(jnp.asarray(K), jnp.asarray(u), jnp.asarray(v), jnp.asarray([eps]))
    np.testing.assert_allclose(float(got[0]), direct, rtol=1e-10)


def test_client_update_slices_compose_to_full_update():
    """Row-block client updates == rows of the centralized update (Fig 1)."""
    rng = np.random.default_rng(9)
    n, c = 12, 3
    m = n // c
    K = rng.uniform(0.1, 1.0, (n, n))
    v = rng.uniform(0.5, 1.5, (n, 1))
    a = rng.dirichlet(np.ones(n))
    full = a[:, None] / (K @ v)
    fn = model.build("client_update", impl="pallas")
    for j in range(c):
        blk = fn(
            jnp.asarray(K[j * m : (j + 1) * m]),
            jnp.asarray(v),
            jnp.asarray(a[j * m : (j + 1) * m]),
            jnp.ones((m, 1)),
            jnp.asarray([1.0]),
        )
        np.testing.assert_allclose(
            np.asarray(blk), full[j * m : (j + 1) * m], rtol=1e-11
        )
