"""tools/bench_diff.py: the CI perf-regression gate over BENCH_*.json."""

import json
import os
import subprocess
import sys

TOOLS = os.path.join(os.path.dirname(__file__), "..", "..", "tools")


def doc(cases):
    return {"cases": [{"name": n, "reps": 3, "min_ms": v} for n, v in cases.items()]}


def run_gate(tmp_path, baseline, fresh, *extra):
    paths = []
    for name, payload in [("baseline.json", baseline), ("fresh.json", fresh)]:
        p = tmp_path / name
        if payload is not None:
            p.write_text(json.dumps(payload))
        paths.append(str(p))
    proc = subprocess.run(
        [
            sys.executable,
            os.path.join(TOOLS, "bench_diff.py"),
            "--baseline",
            paths[0],
            "--fresh",
            paths[1],
            *extra,
        ],
        capture_output=True,
        text=True,
        timeout=60,
    )
    return proc


def test_passes_when_within_threshold(tmp_path):
    base = doc({"native matmul n=512": 1.0, "logsumexp n=512": 4.0})
    fresh = doc({"native matmul n=512": 1.2, "logsumexp n=512": 3.5})
    proc = run_gate(tmp_path, base, fresh)
    assert proc.returncode == 0, proc.stderr
    assert "perf gate OK" in proc.stdout


def test_fails_on_regression_over_threshold(tmp_path):
    base = doc({"native matmul n=512": 1.0, "logsumexp n=512": 4.0})
    fresh = doc({"native matmul n=512": 1.5, "logsumexp n=512": 4.0})
    proc = run_gate(tmp_path, base, fresh)
    assert proc.returncode == 1
    assert "REGRESSED native matmul n=512" in proc.stdout
    assert "FAIL" in proc.stderr


def test_threshold_is_configurable(tmp_path):
    base = doc({"k": 1.0})
    fresh = doc({"k": 1.4})
    assert run_gate(tmp_path, base, fresh, "--threshold", "0.5").returncode == 0
    assert run_gate(tmp_path, base, fresh, "--threshold", "0.2").returncode == 1


def test_noise_floor_shields_micro_cases(tmp_path):
    # 3x slower but only 20 µs absolute: below the 0.05 ms noise floor.
    base = doc({"tiny": 0.010})
    fresh = doc({"tiny": 0.030})
    assert run_gate(tmp_path, base, fresh).returncode == 0
    # The same ratio above the floor fails.
    base = doc({"big": 10.0})
    fresh = doc({"big": 30.0})
    assert run_gate(tmp_path, base, fresh).returncode == 1


def test_renames_note_but_do_not_fail(tmp_path):
    base = doc({"old name": 1.0, "stable": 2.0})
    fresh = doc({"new name": 1.0, "stable": 2.0})
    proc = run_gate(tmp_path, base, fresh)
    assert proc.returncode == 0, proc.stderr
    assert "case removed" in proc.stdout
    assert "new case" in proc.stdout


def test_missing_baseline_is_bootstrap_pass(tmp_path):
    fresh = doc({"k": 1.0})
    proc = run_gate(tmp_path, None, fresh)
    assert proc.returncode == 0, proc.stderr
    assert "bootstrap" in proc.stdout


def test_missing_fresh_is_an_error(tmp_path):
    base = doc({"k": 1.0})
    proc = run_gate(tmp_path, base, None)
    assert proc.returncode == 2
    # Also in --write-baseline mode: a clean error, not a traceback.
    proc = run_gate(tmp_path, base, None, "--write-baseline")
    assert proc.returncode == 2
    assert "Traceback" not in proc.stderr


def test_only_regex_restricts_the_gate(tmp_path):
    base = doc({"hot kernel": 1.0, "cold path": 1.0})
    fresh = doc({"hot kernel": 1.0, "cold path": 9.0})
    assert run_gate(tmp_path, base, fresh, "--only", "hot").returncode == 0
    assert run_gate(tmp_path, base, fresh).returncode == 1


def test_write_baseline_refreshes(tmp_path):
    fresh = doc({"k": 2.0})
    proc = run_gate(tmp_path, None, fresh, "--write-baseline")
    assert proc.returncode == 0, proc.stderr
    refreshed = json.loads((tmp_path / "baseline.json").read_text())
    assert refreshed["cases"][0]["min_ms"] == 2.0
    # And a subsequent identical run passes the gate.
    assert run_gate(tmp_path, None, fresh).returncode == 0


def noted(cases):
    """Like doc(), but each value is (min_ms, note-or-None)."""
    return {
        "cases": [
            {"name": n, "reps": 3, "min_ms": v}
            | ({"note": note} if note else {})
            for n, (v, note) in cases.items()
        ]
    }


def test_note_matches_renamed_cases(tmp_path):
    # The same stable note on both sides: the rename is still gated.
    base = noted({"old display name": (1.0, "fleet-partial-move-n512")})
    fresh = noted({"new display name": (2.0, "fleet-partial-move-n512")})
    proc = run_gate(tmp_path, base, fresh)
    assert proc.returncode == 1
    assert "matched by note" in proc.stdout
    assert "REGRESSED new display name" in proc.stdout
    # Within threshold, the rename is a note, not a failure.
    fresh_ok = noted({"new display name": (1.1, "fleet-partial-move-n512")})
    proc = run_gate(tmp_path, base, fresh_ok)
    assert proc.returncode == 0, proc.stderr
    assert "matched by note" in proc.stdout


def test_duplicate_notes_do_not_match(tmp_path):
    # A note that repeats on one side is ambiguous — fall back to the
    # plain removed/new reporting instead of guessing.
    base = noted({"a": (1.0, "dup"), "b": (1.0, "dup")})
    fresh = noted({"c": (9.0, "dup")})
    proc = run_gate(tmp_path, base, fresh)
    assert proc.returncode == 0, proc.stderr
    assert "case removed" in proc.stdout
    assert "new case" in proc.stdout


def test_write_baseline_carries_notes(tmp_path):
    # Hand-annotated baseline notes survive a --write-baseline refresh
    # when the fresh run does not emit them itself.
    base = noted({"k": (1.0, "stable-identity"), "plain": (2.0, None)})
    fresh = doc({"k": 1.5, "plain": 2.0})
    proc = run_gate(tmp_path, base, fresh, "--write-baseline")
    assert proc.returncode == 0, proc.stderr
    refreshed = {
        c["name"]: c for c in json.loads((tmp_path / "baseline.json").read_text())["cases"]
    }
    assert refreshed["k"]["note"] == "stable-identity"
    assert refreshed["k"]["min_ms"] == 1.5
    assert "note" not in refreshed["plain"]
    # A fresh-side note wins over the old baseline's.
    fresh2 = noted({"k": (1.6, "renamed-identity")})
    assert run_gate(tmp_path, base, fresh2, "--write-baseline").returncode == 0
    refreshed = json.loads((tmp_path / "baseline.json").read_text())
    assert refreshed["cases"][0]["note"] == "renamed-identity"
