"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, tile sizes and damping factors; every
kernel must match :mod:`compile.kernels.ref` to tight tolerances. This is
the CORE correctness signal for the compute layer — the Rust runtime only
ever executes what these kernels lower to.
"""

from __future__ import annotations

import pytest

jax = pytest.importorskip("jax")
pytest.importorskip("hypothesis")

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels import sinkhorn_pallas as sp  # noqa: E402

# Interpret-mode pallas is slow; keep hypothesis shapes modest but odd
# (non-divisible by tiles) to exercise the padding paths.
dims = st.integers(min_value=1, max_value=40)
hists = st.integers(min_value=1, max_value=9)
tiles = st.sampled_from([4, 8, 16, 64])
alphas = st.floats(min_value=0.05, max_value=1.0)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
dtypes = st.sampled_from([np.float64, np.float32])


def _problem(seed, m, n, N, dtype):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.uniform(0.1, 1.0, (m, n)).astype(dtype))
    x = jnp.asarray(rng.uniform(0.1, 1.0, (n, N)).astype(dtype))
    t = jnp.asarray(rng.uniform(0.1, 1.0, (m,)).astype(dtype))
    tm = jnp.asarray(rng.uniform(0.1, 1.0, (m, N)).astype(dtype))
    u = jnp.asarray(rng.uniform(0.1, 1.0, (m, N)).astype(dtype))
    return A, x, t, tm, u


def _tol(dtype):
    return dict(rtol=5e-5, atol=5e-5) if dtype == np.float32 else dict(rtol=1e-11, atol=1e-12)


@settings(max_examples=40, deadline=None)
@given(seed=seeds, m=dims, n=dims, N=hists, bm=tiles, bk=tiles, bn=tiles, dtype=dtypes)
def test_matvec_matches_ref(seed, m, n, N, bm, bk, bn, dtype):
    A, x, *_ = _problem(seed, m, n, N, dtype)
    got = sp.matvec(A, x, bm=bm, bk=bk, bn=bn)
    want = ref.matvec(A, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@settings(max_examples=40, deadline=None)
@given(seed=seeds, m=dims, n=dims, N=hists, bm=tiles, bk=tiles, bn=tiles, alpha=alphas, dtype=dtypes)
def test_scaling_update_matches_ref(seed, m, n, N, bm, bk, bn, alpha, dtype):
    A, x, t, _, u = _problem(seed, m, n, N, dtype)
    got = sp.block_scaling_update(A, x, t, u, alpha, bm=bm, bk=bk, bn=bn)
    want = ref.block_scaling_update(A, x, t, u, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@settings(max_examples=40, deadline=None)
@given(seed=seeds, m=dims, n=dims, N=hists, bm=tiles, bk=tiles, bn=tiles, alpha=alphas, dtype=dtypes)
def test_scaling_update_mat_matches_ref(seed, m, n, N, bm, bk, bn, alpha, dtype):
    A, x, _, tm, u = _problem(seed, m, n, N, dtype)
    got = sp.block_scaling_update_mat(A, x, tm, u, alpha, bm=bm, bk=bk, bn=bn)
    want = ref.block_scaling_update_mat(A, x, tm, u, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@settings(max_examples=30, deadline=None)
@given(seed=seeds, m=dims, n=dims, N=hists, bm=tiles, bk=tiles, bn=tiles, dtype=dtypes)
def test_marginal_error_matches_ref(seed, m, n, N, bm, bk, bn, dtype):
    A, x, t, _, u = _problem(seed, m, n, N, dtype)
    got = sp.marginal_error(A, x, u, t, bm=bm, bk=bk, bn=bn)
    want = ref.marginal_error(A, x, u, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@settings(max_examples=30, deadline=None)
@given(seed=seeds, m=dims, n=dims, N=hists, bm=tiles, bk=tiles, bn=tiles, dtype=dtypes)
def test_marginal_error_mat_matches_ref(seed, m, n, N, bm, bk, bn, dtype):
    A, x, _, tm, u = _problem(seed, m, n, N, dtype)
    got = sp.marginal_error_mat(A, x, u, tm, bm=bm, bk=bk, bn=bn)
    want = ref.marginal_error_mat(A, x, u, tm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def test_undamped_update_is_pure_sinkhorn():
    """alpha = 1 must reduce to the classic u = t / (A x) update."""
    A, x, t, _, u = _problem(7, 17, 13, 3, np.float64)
    got = sp.block_scaling_update(A, x, t, u, 1.0, bm=8, bk=8, bn=4)
    want = t[:, None] / ref.matvec(A, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_zero_alpha_is_identity():
    """alpha = 0 must leave u unchanged (no update applied)."""
    A, x, t, _, u = _problem(11, 9, 21, 2, np.float64)
    got = sp.block_scaling_update(A, x, t, u, 0.0, bm=4, bk=16, bn=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(u), rtol=1e-15)


def test_padding_does_not_leak():
    """Shapes forcing heavy padding must still be exact (nan/inf confined)."""
    A, x, t, _, u = _problem(3, 5, 7, 1, np.float64)
    got = sp.block_scaling_update(A, x, t, u, 0.5, bm=64, bk=64, bn=64)
    want = ref.block_scaling_update(A, x, t, u, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)
    assert np.isfinite(np.asarray(got)).all()


def test_vmem_footprint_model():
    """Default tiles stay well under the 16 MiB/core VMEM budget."""
    fp = sp.vmem_footprint_bytes(sp.DEFAULT_BM, sp.DEFAULT_BK, sp.DEFAULT_BN)
    assert fp <= 2 * 2**20, f"default tile footprint {fp} > 2 MiB"


@pytest.mark.parametrize("w", [1, 3, 10])
def test_sweep_matches_manual_iteration(w):
    """ref.sinkhorn_sweep == w hand-rolled full Sinkhorn iterations."""
    rng = np.random.default_rng(3)
    n, N = 12, 4
    K = jnp.asarray(rng.uniform(0.2, 1.0, (n, n)))
    a = jnp.asarray(rng.dirichlet(np.ones(n)))
    b = jnp.asarray(rng.dirichlet(np.ones(n), size=N).T.copy())
    u = jnp.ones((n, N))
    v = jnp.ones((n, N))
    gu, gv = ref.sinkhorn_sweep(K, a, b, u, v, w)
    wu, wv = np.ones((n, N)), np.ones((n, N))
    Kn, an, bn = np.asarray(K), np.asarray(a), np.asarray(b)
    for _ in range(w):
        wu = an[:, None] / (Kn @ wv)
        wv = bn / (Kn.T @ wu)
    np.testing.assert_allclose(np.asarray(gu), wu, rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gv), wv, rtol=1e-10)
