"""AOT bridge checks: manifest integrity and HLO-text round-trip.

The Rust integration tests re-verify numerics through PJRT; here we check
the python side — every manifest entry exists, parses as HLO text with the
expected parameter count, and re-lowering is deterministic.
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import tempfile

import pytest

pytest.importorskip("jax")

from compile import aot, model  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as fh:
        return json.load(fh)


def test_manifest_entries_exist_and_are_unique():
    man = _manifest()
    assert man["version"] == 1
    seen = set()
    for e in man["entries"]:
        key = (e["op"], e["impl"], e["dtype"], e["m"], e["n"], e["nhist"], e["w"])
        assert key not in seen, f"duplicate manifest entry {key}"
        seen.add(key)
        assert os.path.exists(os.path.join(ART, e["file"])), e["file"]


def test_manifest_covers_core_ops():
    ops = {e["op"] for e in _manifest()["entries"]}
    for op in (
        "client_update",
        "client_update_mat",
        "server_matvec",
        "block_marginal",
        "block_objective",
        "plan_block",
        "sinkhorn_sweep",
    ):
        assert op in ops, f"manifest missing op {op}"


def test_manifest_has_both_impls():
    impls = {e["impl"] for e in _manifest()["entries"]}
    assert {"pallas", "xla"} <= impls


def test_hlo_text_parameter_count_matches_signature():
    man = _manifest()
    # One sample per op keeps this fast; param count must equal signature.
    by_op = {}
    for e in man["entries"]:
        by_op.setdefault(e["op"], e)
    for op, e in by_op.items():
        with open(os.path.join(ART, e["file"])) as fh:
            text = fh.read()
        n_params = len(
            set(re.findall(r"parameter\((\d+)\)", text))
        )
        sig = model.signature(op, e["m"], e["n"], e["nhist"], float)
        assert n_params == len(sig), f"{op}: {n_params} != {len(sig)}"
        assert "ENTRY" in text


def test_lowering_is_deterministic():
    a = aot.lower_entry("client_update", "xla", "f64", 8, 16, 2)
    b = aot.lower_entry("client_update", "xla", "f64", 8, 16, 2)
    assert a == b


def test_entry_name_roundtrip():
    assert (
        aot.entry_name("client_update", "pallas", "f64", 4, 8, 1)
        == "client_update_pallas_f64_m4_n8_N1"
    )
    assert (
        aot.entry_name("sinkhorn_sweep", "xla", "f64", 64, 64, 1, 10)
        == "sinkhorn_sweep_xla_f64_m64_n64_N1_w10"
    )


def test_quick_grid_regenerates(tmp_path):
    """aot.py --grid quick runs end-to-end in a fresh directory."""
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--grid", "quick"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert len(man["entries"]) > 50
    # Freshness short-circuit: second run must be a no-op.
    proc2 = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--grid", "quick"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert "fresh" in proc2.stdout
