"""L2 — JAX model: the Federated Sinkhorn compute graph.

Composes the L1 kernels (:mod:`compile.kernels.sinkhorn_pallas`, with the
pure-jnp oracle :mod:`compile.kernels.ref` as the "plain-XLA" ablation)
into the operations the Rust coordinator dispatches through PJRT:

====================  =======================================================
``client_update``      fused damped scaling update (Algs. 1–2 hot path)
``client_update_mat``  same, per-histogram targets (vectorized v-update)
``server_matvec``      ``q = K · v`` (star-network server step, Alg. 3)
``block_marginal``     per-histogram L1 marginal error of a block
``block_marginal_mat`` matrix-target flavor
``block_objective``    entropic OT objective contribution of a row block
``plan_block``         transport-plan block ``diag(u) K_j diag(v)``
``sinkhorn_sweep``     ``w`` fused centralized iterations (``lax.scan``)
====================  =======================================================

Each factory returns a function of concrete arrays; ``compile.aot`` jits
and lowers them at fixed shapes to HLO text for the Rust runtime. ``impl``
selects the Pallas path (kernels lower into the same HLO module —
the architecture requirement) or the jnp oracle (XLA's native GEMM
fusion; faster on this CPU-only image, see EXPERIMENTS.md §Perf for the
measured ablation).
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from .kernels import ref  # noqa: E402
from .kernels import sinkhorn_pallas as sp  # noqa: E402

IMPLS = ("pallas", "xla")


def _mod(impl: str):
    if impl == "pallas":
        return sp
    if impl == "xla":
        return ref
    raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")


# Scalars (alpha, eps) are passed as shape-(1,) arrays: the Rust side
# builds them with Literal::vec1 and the Pallas kernels consume a (1,)
# block; a single convention for both impls keeps the manifest uniform.


def make_client_update(impl: str = "pallas"):
    """u_new = α·t/(A@x) + (1−α)·u — inputs A(m,n) x(n,N) t(m) u(m,N) α(1)."""
    k = _mod(impl)

    def client_update(A, x, t, u_old, alpha):
        return k.block_scaling_update(A, x, t, u_old, alpha[0])

    return client_update


def make_client_update_mat(impl: str = "pallas"):
    """Matrix-target flavor — inputs A(m,n) x(n,N) t(m,N) u(m,N) α(1)."""
    k = _mod(impl)

    def client_update_mat(A, x, t, u_old, alpha):
        return k.block_scaling_update_mat(A, x, t, u_old, alpha[0])

    return client_update_mat


def make_server_matvec(impl: str = "pallas"):
    """q = A @ x — inputs A(m,n) x(n,N)."""
    k = _mod(impl)

    def server_matvec(A, x):
        return k.matvec(A, x)

    return server_matvec


def make_block_marginal(impl: str = "pallas"):
    """err(N,) = Σ_i |u∘(A@x) − t| — inputs A(m,n) x(n,N) u(m,N) t(m)."""
    k = _mod(impl)

    def block_marginal(A, x, u, t):
        return k.marginal_error(A, x, u, t)

    return block_marginal


def make_block_marginal_mat(impl: str = "pallas"):
    """Matrix-target marginal error — inputs A(m,n) x(n,N) u(m,N) t(m,N)."""
    k = _mod(impl)

    def block_marginal_mat(A, x, u, t):
        return k.marginal_error_mat(A, x, u, t)

    return block_marginal_mat


def make_block_objective(impl: str = "xla"):
    """Entropic objective of a row block — K(m,n) u(m) v(n) eps(1) → (1,).

    Cold path (once per convergence check); always the jnp formulation —
    the stable ``ε Σ P (log u + log v − 1)`` rewrite has no matmul to tile.
    """

    def block_objective(K_block, u, v, eps):
        return ref.block_objective(K_block, u, v, eps[0])[None]

    return block_objective


def make_plan_block(impl: str = "xla"):
    """P_j = diag(u) K_j diag(v) — K(m,n) u(m) v(n) → (m,n). Cold path."""

    def plan_block(K_block, u, v):
        return ref.plan_block(K_block, u, v)

    return plan_block


def make_sinkhorn_sweep(w: int, impl: str = "pallas"):
    """``w`` fused centralized iterations — K(n,n) a(n) b(n,N) u,v(n,N) α(1).

    ``lax.scan`` keeps the lowered module O(1) in ``w`` (no unrolling);
    u/v are the carry, so XLA donates/aliases their buffers across steps.
    """
    k = _mod(impl)

    def sweep(K, a, b, u, v, alpha):
        a_mat = jnp.broadcast_to(a[:, None], b.shape)

        def step(carry, _):
            u_c, v_c = carry
            u_n = k.block_scaling_update_mat(K, v_c, a_mat, u_c, alpha[0])
            v_n = k.block_scaling_update_mat(K.T, u_n, b, v_c, alpha[0])
            return (u_n, v_n), ()

        (u_f, v_f), _ = lax.scan(step, (u, v), None, length=w)
        return u_f, v_f

    return sweep


# --- Shape signatures for AOT lowering (m, n, N, dtype [, w]) -------------


def signature(op: str, m: int, n: int, N: int, dtype):
    """ShapeDtypeStructs for ``op`` at the given sizes (see aot.py)."""
    s = lambda *sh: jax.ShapeDtypeStruct(sh, dtype)  # noqa: E731
    scal = s(1)
    table = {
        "client_update": (s(m, n), s(n, N), s(m), s(m, N), scal),
        "client_update_mat": (s(m, n), s(n, N), s(m, N), s(m, N), scal),
        "server_matvec": (s(m, n), s(n, N)),
        "block_marginal": (s(m, n), s(n, N), s(m, N), s(m)),
        "block_marginal_mat": (s(m, n), s(n, N), s(m, N), s(m, N)),
        "block_objective": (s(m, n), s(m), s(n), scal),
        "plan_block": (s(m, n), s(m), s(n)),
        "sinkhorn_sweep": (s(n, n), s(n), s(n, N), s(n, N), s(n, N), scal),
    }
    return table[op]


FACTORIES = {
    "client_update": make_client_update,
    "client_update_mat": make_client_update_mat,
    "server_matvec": make_server_matvec,
    "block_marginal": make_block_marginal,
    "block_marginal_mat": make_block_marginal_mat,
    "block_objective": lambda impl: make_block_objective(impl),
    "plan_block": lambda impl: make_plan_block(impl),
}


def build(op: str, impl: str = "pallas", w: int | None = None):
    """Instantiate the L2 function for ``op`` (``sinkhorn_sweep`` needs w)."""
    if op == "sinkhorn_sweep":
        assert w is not None, "sinkhorn_sweep requires w"
        return make_sinkhorn_sweep(w, impl)
    return FACTORIES[op](impl)
