"""Pure-jnp reference oracle for the Pallas Sinkhorn kernels.

Every Pallas kernel in :mod:`sinkhorn_pallas` has an exact counterpart
here; pytest/hypothesis assert allclose between the two across shapes and
dtypes. The L2 model (``compile.model``) can be built on either
implementation — the oracle is also what we lower when benchmarking the
"plain-XLA" ablation against the Pallas-lowered artifacts.

Conventions
-----------
* ``A`` is an ``(m, n)`` block of the Gibbs kernel ``K`` — either the row
  block ``K_j`` (u-update) or the transposed column block ``K[:, j]ᵀ``
  (v-update). Both updates are the same computation.
* ``x`` is the full scaling state, ``(n, N)`` for ``N`` simultaneous target
  histograms (Cuturi vectorization, paper §IV-B3); ``N = 1`` recovers the
  classic algorithm.
* ``t`` is the client's local marginal slice (``a_j`` or ``b_j``), ``(m,)``.
* ``alpha`` is the damping step size of the asynchronous variant (paper
  §II-A2); ``alpha = 1`` is the undamped Sinkhorn–Knopp update.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

__all__ = [
    "matvec",
    "block_scaling_update",
    "block_scaling_update_mat",
    "marginal_error",
    "marginal_error_mat",
    "block_objective",
    "plan_block",
    "sinkhorn_sweep",
]


def matvec(A, x):
    """Plain block product ``q = A @ x`` — the star-server step (Alg. 3).

    ``A: (m, n)``, ``x: (n, N)`` → ``(m, N)``.
    """
    return jnp.dot(A, x, precision=lax.Precision.HIGHEST)


def block_scaling_update(A, x, t, u_old, alpha):
    """Fused damped Sinkhorn scaling update (the hot path of Algs. 1–2).

    ``u_new = alpha * t / (A @ x) + (1 - alpha) * u_old``

    ``A: (m, n)``, ``x: (n, N)``, ``t: (m,)``, ``u_old: (m, N)``,
    ``alpha: scalar`` → ``(m, N)``.
    """
    q = matvec(A, x)
    return alpha * (t[:, None] / q) + (1.0 - alpha) * u_old


def block_scaling_update_mat(A, x, t, u_old, alpha):
    """Matrix-target flavor: ``t: (m, N)`` — per-histogram targets.

    The v-update in vectorized mode (paper §IV-B3), where ``b ∈ R^{n×N}``
    carries one target histogram per column.
    """
    q = matvec(A, x)
    return alpha * (t / q) + (1.0 - alpha) * u_old


def marginal_error(A, x, u, t):
    """Per-histogram L1 marginal error of a block.

    With ``P = diag(u) K diag(v)`` the row-marginal restricted to this
    block is ``u_j * (K_j v)``; the error is ``Σ_i |u_i (A x)_i − t_i|``
    (paper §IV-C1 uses the signed sum; we report L1 which upper-bounds it
    and is the convergence criterion used in §IV-D).

    ``A: (m, n)``, ``x: (n, N)``, ``u: (m, N)``, ``t: (m,)`` → ``(N,)``.
    """
    row = u * matvec(A, x)
    return jnp.sum(jnp.abs(row - t[:, None]), axis=0)


def marginal_error_mat(A, x, u, t):
    """Matrix-target marginal error: ``t: (m, N)`` → ``(N,)``."""
    row = u * matvec(A, x)
    return jnp.sum(jnp.abs(row - t), axis=0)


def block_objective(K_block, u, v, eps):
    """Entropic OT objective contribution of one row block (N = 1).

    ``⟨P, C⟩ + ε Σ P (log P − 1)`` with ``C = −ε log K`` and
    ``P = diag(u) K diag(v)`` simplifies to
    ``ε Σ_ij P_ij (log u_i + log v_j − 1)`` — numerically stable, no
    ``log P`` of tiny entries.

    ``K_block: (m, n)``, ``u: (m,)``, ``v: (n,)``, ``eps: scalar`` → scalar.
    """
    P = u[:, None] * K_block * v[None, :]
    w = jnp.log(u)[:, None] + jnp.log(v)[None, :] - 1.0
    return eps * jnp.sum(P * w)


def plan_block(K_block, u, v):
    """Transport-plan block ``P_j = diag(u_j) K_j diag(v)`` (N = 1).

    ``K_block: (m, n)``, ``u: (m,)``, ``v: (n,)`` → ``(m, n)``.
    """
    return u[:, None] * K_block * v[None, :]


def sinkhorn_sweep(K, a, b, u, v, w, alpha=1.0):
    """``w`` full (centralized) Sinkhorn iterations via ``lax.scan``.

    Used to amortize PJRT dispatch overhead in the centralized baseline and
    for the local-iterations study (App. A).

    ``K: (n, n)``, ``a: (n,)``, ``b: (n, N)``, ``u, v: (n, N)`` →
    ``(u, v)`` after ``w`` iterations.
    """

    def step(carry, _):
        u_c, v_c = carry
        u_n = alpha * (a[:, None] / matvec(K, v_c)) + (1.0 - alpha) * u_c
        v_n = alpha * (b / matvec(K.T, u_n)) + (1.0 - alpha) * v_c
        return (u_n, v_n), ()

    (u_f, v_f), _ = lax.scan(step, (u, v), None, length=w)
    return u_f, v_f
