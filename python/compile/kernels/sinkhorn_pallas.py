"""L1 — Pallas kernels for the Federated Sinkhorn hot path.

The paper runs its hot spot (blocked ``K_j·v`` products + element-wise
scaling) on A100 GPUs through torch. Rethought for TPU (see DESIGN.md
§Hardware-Adaptation):

* the ``(m, n)`` kernel block is tiled into ``(bm, bk)`` VMEM-resident
  tiles streamed from HBM by ``BlockSpec`` index maps — the role CUDA
  threadblocks/shared-memory play in the GPU formulation;
* the ``bm×bk @ bk×bN`` partial products target the MXU systolic array;
  the f32 accumulator lives in the output VMEM block across the k-grid;
* the damped scaling epilogue ``u = α·t/q + (1−α)·u_old`` is fused into
  the final k-step so ``q`` never round-trips to HBM.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes. Correctness is pinned to :mod:`ref` by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes).

Tile defaults keep the VMEM footprint ≈ ``bm·bk + bk·bN + 2·bm·bN`` words
≤ 2 MiB f32 — far under the 16 MiB/core budget, leaving room for
double-buffered pipelining on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "matvec",
    "block_scaling_update",
    "block_scaling_update_mat",
    "marginal_error",
    "marginal_error_mat",
    "DEFAULT_BM",
    "DEFAULT_BK",
    "DEFAULT_BN",
    "vmem_footprint_bytes",
]

# Default tile sizes (rows of A, contraction, histogram columns).
DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 64


def vmem_footprint_bytes(bm: int, bk: int, bn: int, itemsize: int = 4) -> int:
    """Estimated VMEM bytes a (bm, bk, bn) tile schedule keeps resident.

    A-tile + x-tile + u_old-tile + out/accumulator tile. Used by DESIGN.md
    §Perf to size tiles under the 16 MiB/core budget.
    """
    return itemsize * (bm * bk + bk * bn + 2 * bm * bn)


def _pick_tiles(m: int, n: int, N: int, bm: int, bk: int, bn: int):
    """Clamp requested tile sizes to the problem and to divisors of it.

    Shapes are padded by the callers to multiples of the returned tiles,
    so any clamp ≤ requested is valid; we shrink to the dim itself for
    small problems to avoid an all-padding grid.
    """
    return min(bm, m), min(bk, n), min(bn, N)


def _pad2(arr, r, c):
    pr = (-arr.shape[0]) % r
    pc = (-arr.shape[1]) % c
    if pr == 0 and pc == 0:
        return arr
    return jnp.pad(arr, ((0, pr), (0, pc)))


# ---------------------------------------------------------------------------
# Fused scaling update: u_new = alpha * t / (A @ x) + (1 - alpha) * u_old
# ---------------------------------------------------------------------------


def _scaling_kernel(a_ref, x_ref, t_ref, u_ref, alpha_ref, o_ref, *, nk: int):
    """Grid = (m/bm, N/bn, n/bk); k is the innermost (minor) grid dim.

    o_ref doubles as the accumulator for the k-loop; the divide/damp
    epilogue runs on the last k step, fused so q never leaves VMEM.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        alpha = alpha_ref[0]
        q = o_ref[...]
        o_ref[...] = alpha * (t_ref[...][:, None] / q) + (1.0 - alpha) * u_ref[...]


def block_scaling_update(
    A,
    x,
    t,
    u_old,
    alpha,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
):
    """Pallas version of :func:`ref.block_scaling_update`.

    ``A: (m, n)``, ``x: (n, N)``, ``t: (m,)``, ``u_old: (m, N)``,
    ``alpha``: scalar → ``(m, N)``.
    """
    m, n = A.shape
    N = x.shape[1]
    bm, bk, bn = _pick_tiles(m, n, N, bm, bk, bn)

    Ap = _pad2(A, bm, bk)
    xp = _pad2(x, bk, bn)
    up = _pad2(u_old, bm, bn)
    # Pad t with ones so padded rows compute 1/0 = inf, not 0/0 = nan —
    # keeps interpret-mode nan checks quiet; padding is sliced off below.
    tp = jnp.pad(t, (0, (-m) % bm), constant_values=1)
    mp, np_ = Ap.shape
    Np = xp.shape[1]
    nk = np_ // bk
    alpha_arr = jnp.asarray([alpha], dtype=A.dtype)

    out = pl.pallas_call(
        functools.partial(_scaling_kernel, nk=nk),
        grid=(mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, Np), A.dtype),
        interpret=interpret,
    )(Ap, xp, tp, up, alpha_arr)
    return out[:m, :N]


# ---------------------------------------------------------------------------
# Matrix-target flavor: t is (m, N) — the v-update when N > 1 histograms
# each carry their own target marginal b[:, h] (Cuturi vectorization).
# ---------------------------------------------------------------------------


def _scaling_mat_kernel(a_ref, x_ref, t_ref, u_ref, alpha_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        alpha = alpha_ref[0]
        q = o_ref[...]
        o_ref[...] = alpha * (t_ref[...] / q) + (1.0 - alpha) * u_ref[...]


def block_scaling_update_mat(
    A,
    x,
    t,
    u_old,
    alpha,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
):
    """Like :func:`block_scaling_update` but with per-histogram targets.

    ``A: (m, n)``, ``x: (n, N)``, ``t: (m, N)``, ``u_old: (m, N)``.
    """
    m, n = A.shape
    N = x.shape[1]
    bm, bk, bn = _pick_tiles(m, n, N, bm, bk, bn)

    Ap = _pad2(A, bm, bk)
    xp = _pad2(x, bk, bn)
    up = _pad2(u_old, bm, bn)
    tp = _pad2(t, bm, bn) + _pad_ones_mask(t.shape, bm, bn, t.dtype)
    mp, np_ = Ap.shape
    Np = xp.shape[1]
    nk = np_ // bk
    alpha_arr = jnp.asarray([alpha], dtype=A.dtype)

    out = pl.pallas_call(
        functools.partial(_scaling_mat_kernel, nk=nk),
        grid=(mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, Np), A.dtype),
        interpret=interpret,
    )(Ap, xp, tp, up, alpha_arr)
    return out[:m, :N]


def _pad_ones_mask(shape, bm, bn, dtype):
    """A (padded-shape) array that is 1 exactly on the padding cells.

    Added to a zero-padded target so padded lanes compute ``1/0 = inf``
    rather than ``0/0 = nan`` (the padding is sliced away afterwards).
    """
    m, N = shape
    mp = m + ((-m) % bm)
    Np = N + ((-N) % bn)
    ones = jnp.ones((mp, Np), dtype=dtype)
    return ones - _pad2(jnp.ones(shape, dtype=dtype), bm, bn)


# ---------------------------------------------------------------------------
# Plain block product: q = A @ x (star-network server step)
# ---------------------------------------------------------------------------


def _matvec_kernel(a_ref, x_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=o_ref.dtype
    )


def matvec(
    A,
    x,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
):
    """Pallas version of :func:`ref.matvec`: ``(m, n) @ (n, N) → (m, N)``."""
    m, n = A.shape
    N = x.shape[1]
    bm, bk, bn = _pick_tiles(m, n, N, bm, bk, bn)

    Ap = _pad2(A, bm, bk)
    xp = _pad2(x, bk, bn)
    mp, np_ = Ap.shape
    Np = xp.shape[1]

    out = pl.pallas_call(
        _matvec_kernel,
        grid=(mp // bm, Np // bn, np_ // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, Np), A.dtype),
        interpret=interpret,
    )(Ap, xp)
    return out[:m, :N]


# ---------------------------------------------------------------------------
# Marginal error: err[h] = sum_i |u[i,h] * (A@x)[i,h] - t[i]|
# ---------------------------------------------------------------------------


def _marginal_row_kernel(q_ref, u_ref, t_ref, o_ref, *, nm: int):
    """Reduce |u∘q − t| over row blocks; grid = (N/bn, m/bm)."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row = u_ref[...] * q_ref[...]
    o_ref[...] += jnp.sum(jnp.abs(row - t_ref[...][:, None]), axis=0)


def marginal_error(
    A,
    x,
    u,
    t,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
):
    """Pallas version of :func:`ref.marginal_error` → ``(N,)``.

    Two kernels: the tiled MXU product (reusing :func:`matvec`) followed
    by a row-block reduction of ``|u∘q − t|``. Splitting keeps each kernel
    scratch-free (the product's accumulator is its own output block).
    """
    m, n = A.shape
    N = x.shape[1]
    q = matvec(A, x, bm=bm, bk=bk, bn=bn, interpret=interpret)

    bm, _, bn = _pick_tiles(m, n, N, bm, bk, bn)
    qp = _pad2(q, bm, bn)
    up = _pad2(u, bm, bn)
    # Zero-pad t AND u: padded rows contribute |0*q - 0| = 0 to the sum.
    tp = jnp.pad(t, (0, (-m) % bm))
    mp, Np = qp.shape
    nm = mp // bm

    out = pl.pallas_call(
        functools.partial(_marginal_row_kernel, nm=nm),
        grid=(Np // bn, nm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((Np,), A.dtype),
        interpret=interpret,
    )(qp, up, tp)
    return out[:N]


def _marginal_row_mat_kernel(q_ref, u_ref, t_ref, o_ref, *, nm: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row = u_ref[...] * q_ref[...]
    o_ref[...] += jnp.sum(jnp.abs(row - t_ref[...]), axis=0)


def marginal_error_mat(
    A,
    x,
    u,
    t,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
):
    """Matrix-target marginal error: ``t: (m, N)`` → ``(N,)``.

    The b-marginal check in vectorized (N > 1) mode, where each histogram
    has its own target column.
    """
    m, n = A.shape
    N = x.shape[1]
    q = matvec(A, x, bm=bm, bk=bk, bn=bn, interpret=interpret)

    bm, _, bn = _pick_tiles(m, n, N, bm, bk, bn)
    qp = _pad2(q, bm, bn)
    up = _pad2(u, bm, bn)
    tp = _pad2(t, bm, bn)
    mp, Np = qp.shape
    nm = mp // bm

    out = pl.pallas_call(
        functools.partial(_marginal_row_mat_kernel, nm=nm),
        grid=(Np // bn, nm),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((Np,), A.dtype),
        interpret=interpret,
    )(qp, up, tp)
    return out[:N]
