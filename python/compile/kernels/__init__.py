"""L1 kernels: Pallas implementations + pure-jnp reference oracle."""

from . import ref, sinkhorn_pallas  # noqa: F401
