"""AOT bridge: lower the L2 model at fixed shapes to HLO text + manifest.

``python -m compile.aot --out-dir ../artifacts`` writes one
``<op>_<impl>_<dtype>_m{m}_n{n}_N{N}[_w{w}].hlo.txt`` per grid entry plus a
``manifest.json`` the Rust runtime (`rust/src/runtime/manifest.rs`) parses
to locate and compile executables.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Functions are lowered with ``return_tuple=True`` — every output is a
tuple, unwrapped on the Rust side.

Grids:
  * ``default`` — the shape set the examples, tests and scaled-down
    experiment drivers need (laptop-class; see DESIGN.md §5).
  * ``quick``   — a minimal set for CI smoke runs.
  * ``paper``   — adds the paper-size shapes (n up to 10000); heavy.

Python runs ONCE here (``make artifacts``); it is never on the Rust
request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPES = {"f64": jnp.float64, "f32": jnp.float32}

# Ops whose targets/outputs are N-independent (lowered once per (m, n)).
N_FREE_OPS = {"block_objective", "plan_block"}


def to_hlo_text(lowered, return_tuple: bool) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (ids reassigned).

    Single-output ops are lowered *untupled* so the Rust runtime can feed
    the output `PjRtBuffer` straight back as the next call's input (the
    device-resident-state optimization); multi-output ops keep the tuple.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def entry_name(op, impl, dtype, m, n, N, w=None):
    base = f"{op}_{impl}_{dtype}_m{m}_n{n}_N{N}"
    if w is not None:
        base += f"_w{w}"
    return base


def lower_entry(op, impl, dtype, m, n, N, w=None):
    fn = model.build(op, impl=impl, w=w)
    sig = model.signature(op, m, n, N, DTYPES[dtype])
    lowered = jax.jit(fn).lower(*sig)
    return to_hlo_text(lowered, return_tuple=op == "sinkhorn_sweep")


def grid_entries(grid: str):
    """Yield (op, impl, dtype, m, n, N, w) for the requested grid."""
    seen = set()

    def emit(op, impl, dtype, m, n, N, w=None):
        if op in N_FREE_OPS:
            N = 1
        key = (op, impl, dtype, m, n, N, w)
        if key not in seen:
            seen.add(key)
            yield key

    def block_shapes(sizes, clients):
        for n in sizes:
            for c in clients:
                if n % c == 0:
                    yield n // c, n

    if grid == "quick":
        sizes, clients, hists = [64, 256], [1, 2, 4], [1, 8]
        vec_hists, vec_n = [64], 64
        sweep_sizes, impls_hot = [64], ["xla", "pallas"]
    elif grid == "default":
        sizes, clients, hists = [64, 256, 512, 1024, 2048], [1, 2, 4, 8], [1, 64]
        vec_hists, vec_n = [512, 4096], 512
        sweep_sizes = [64, 256, 512, 1024, 2048]
        impls_hot = ["xla", "pallas"]
    elif grid == "paper":
        sizes, clients, hists = (
            [64, 256, 512, 1024, 2048, 5000, 10000],
            [1, 2, 4, 8],
            [1, 64],
        )
        vec_hists, vec_n = [512, 4096, 10000], 1000
        sweep_sizes = [64, 256, 512, 1024, 2048, 5000, 10000]
        impls_hot = ["xla", "pallas"]
    else:
        raise SystemExit(f"unknown grid {grid!r}")

    dtype = "f64"
    # Pallas-lowered artifacts are the architecture ablation; bound their
    # lowering cost to the small-to-mid shapes (interpret-mode tracing of
    # huge grids is slow and the ablation signal saturates).
    pallas_cap = 512

    for m, n in block_shapes(sizes, clients):
        for N in hists:
            for impl in impls_hot:
                if impl == "pallas" and n > pallas_cap:
                    continue
                yield from emit("client_update", impl, dtype, m, n, N)
                yield from emit("client_update_mat", impl, dtype, m, n, N)
                if m == n:
                    yield from emit("server_matvec", impl, dtype, m, n, N)
            yield from emit("block_marginal", "xla", dtype, m, n, N)
            yield from emit("block_marginal_mat", "xla", dtype, m, n, N)
        yield from emit("block_objective", "xla", dtype, m, n, 1)
        yield from emit("plan_block", "xla", dtype, m, n, 1)

    # Vectorized (Cuturi N-histogram) study shapes, §IV-B3 / Figs 7-8.
    for c in [1, 2, 4]:
        m = vec_n // c
        for N in vec_hists:
            yield from emit("client_update", "xla", dtype, m, vec_n, N)
            yield from emit("client_update_mat", "xla", dtype, m, vec_n, N)
            if m == vec_n:
                yield from emit("server_matvec", "xla", dtype, m, vec_n, N)
            yield from emit("block_marginal", "xla", dtype, m, vec_n, N)
            yield from emit("block_marginal_mat", "xla", dtype, m, vec_n, N)

    # Fused multi-iteration centralized sweeps (PJRT dispatch amortizer).
    for n in sweep_sizes:
        for w in [10]:
            impl = "pallas" if n <= pallas_cap else "xla"
            yield from emit("sinkhorn_sweep", "xla", dtype, n, n, 1, w)
            if impl == "pallas":
                yield from emit("sinkhorn_sweep", "pallas", dtype, n, n, 1, w)

    # f32 coverage (paper drops to f32 for the largest runs, §IV-B4).
    for N in [1]:
        yield from emit("client_update", "xla", "f32", 256, 256, N)
        yield from emit("server_matvec", "xla", "f32", 256, 256, N)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid", default="default", choices=["quick", "default", "paper"])
    ap.add_argument("--force", action="store_true", help="re-lower even if fresh")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")

    # Freshness: hash the compile-path sources; skip everything if the
    # manifest was built from identical sources with a superset grid.
    src_files = [
        os.path.join(os.path.dirname(__file__), f)
        for f in ("aot.py", "model.py", "kernels/ref.py", "kernels/sinkhorn_pallas.py")
    ]
    h = hashlib.sha256()
    for f in src_files:
        with open(f, "rb") as fh:
            h.update(fh.read())
    src_hash = h.hexdigest()[:16]

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as fh:
                old = json.load(fh)
            if old.get("src_hash") == src_hash and old.get("grid") == args.grid:
                print(f"artifacts fresh (src {src_hash}, grid {args.grid}); nothing to do")
                return 0
        except (json.JSONDecodeError, OSError):
            pass

    entries = []
    t0 = time.time()
    todo = list(grid_entries(args.grid))
    for i, (op, impl, dtype, m, n, N, w) in enumerate(todo):
        name = entry_name(op, impl, dtype, m, n, N, w)
        path = os.path.join(out_dir, name + ".hlo.txt")
        t1 = time.time()
        text = lower_entry(op, impl, dtype, m, n, N, w)
        with open(path, "w") as fh:
            fh.write(text)
        entries.append(
            {
                "op": op,
                "impl": impl,
                "dtype": dtype,
                "m": m,
                "n": n,
                "nhist": N,
                "w": w if w is not None else 0,
                "file": os.path.basename(path),
                "outputs": 2 if op == "sinkhorn_sweep" else 1,
            }
        )
        print(
            f"[{i + 1}/{len(todo)}] {name}: {len(text)} chars "
            f"({time.time() - t1:.2f}s)",
            file=sys.stderr,
        )

    manifest = {
        "version": 1,
        "generated_by": "compile.aot",
        "grid": args.grid,
        "src_hash": src_hash,
        "entries": entries,
    }
    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(
        f"wrote {len(entries)} artifacts + manifest.json to {out_dir} "
        f"in {time.time() - t0:.1f}s"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
