#!/usr/bin/env python3
"""Perf-regression gate over the BENCH_*.json perf-trajectory documents.

Compares a freshly emitted ``BENCH_kernels.json`` (``cargo bench --bench
bench_kernels``; CI runs it with ``FEDSINK_BENCH_QUICK=1`` for a
deterministic pinned case list) against the committed
``BENCH_baseline.json`` and exits non-zero when any hot kernel regressed
by more than ``--threshold`` (default 30%).

Semantics:

* cases are matched by name; the compared metric is ``min_ms`` by
  default (the outlier-robust best-case timing — the conventional
  perf-gate statistic);
* a case regresses when ``fresh > baseline * (1 + threshold)`` AND the
  absolute slowdown exceeds ``--min-ms`` (default 0.05 ms), so
  micro-cases lost in timer noise cannot flip the gate;
* cases only present on one side are reported but do not fail the gate
  (renames and new benches require an intentional baseline refresh, not
  a red CI);
* cases may carry a ``note`` field — a stable identity the emitting
  bench attaches alongside the display name. A case missing by name but
  whose note uniquely matches one unmatched case on the other side is
  still compared (rename-tolerant gating), and ``--write-baseline``
  carries notes from the old baseline through the rewrite so hand-added
  annotations survive refreshes;
* a missing baseline file is the bootstrap state: the gate passes with a
  notice telling you how to seed it.

Refresh flow (intentional): download the ``BENCH_kernels`` artifact from
a green main run (or run the quick bench locally) and commit it as
``BENCH_baseline.json`` — or run with ``--write-baseline`` locally.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys


def load_cases(path):
    """Return {name: {metric: value}} from a BENCH_*.json document."""
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    cases = {}
    for case in doc.get("cases", []):
        name = case.get("name")
        if isinstance(name, str):
            cases[name] = case
    return cases


def _unique_note_index(cases, names):
    """{note: name} over ``names``, dropping notes that repeat."""
    index, dupes = {}, set()
    for name in names:
        note = cases[name].get("note")
        if not isinstance(note, str) or not note:
            continue
        if note in index or note in dupes:
            index.pop(note, None)
            dupes.add(note)
            continue
        index[note] = name
    return index


def match_cases(baseline, fresh):
    """Pair baseline and fresh cases: by name first, then — for the
    leftovers — by a unique ``note`` identity (rename tolerance).

    Returns (pairs, removed, added): pairs is a list of
    (base_name, fresh_name), removed/added are the names left unmatched
    on each side.
    """
    pairs = [(n, n) for n in sorted(set(baseline) & set(fresh))]
    base_only = set(baseline) - set(fresh)
    fresh_only = set(fresh) - set(baseline)
    base_by_note = _unique_note_index(baseline, sorted(base_only))
    fresh_by_note = _unique_note_index(fresh, sorted(fresh_only))
    for note in sorted(set(base_by_note) & set(fresh_by_note)):
        b, f = base_by_note[note], fresh_by_note[note]
        pairs.append((b, f))
        base_only.discard(b)
        fresh_only.discard(f)
    return pairs, sorted(base_only), sorted(fresh_only)


def diff(baseline, fresh, threshold, metric, min_ms, only=None):
    """Compare case maps; returns (regressions, improvements, notes).

    Each regression/improvement is (name, base_value, fresh_value,
    ratio). Notes are human-readable remarks about skipped/unmatched
    cases. Cases are matched by name, falling back to a unique ``note``
    identity so renamed cases stay gated.
    """
    pattern = re.compile(only) if only else None
    regressions, improvements, notes = [], [], []
    pairs, removed, added = match_cases(baseline, fresh)
    for name in removed:
        if pattern and not pattern.search(name):
            continue
        notes.append(f"case removed (not in fresh run): {name}")
    for name in added:
        if pattern and not pattern.search(name):
            continue
        notes.append(f"new case (not in baseline): {name}")
    for base_name, fresh_name in sorted(pairs, key=lambda p: p[1]):
        if pattern and not pattern.search(fresh_name):
            continue
        name = fresh_name
        if base_name != fresh_name:
            note = fresh[fresh_name].get("note")
            notes.append(
                f"renamed case matched by note {note!r}: "
                f"{base_name} -> {fresh_name}"
            )
        base = baseline[base_name].get(metric)
        new = fresh[fresh_name].get(metric)
        if not isinstance(base, (int, float)) or not isinstance(new, (int, float)):
            notes.append(f"case lacks metric {metric!r}: {name}")
            continue
        if base <= 0.0:
            notes.append(f"non-positive baseline timing, skipped: {name}")
            continue
        ratio = new / base
        if ratio > 1.0 + threshold and (new - base) > min_ms:
            regressions.append((name, base, new, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, base, new, ratio))
    return regressions, improvements, notes


def refresh_baseline(baseline_path, fresh_path):
    """Copy the fresh document over the baseline, carrying per-case
    ``note`` annotations from the old baseline (matched by name) so
    hand-added identities survive the rewrite."""
    with open(fresh_path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    if os.path.exists(baseline_path):
        old = load_cases(baseline_path)
        for case in doc.get("cases", []):
            name = case.get("name")
            if "note" in case or name not in old:
                continue
            note = old[name].get("note")
            if isinstance(note, str) and note:
                case["note"] = note
    with open(baseline_path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1)
        fh.write("\n")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed BENCH_baseline.json")
    ap.add_argument("--fresh", required=True, help="freshly emitted BENCH_kernels.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.30,
        help="relative slowdown that fails the gate (0.30 = 30%%)",
    )
    ap.add_argument(
        "--metric",
        default="min_ms",
        choices=["min_ms", "median_ms", "mean_ms"],
        help="which timing statistic to compare",
    )
    ap.add_argument(
        "--min-ms",
        type=float,
        default=0.05,
        help="ignore regressions whose absolute slowdown is below this (timer noise)",
    )
    ap.add_argument("--only", default=None, help="regex restricting the compared case names")
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="copy the fresh document over the baseline path and exit (local refresh)",
    )
    args = ap.parse_args(argv)

    if not os.path.exists(args.fresh):
        print(f"error: fresh bench document not found: {args.fresh}", file=sys.stderr)
        return 2

    if args.write_baseline:
        refresh_baseline(args.baseline, args.fresh)
        print(f"baseline refreshed: {args.fresh} -> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(
            f"no committed baseline at {args.baseline} — bootstrap pass.\n"
            f"Seed it from a green run: commit the fresh {args.fresh} as the baseline\n"
            f"(or rerun with --write-baseline)."
        )
        return 0

    baseline = load_cases(args.baseline)
    fresh = load_cases(args.fresh)
    regressions, improvements, notes = diff(
        baseline, fresh, args.threshold, args.metric, args.min_ms, args.only
    )

    for note in notes:
        print(f"note: {note}")
    for name, base, new, ratio in improvements:
        print(f"improved  {name}: {base:.4f} -> {new:.4f} ms ({ratio:.2f}x)")
    for name, base, new, ratio in regressions:
        print(f"REGRESSED {name}: {base:.4f} -> {new:.4f} ms ({ratio:.2f}x)")

    compared = len(match_cases(baseline, fresh)[0])
    print(
        f"compared {compared} case(s) on {args.metric}: "
        f"{len(regressions)} regression(s), {len(improvements)} improvement(s)"
    )
    if regressions:
        print(
            f"FAIL: hot kernel(s) regressed > {args.threshold:.0%} vs {args.baseline}. "
            f"If intentional, refresh the baseline (see tools/bench_diff.py docstring).",
            file=sys.stderr,
        )
        return 1
    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
