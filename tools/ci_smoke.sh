#!/usr/bin/env bash
# CI smoke stages for the fedsink binary — THE single home for smoke
# commands (the workflow calls `tools/ci_smoke.sh <stage>`; nothing is
# inlined in ci.yml). Run locally after `cargo build --release`:
#
#   tools/ci_smoke.sh            # every stage
#   tools/ci_smoke.sh service    # one named stage
#
# Override the binary with FEDSINK_BIN (defaults to the release build).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${FEDSINK_BIN:-rust/target/release/fedsink}
TMP=$(mktemp -d)
trap 'rm -rf "$TMP"' EXIT

# Sparse stabilized path: FEDSINK_DOMAIN exercises the Settings wiring;
# the log domain at small ε drives the absorption-hybrid /
# truncated-sparse engine.
stage_sparse() {
  FEDSINK_DOMAIN=log "$BIN" solve \
    --variant centralized --backend native --n 128 --eps 0.005 \
    --cond ill --max-iters 2000 --threshold 1e-8
}

# The multi-histogram absorption engine at the ROADMAP's acceptance
# shape: n=512, N=8, eps=0.005 on the shared-support batched GEMM
# schedule (prints the linear-iteration fraction).
stage_vectorized() {
  FEDSINK_DOMAIN=log "$BIN" solve \
    --variant centralized --backend native --n 512 --hists 8 \
    --eps 0.005 --cond ill --max-iters 3000 --threshold 1e-8
}

# Fleet-synchronized absorption on all four coordinators: n=512, c=4,
# eps=0.005 with the coordinator-broadcast reference dual (async
# variants damped, per the paper's stable regime). Prints the fleet
# command/rebuild counters.
stage_fleet() {
  for v in sync-a2a sync-star; do
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
      --fleet-absorb
  done
  for v in async-a2a async-star; do
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 8000 --threshold 1e-8 \
      --fleet-absorb --alpha 0.5
  done
}

# Compressed streaming exchange on all four coordinators: delta-coded
# f32 frames plus the slice-streaming fold. DeltaF32's quantization step
# shrinks with the iterate deltas, so the tight 1e-8 threshold stays
# reachable; the solve output prints the per-kind byte buckets.
stage_wire() {
  for v in sync-a2a sync-star; do
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
      --wire-format deltaf32 --stream-exchange
  done
  for v in async-a2a async-star; do
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 8000 --threshold 1e-8 \
      --wire-format deltaf32 --stream-exchange --alpha 0.5
  done
}

# The wire-codec shape again, now over faulted links: 5% drops plus
# dup/reorder on every link. Reliable streams retransmit
# (backoff-priced ARQ), latest-wins streams lose frames and rekey the
# delta codec — every coordinator must still reach 1e-8. The greps
# assert each run both converged and actually exercised the fault
# layer: nonzero retransmits on the lock-step protocols, nonzero drops
# on the latest-wins ones.
stage_chaos() {
  local chaos="--drop-prob 0.05 --dup-prob 0.02 --reorder-prob 0.02"
  for v in sync-a2a sync-star; do
    # shellcheck disable=SC2086
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
      --wire-format deltaf32 --stream-exchange $chaos \
      | tee "$TMP/chaos.log"
    grep -q "stop=Converged" "$TMP/chaos.log"
    grep -Eq "retransmits=[1-9]" "$TMP/chaos.log"
  done
  for v in async-a2a async-star; do
    # shellcheck disable=SC2086
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 8000 --threshold 1e-8 \
      --wire-format deltaf32 --stream-exchange --alpha 0.5 $chaos \
      | tee "$TMP/chaos.log"
    grep -q "stop=Converged" "$TMP/chaos.log"
    grep -Eq " drops=[1-9]" "$TMP/chaos.log"
  done
}

# Decentralized topologies on the shared protocol engine, selected via
# the --coordinator alias: the ring's rotation allgather (reliable ARQ
# relays, c−1 hops) and the gossip push protocol (latest-wins stamped
# views), each over the delta-coded wire, lossless and again under the
# chaos plan. The greps assert the chaos runs converged AND exercised
# the expected delivery class: retransmits on the ring's reliable
# relays, genuine drops on gossip's latest-wins pushes.
stage_topology() {
  local chaos="--drop-prob 0.05 --dup-prob 0.02 --reorder-prob 0.02"
  FEDSINK_DOMAIN=log "$BIN" solve \
    --coordinator ring --backend native --n 512 --clients 4 \
    --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
    --wire-format deltaf32
  # shellcheck disable=SC2086
  FEDSINK_DOMAIN=log "$BIN" solve \
    --coordinator ring --backend native --n 512 --clients 4 \
    --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
    --wire-format deltaf32 $chaos \
    | tee "$TMP/topology.log"
  grep -q "stop=Converged" "$TMP/topology.log"
  grep -Eq "retransmits=[1-9]" "$TMP/topology.log"
  FEDSINK_DOMAIN=log "$BIN" solve \
    --coordinator gossip --backend native --n 512 --clients 4 \
    --eps 0.005 --cond ill --max-iters 8000 --threshold 1e-8 \
    --wire-format deltaf32 --alpha 0.5
  # shellcheck disable=SC2086
  FEDSINK_DOMAIN=log "$BIN" solve \
    --coordinator gossip --backend native --n 512 --clients 4 \
    --eps 0.005 --cond ill --max-iters 8000 --threshold 1e-8 \
    --wire-format deltaf32 --alpha 0.5 $chaos \
    | tee "$TMP/topology.log"
  grep -q "stop=Converged" "$TMP/topology.log"
  grep -Eq " drops=[1-9]" "$TMP/topology.log"
}

# Greedy top-k exchange: the Greenkhorn-style schedule on the lock-step
# coordinators, full vs greedy at the same ε and threshold. The greps
# assert each greedy run converged, moved its scaling traffic on the
# sparse frame kinds, and printed the selection telemetry; the python
# step pins the acceptance bar — strictly fewer exchanged scaling bytes
# per iteration than the dense baseline, with no dense U/V frames at
# all on the greedy run.
stage_greedy() {
  for v in sync-a2a sync-star; do
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
      | tee "$TMP/full.log"
    FEDSINK_DOMAIN=log "$BIN" solve \
      --variant "$v" --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 12000 --threshold 1e-8 \
      --exchange greedy \
      | tee "$TMP/greedy.log"
    grep -q "stop=Converged" "$TMP/greedy.log"
    grep -q "greedy:" "$TMP/greedy.log"
    grep -q "SpU=" "$TMP/greedy.log"
    python3 - "$TMP/full.log" "$TMP/greedy.log" <<'PY'
import re, sys

def parse(path):
    text = open(path).read()
    iters = int(re.search(r"iters=(\d+)", text).group(1))
    kinds = {k: int(b) for k, b in re.findall(r"(\w+)=(\d+)B/\d+msg", text)}
    return iters, kinds

fi, fk = parse(sys.argv[1])
gi, gk = parse(sys.argv[2])
full = (fk.get("U", 0) + fk.get("V", 0)) / fi
sparse = (gk.get("SpU", 0) + gk.get("SpV", 0)) / gi
assert gk.get("U", 0) + gk.get("V", 0) == 0, f"greedy moved dense frames: {gk}"
assert sparse > 0, f"no sparse traffic metered: {gk}"
assert sparse < full, f"greedy {sparse:.0f} B/iter !< full {full:.0f} B/iter"
print(f"greedy exchange OK: {sparse:.0f} B/iter sparse vs {full:.0f} B/iter dense")
PY
  done
}

# The streaming shape pinned at both ends of the pool-sizing range: a
# serial pool (never fans out) and a 4-thread pool sharing workers
# across all five node threads. Banding is per-row, so both must reach
# the same 1e-8 threshold in the same iterations.
stage_threads() {
  for t in 1 4; do
    FEDSINK_THREADS="$t" FEDSINK_DOMAIN=log "$BIN" solve \
      --variant sync-a2a --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 4000 --threshold 1e-8 \
      --wire-format deltaf32 --stream-exchange
    FEDSINK_THREADS="$t" FEDSINK_DOMAIN=log "$BIN" solve \
      --variant async-star --backend native --n 512 --clients 4 \
      --eps 0.005 --cond ill --max-iters 8000 --threshold 1e-8 \
      --wire-format deltaf32 --stream-exchange --alpha 0.5
  done
}

# Multi-tenant serve: 64 requests over one shared geometry. --perturb 8
# puts the log-histogram spread (≈ 2 + 2·8 = 18) above the default
# admission budget (2 · 0.5·τ = 15), so the stream MUST split into
# multiple batches (a degraded batch shape, not one lucky mega-batch);
# jittered tolerances drive per-column stopping (early_frozen > 0). The
# JSON assert pins the headline amortization claim: batched rebuilds
# strictly below the standalone sum.
stage_service() {
  "$BIN" serve \
    --n 192 --eps 0.005 --cond ill --requests 64 --tenants 8 \
    --perturb 8 --threshold 1e-8 --tolerance-jitter 1.0 \
    --max-batch 16 --max-iters 6000 --domain log \
    --compare-standalone --out "$TMP/BENCH_service.json" \
    | tee "$TMP/service.log"
  grep -Eq "batches=([2-9]|[1-9][0-9]+)" "$TMP/service.log"
  grep -q "splits=[1-9]" "$TMP/service.log"
  grep -q "unconverged=0" "$TMP/service.log"
  grep -Eq "early_frozen=[1-9]" "$TMP/service.log"
  python3 - "$TMP/BENCH_service.json" <<'PY'
import json, sys

doc = json.load(open(sys.argv[1]))
assert doc["unconverged"] == 0, f"unconverged requests: {doc['unconverged']}"
batched = doc["rebuilds"]
standalone = doc["standalone"]["rebuilds"]
assert standalone > 0, "standalone baseline never rebuilt - nothing amortized"
assert batched < standalone, f"rebuilds not amortized: {batched} vs {standalone}"
print(f"service amortization OK: {batched} batched rebuilds vs {standalone} standalone")
PY
}

STAGES=(sparse vectorized fleet wire chaos topology greedy threads service)

usage() {
  local IFS='|'
  echo "usage: $0 [all|${STAGES[*]}]" >&2
  exit 2
}

main() {
  local pick=${1:-all}
  if [ "$pick" = all ]; then
    for s in "${STAGES[@]}"; do
      echo "==> smoke stage: $s"
      "stage_$s"
    done
    return
  fi
  for s in "${STAGES[@]}"; do
    if [ "$pick" = "$s" ]; then
      echo "==> smoke stage: $s"
      "stage_$s"
      return
    fi
  done
  usage
}

main "$@"
