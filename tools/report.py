#!/usr/bin/env python3
"""Render results/*.json (fedsink --out dumps) as markdown tables.

Usage: python tools/report.py [results_dir] > report.md

Each experiment document carries an `experiment` tag; this tool picks a
renderer per tag and degrades to a key dump for unknown shapes, so new
drivers keep working without edits here.
"""

from __future__ import annotations

import json
import os
import sys


def fmt(x):
    if isinstance(x, bool):
        return "yes" if x else "no"
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)


def table(headers, rows):
    out = ["| " + " | ".join(headers) + " |", "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(fmt(c) for c in r) + " |")
    return "\n".join(out)


def render_epsilon(doc):
    rows = [
        (r["eps"], r["i_min"], r["objective"], r["err_a"], r["collapsed"])
        for r in doc["rows"]
    ]
    return table(["eps", "I_min", "objective", "err_a", "collapsed"], rows)


def render_timing(doc):
    rows = [
        (r["nodes"], r["comp_mean"], r["comp_std"], r["comm_mean"], r["comm_std"])
        for r in doc["rows"]
    ]
    return table(["nodes", "comp mean (s)", "std", "comm mean (s)", "std"], rows)


def render_vectorized(doc):
    parts = []
    if "serial_compare" in doc:
        sc = doc["serial_compare"]
        parts.append(
            table(
                ["N", "1 problem (s)", "vectorized (s)", "serial (s)"],
                [(sc["nhist"], sc["one_secs"], sc["vectorized_secs"], sc["serial_secs"])],
            )
        )
    rows = [(r["nhist"], r["nodes"], r["comp_secs"], r["comm_secs"]) for r in doc["rows"]]
    parts.append(table(["N", "nodes", "comp (s)", "comm (s)"], rows))
    return "\n\n".join(parts)


def render_stepsize(doc):
    headers = ["nodes"] + [f"α={c['alpha']}" for c in doc["rows"][0]["cells"]]
    rows = []
    for r in doc["rows"]:
        rows.append([r["nodes"]] + [c["mean_secs"] for c in r["cells"]])
    return table(headers, rows)


def render_delays(doc):
    rows = [
        (r["nodes"], r["samples"], r["tau_max"], r["tau_mean"], r["tau_std"])
        for r in doc["rows"]
    ]
    return table(["nodes", "samples", "tau_max", "tau_mean", "tau_std"], rows)


def render_robustness(doc):
    parts = []
    for t in doc["tables"]:
        parts.append(f"**{t['nodes']} nodes**")
        for s in t["settings"]:
            rows = [
                (c["limit"], c["threshold"], c["avg_secs"], c["pct_convergence"],
                 c["pct_timeout"], c["pct_divergence"])
                for c in s["cells"]
            ]
            parts.append(f"*{s['setting']}*\n\n" + table(
                ["limit", "thresh", "avg s", "% conv", "% timeout", "% div"], rows))
    if doc.get("alpha_sweep"):
        rows = [(c["alpha"], c["pct_convergence"]) for c in doc["alpha_sweep"]]
        parts.append("*Fig 13 α sweep*\n\n" + table(["alpha", "% conv"], rows))
    return "\n\n".join(parts)


def render_perf_grid(doc):
    rows = [
        (r["variant"], r["n"], r["clients"], r["nhist"], r["sparsity"], r["cond"],
         r["comp_secs"], r["comm_secs"], r["total_secs"], r["iterations"], r["converged"])
        for r in doc["rows"]
    ]
    out = table(
        ["variant", "n", "c", "N", "s", "cond", "comp", "comm", "total", "iters", "cvg"],
        rows,
    )
    if doc.get("chi2"):
        out += "\n\n*Table VI (χ²)*\n\n" + table(
            ["n", "chi2", "p", "df"],
            [(r["n"], r["chi2"], r["p_value"], r["df"]) for r in doc["chi2"]],
        )
    return out


def render_finance(doc):
    parts = []
    if "paper_example" in doc:
        rows = [
            (r["variant"], r["rho_worst"], r["inner_iters"], r["secs"], r["converged"])
            for r in doc["paper_example"]
        ]
        parts.append(table(["variant", "rho_worst", "iters", "secs", "cvg"], rows))
    if "synthetic" in doc:
        s = doc["synthetic"]
        parts.append(table(list(s.keys()), [list(s.values())]))
    return "\n\n".join(parts)


def render_generic(doc):
    keys = [k for k, v in doc.items() if not isinstance(v, (list, dict))]
    return table(keys, [[doc[k] for k in keys]])


RENDERERS = {
    "epsilon-study": render_epsilon,
    "timing": render_timing,
    "vectorized": render_vectorized,
    "stepsize": render_stepsize,
    "delays": render_delays,
    "robustness": render_robustness,
    "perf-grid": render_perf_grid,
    "finance": render_finance,
}


def main() -> int:
    results_dir = sys.argv[1] if len(sys.argv) > 1 else "results"
    if not os.path.isdir(results_dir):
        print(f"no results directory {results_dir!r}", file=sys.stderr)
        return 1
    print("# fedsink experiment report\n")
    for name in sorted(os.listdir(results_dir)):
        if not name.endswith(".json"):
            continue
        path = os.path.join(results_dir, name)
        try:
            with open(path) as fh:
                doc = json.load(fh)
        except json.JSONDecodeError as e:
            print(f"## {name}\n\n(unparseable: {e})\n")
            continue
        tag = doc.get("experiment", "?")
        print(f"## {name} — `{tag}`\n")
        renderer = RENDERERS.get(tag, render_generic)
        try:
            print(renderer(doc))
        except (KeyError, IndexError, TypeError) as e:
            print(f"(renderer failed: {e}; falling back)\n")
            print(render_generic(doc))
        print()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
