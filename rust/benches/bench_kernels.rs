//! Micro-benchmarks of the compute hot path + the backend ablations:
//! * native dense GEMV/GEMM, threaded scaling, CSR crossover (sparsity);
//! * log-domain logsumexp vs GEMV — the stabilized small-ε path's cost
//!   relative to the linear hot path, tracked in the perf trajectory;
//! * XLA artifact dispatch (needs `--features xla-backend` + artifacts):
//!   plain-XLA vs Pallas-lowered modules vs the native kernels (the L1
//!   impl ablation of DESIGN.md §7).

mod common;

use fedsink::benchkit::{section, write_baseline, Bench, BenchResult};
use fedsink::linalg::{AbsorbedLogCsr, LogCsr, Mat};
use fedsink::rng::{child_seed, Rng};

/// Random log-kernel block with a fraction `s` of entries hard-masked to
/// `−∞` — the §IV-D sparse-kernel regime seen from the log domain.
fn masked_log_kernel(n: usize, s: f64, rng: &mut Rng) -> Mat {
    let mut a = Mat::rand_uniform(n, n, -8.0, 0.0, rng);
    for i in 0..n {
        for j in 0..n {
            // Keep the diagonal so no row masks out entirely.
            if i != j && rng.uniform() < s {
                a[(i, j)] = f64::NEG_INFINITY;
            }
        }
    }
    a
}

fn main() {
    let b = Bench::default();
    // Quick mode (CI) pins a deterministic subset of the full case list;
    // every case reseeds its own RNG from its parameters, so the emitted
    // timings (and case names) are stable run-to-run and mode-to-mode —
    // the contract `tools/bench_diff.py` gates on.
    let quick = Bench::quick();
    let mut baseline: Vec<BenchResult> = Vec::new();

    section("native GEMV / GEMM (n x n @ n x N)");
    let gemm_shapes: &[(usize, usize)] = if quick {
        &[(512, 1), (512, 64)]
    } else {
        &[(512, 1), (512, 64), (1024, 1), (1024, 64)]
    };
    for &(n, nh) in gemm_shapes {
        let mut rng = Rng::seed_from(child_seed(0xB_0001, (n * 1000 + nh) as u64));
        let a = Mat::rand_uniform(n, n, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let mut out = Mat::zeros(n, nh);
        for threads in [1usize, 4] {
            baseline.push(b.run(
                &format!("native matmul n={n} N={nh} threads={threads}"),
                || a.matmul_into(&x, &mut out, threads),
            ));
        }
    }

    section("log-domain logsumexp vs GEMV (same shapes, log-kernel input)");
    for &(n, nh) in gemm_shapes {
        // A log-kernel block (−C/ε scale) and log-scalings.
        let mut rng = Rng::seed_from(child_seed(0xB_0002, (n * 1000 + nh) as u64));
        let a_log = Mat::rand_uniform(n, n, -40.0, 0.0, &mut rng);
        let x_log = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let mut out = Mat::zeros(n, nh);
        for threads in [1usize, 4] {
            baseline.push(b.run(
                &format!("logsumexp n={n} N={nh} threads={threads}"),
                || a_log.logsumexp_into(&x_log, &mut out, threads),
            ));
        }
    }

    section("CSR vs dense at off-diagonal sparsity (n=1024, N=1)");
    let n = 1024;
    for &s in &[0.0f64, 0.5, 0.9, 1.0] {
        let mut rng = Rng::seed_from(child_seed(0xB_0003, (s * 100.0) as u64));
        let p = fedsink::workload::ProblemSpec::new(n)
            .with_sparsity(s, 4)
            .build(5);
        let x = Mat::rand_uniform(n, 1, 0.1, 1.0, &mut rng);
        let mut out = Mat::zeros(n, 1);
        let csr = fedsink::linalg::Csr::from_dense(p.kernel(), 1e-300);
        baseline.push(b.run(
            &format!("dense  s={s} (density {:.2})", csr.density()),
            || p.kernel().matmul_into(&x, &mut out, 1),
        ));
        baseline.push(b.run(&format!("csr    s={s}"), || csr.matmul_into(&x, &mut out, 1)));
    }

    section("truncated sparse-log LSE vs dense logsumexp (N=1)");
    // Mask fraction s → density ≈ 1−s; the n=4096 rows are the
    // acceptance bar for the stabilized sparse engine: sparse ≥ 4×
    // dense at density ≤ 0.1.
    let lse_shapes: &[(usize, f64)] = if quick {
        &[(1024, 0.9), (1024, 0.99)]
    } else {
        &[(1024, 0.0), (1024, 0.5), (1024, 0.9), (1024, 0.99), (4096, 0.9), (4096, 0.99)]
    };
    for &(n, s) in lse_shapes {
        let mut rng =
            Rng::seed_from(child_seed(0xB_0004, (n * 1000 + (s * 100.0) as usize) as u64));
        let a_log = masked_log_kernel(n, s, &mut rng);
        let lc = LogCsr::from_dense_log(&a_log, f64::NEG_INFINITY);
        let x_log = Mat::rand_uniform(n, 1, -2.0, 2.0, &mut rng);
        let mut out = Mat::zeros(n, 1);
        baseline.push(b.run(
            &format!("dense-log  n={n} s={s} (density {:.3})", lc.density()),
            || a_log.logsumexp_into(&x_log, &mut out, 1),
        ));
        baseline.push(b.run(&format!("sparse-log n={n} s={s}"), || {
            lc.logsumexp_into(&x_log, &mut out, 1)
        }));
    }

    section("greedy top-k row folds vs full products (s=0.9, N=1)");
    // The greedy exchange's compute claim: a k-row violation update
    // pays ~k/n of the full fold. Packed row-subset kernels against
    // the full products on the same operands — linear CSR GEMV and
    // sparse-log LSE — at k = n/8 and n/2. Stable `note` identities
    // keep the perf gate matching these across rewordings.
    let topk_shapes: &[usize] = if quick { &[1024] } else { &[1024, 4096] };
    for &n in topk_shapes {
        let mut rng = Rng::seed_from(child_seed(0xB_000A, n as u64));
        let p = fedsink::workload::ProblemSpec::new(n).with_sparsity(0.9, 4).build(7);
        let csr = fedsink::linalg::Csr::from_dense(p.kernel(), 1e-300);
        let x = Mat::rand_uniform(n, 1, 0.1, 1.0, &mut rng);
        let mut full_out = Mat::zeros(n, 1);
        baseline.push(
            b.run(&format!("csr full-fold  n={n}"), || csr.matmul_into(&x, &mut full_out, 1))
                .with_note(&format!("topk-csr-full-n{n}")),
        );
        for &k in &[n / 8, n / 2] {
            let sel: Vec<u32> = (0..n as u32).step_by(n / k).take(k).collect();
            let mut out = vec![0.0; sel.len()];
            baseline.push(
                b.run(&format!("csr top-k fold n={n} k={k}"), || {
                    csr.matmul_select_rows(&sel, &x, &mut out, 1)
                })
                .with_note(&format!("topk-csr-select-n{n}-k{k}")),
            );
        }
        let a_log = masked_log_kernel(n, 0.9, &mut rng);
        let lc = LogCsr::from_dense_log(&a_log, f64::NEG_INFINITY);
        let x_log = Mat::rand_uniform(n, 1, -2.0, 2.0, &mut rng);
        let mut lse_full = Mat::zeros(n, 1);
        baseline.push(
            b.run(&format!("log full-lse   n={n}"), || {
                lc.logsumexp_into(&x_log, &mut lse_full, 1)
            })
            .with_note(&format!("topk-log-full-n{n}")),
        );
        for &k in &[n / 8, n / 2] {
            let sel: Vec<u32> = (0..n as u32).step_by(n / k).take(k).collect();
            let mut out = vec![0.0; sel.len()];
            baseline.push(
                b.run(&format!("log top-k lse  n={n} k={k}"), || {
                    lc.logsumexp_rows(&sel, &x_log, &mut out, 1)
                })
                .with_note(&format!("topk-log-select-n{n}-k{k}")),
            );
        }
    }

    section("multi-histogram absorbed sparse GEMM vs dense LSE (s=0.9)");
    // The vectorized hybrid's linear hot path: one shared-support
    // absorbed kernel, per-histogram column corrections, batched
    // multi-RHS GEMM — against the dense multi-RHS logsumexp the
    // pre-hybrid schedule paid every iteration.
    let absorbed_shapes: &[(usize, usize)] = if quick {
        &[(512, 8)]
    } else {
        &[(512, 8), (1024, 8), (1024, 64)]
    };
    for &(n, nh) in absorbed_shapes {
        let mut rng = Rng::seed_from(child_seed(0xB_0005, (n * 1000 + nh) as u64));
        let a_log = masked_log_kernel(n, 0.9, &mut rng);
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, 15.0, 15.0);
        let x_log = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let mut ex = Mat::zeros(n, nh);
        let mut lin = Mat::zeros(n, nh);
        let mut out = Mat::zeros(n, nh);
        baseline.push(b.run(
            &format!("dense-lse N-RHS n={n} N={nh} (density {:.3})", k.density()),
            || a_log.logsumexp_into(&x_log, &mut out, 1),
        ));
        baseline.push(b.run(&format!("absorbed-gemm   n={n} N={nh}"), || {
            k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut out, 1)
        }));
        // The partial O(nnz) re-absorption tier (reference move within
        // the anchor budget) — idempotent, so repeated reps are fair.
        let gref: Vec<f64> = (0..n).map(|j| x_log[(j, 0)]).collect();
        let mut kk = k.clone();
        baseline.push(
            b.run(&format!("absorbed-reabsorb n={n} N={nh}"), || kk.reabsorb(&gref)),
        );
    }

    section("absorbed GEMM thread crossover (s=0.9)");
    // The shape-aware thread dispatch of the hybrid engine (the pool's
    // calibrated `par_min_work` crossover, `FEDSINK_PAR_MIN_WORK` to
    // override) is charted here: at nnz·N below the crossover the
    // banded SpMM loses to its own dispatch cost, above it the
    // configured threads win. Stable
    // `note` identities keep the perf gate tracking these cases across
    // rewordings.
    let xover_shapes: &[(usize, usize)] = if quick {
        &[(512, 8), (1024, 64)]
    } else {
        &[(256, 8), (512, 8), (1024, 8), (1024, 64)]
    };
    for &(n, nh) in xover_shapes {
        let mut rng = Rng::seed_from(child_seed(0xB_0007, (n * 1000 + nh) as u64));
        let a_log = masked_log_kernel(n, 0.9, &mut rng);
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, 15.0, 15.0);
        let x_log = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let mut ex = Mat::zeros(n, nh);
        let mut lin = Mat::zeros(n, nh);
        let mut out = Mat::zeros(n, nh);
        for threads in [1usize, 2, 4] {
            baseline.push(
                b.run(
                    &format!(
                        "absorbed-gemm n={n} N={nh} t={threads} (nnzN={})",
                        k.nnz() * nh
                    ),
                    || k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut out, threads),
                )
                .with_note(&format!("absorbed-gemm-xover-n{n}-N{nh}-t{threads}")),
            );
        }
    }

    section("wire codec: encode cost per format (n=4096 slice stream)");
    // The --wire-format encode path as the fabric pays it: per-slice
    // scale header + 4-byte lanes + error-feedback residual (f32), plus
    // the delta reference walk (deltaf32). The clone models the payload
    // hand-off every send performs, identically across formats.
    {
        use fedsink::net::wire::{StreamCodec, WireFormat};
        let n = 4096usize;
        let mut rng = Rng::seed_from(child_seed(0xB_0008, n as u64));
        let values: Vec<f64> = (0..n).map(|_| rng.uniform_range(-50.0, 50.0)).collect();
        for fmt in [WireFormat::F64, WireFormat::F32, WireFormat::DeltaF32] {
            let mut codec = StreamCodec::new(fmt);
            baseline.push(
                b.run(&format!("wire-encode {} n={n}", fmt.name()), || {
                    let _ = codec.encode(values.clone());
                })
                .with_note(&format!("wire-encode-{}-n{n}", fmt.name())),
            );
        }
    }

    section("fleet absorption tiers: partial reference move vs full retruncation");
    // The two costs a fleet-synchronized absorb command arbitrates per
    // node: the O(nnz) reference move the shared anchor usually allows
    // vs the O(m·n) rebuild a drifted anchor forces. These cases carry
    // stable `note` identities so the perf gate keeps matching them if
    // the display names are ever reworded (tools/bench_diff.py falls
    // back to note-based matching and --write-baseline preserves notes).
    let fleet_shapes: &[usize] = if quick { &[512] } else { &[512, 1024] };
    for &n in fleet_shapes {
        let mut rng = Rng::seed_from(child_seed(0xB_0006, n as u64));
        let a_log = masked_log_kernel(n, 0.9, &mut rng);
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, 15.0, 15.0);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-5.0, 5.0)).collect();
        let mut partial = k.clone();
        baseline.push(
            b.run(&format!("fleet partial-move n={n}"), || partial.reabsorb(&gref))
                .with_note(&format!("fleet-partial-move-n{n}")),
        );
        let mut full = k.clone();
        baseline.push(
            b.run(&format!("fleet full-retruncate n={n}"), || {
                full.retruncate(&a_log, &gref, 15.0)
            })
            .with_note(&format!("fleet-full-retruncate-n{n}")),
        );
    }

    section("spawn vs pool dispatch (banded dot-product loop, t=4)");
    // The worker-pool runtime's claim, measured: one identical band
    // body — a plain row·x dot loop — dispatched two ways. The pool
    // side submits to the resident workers (park/unpark handoff); the
    // scoped side pays a fresh `crossbeam` thread spawn per call, the
    // dispatch every hot kernel used before the pool. The gap is pure
    // dispatch overhead, largest at streamed-fold slice sizes (small
    // n). Stable `note` identities keep the perf gate matching these.
    let spawn_shapes: &[usize] = if quick { &[256, 2048] } else { &[256, 512, 1024, 2048] };
    for &n in spawn_shapes {
        let mut rng = Rng::seed_from(child_seed(0xB_0009, n as u64));
        let a = Mat::rand_uniform(n, n, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(n, 1, 0.1, 1.0, &mut rng);
        let (data, xs) = (a.as_slice(), x.as_slice());
        let threads = 4usize;
        let mut out = vec![0.0; n];
        let band_dot = |band: &mut [f64], r0: usize| {
            for (i, oi) in band.iter_mut().enumerate() {
                let row = &data[(r0 + i) * n..(r0 + i) * n + n];
                *oi = row.iter().zip(xs).map(|(aij, xj)| aij * xj).sum();
            }
        };
        struct SendPtr(*mut f64);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let pool = fedsink::runtime::Pool::global().with_share(threads);
        let base = SendPtr(out.as_mut_ptr());
        baseline.push(
            b.run(&format!("pool-dispatch banded-dot n={n} t={threads}"), || {
                pool.run_bands(n, |_, r0, r1| {
                    // Bands are disjoint, so the aliased writes are safe.
                    let band = unsafe { std::slice::from_raw_parts_mut(base.0.add(r0), r1 - r0) };
                    band_dot(band, r0);
                })
            })
            .with_note(&format!("pool-dispatch-dot-n{n}-t{threads}")),
        );
        let per = n.div_ceil(threads);
        baseline.push(
            b.run(&format!("scoped-spawn  banded-dot n={n} t={threads}"), || {
                let bd = &band_dot;
                crossbeam_utils::thread::scope(|s| {
                    for (bidx, band) in out.chunks_mut(per).enumerate() {
                        s.spawn(move |_| bd(band, bidx * per));
                    }
                })
                .unwrap();
            })
            .with_note(&format!("scoped-spawn-dot-n{n}-t{threads}")),
        );
    }

    if let Err(e) = write_baseline("BENCH_kernels.json", &baseline) {
        eprintln!("could not write BENCH_kernels.json: {e}");
    }

    let mut rng = Rng::seed_from(1);
    xla_ablation(&b, &mut rng);
}

#[cfg(not(feature = "xla-backend"))]
fn xla_ablation(_b: &Bench, _rng: &mut Rng) {
    eprintln!("skipping XLA ablation benches: built without --features xla-backend");
}

#[cfg(feature = "xla-backend")]
fn xla_ablation(b: &Bench, rng: &mut Rng) {
    use fedsink::config::BackendKind;
    use fedsink::runtime::{make_backend, ComputeBackend, NativeBackend, PjrtRuntime, Target};

    if !common::artifacts_available() {
        eprintln!("skipping XLA ablation benches: run `make artifacts`");
        return;
    }

    section("backend ablation: client_update (m=n, N=1)");
    let dir = fedsink::config::default_artifacts_dir();
    let xla_be = make_backend(BackendKind::Xla, &dir, 1).expect("xla backend");
    let native = NativeBackend::new(1);
    for &n in &[256usize, 512] {
        let a = Mat::rand_uniform(n, n, 0.1, 1.0, rng);
        let x = Mat::rand_uniform(n, 1, 0.1, 1.0, rng);
        let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let mut op_x = xla_be.block_op(&a, Target::Vec(&t), Mat::ones(n, 1)).unwrap();
        let mut op_n = native.block_op(&a, Target::Vec(&t), Mat::ones(n, 1)).unwrap();
        b.run(&format!("xla    update n={n}"), || {
            op_x.update(&x, 1.0);
        });
        b.run(&format!("native update n={n}"), || {
            op_n.update(&x, 1.0);
        });
    }

    section("artifact impl ablation: plain-XLA vs Pallas-lowered HLO");
    let rt = PjrtRuntime::shared(&dir).expect("runtime");
    for &n in &[256usize, 512] {
        let (Some(ex), Some(ep)) = (
            rt.manifest().find_impl("client_update", "xla", n, n, 1, 0),
            rt.manifest().find_impl("client_update", "pallas", n, n, 1, 0),
        ) else {
            continue;
        };
        let a = Mat::rand_uniform(n, n, 0.1, 1.0, rng);
        let x = Mat::rand_uniform(n, 1, 0.1, 1.0, rng);
        let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let mk = |d: &[f64], dims: &[i64]| xla::Literal::vec1(d).reshape(dims).unwrap();
        let inputs = vec![
            mk(a.as_slice(), &[n as i64, n as i64]),
            mk(x.as_slice(), &[n as i64, 1]),
            xla::Literal::vec1(&t),
            mk(Mat::ones(n, 1).as_slice(), &[n as i64, 1]),
            xla::Literal::vec1(&[1.0f64]),
        ];
        b.run(&format!("hlo[xla]    client_update n={n}"), || {
            rt.run_entry(ex, &inputs).unwrap()
        });
        b.run(&format!("hlo[pallas] client_update n={n}"), || {
            rt.run_entry(ep, &inputs).unwrap()
        });
    }

    section("fused sweep artifact (w=10) vs 10 step dispatches");
    for &n in &[256usize, 512] {
        let Some(sweep) = rt.manifest().find_w("sinkhorn_sweep", n, n, 1, 10) else {
            continue;
        };
        let p = fedsink::workload::ProblemSpec::new(n).with_eps(0.1).build(9);
        let mk = |d: &[f64], dims: &[i64]| xla::Literal::vec1(d).reshape(dims).unwrap();
        let inputs = vec![
            mk(p.kernel().as_slice(), &[n as i64, n as i64]),
            xla::Literal::vec1(p.a.as_slice()),
            mk(p.b.as_slice(), &[n as i64, 1]),
            mk(Mat::ones(n, 1).as_slice(), &[n as i64, 1]),
            mk(Mat::ones(n, 1).as_slice(), &[n as i64, 1]),
            xla::Literal::vec1(&[1.0f64]),
        ];
        b.run(&format!("sweep w=10 n={n}"), || rt.run_entry(sweep, &inputs).unwrap());
        let be = make_backend(BackendKind::Xla, &dir, 1).unwrap();
        let mut u_op = be.block_op(p.kernel(), Target::Vec(&p.a), Mat::ones(n, 1)).unwrap();
        let kt = p.kernel_t();
        let mut v_op = be.block_op(kt, Target::Mat(&p.b), Mat::ones(n, 1)).unwrap();
        b.run(&format!("10 x step dispatch n={n}"), || {
            for _ in 0..10 {
                let u = u_op.update(v_op.state(), 1.0).clone();
                v_op.update(&u, 1.0);
            }
        });
    }
}
