//! Fig 9 / Figs 21–22 + Tables XXVIII–XXXVI — asynchronous federation:
//! repeated convergence runs (non-determinism) and α sensitivity.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::BackendKind;
use fedsink::config::Variant;
use fedsink::workload::ProblemSpec;

fn main() {
    let b = Bench::default();
    let n = if common::paper_scale() { 10000 } else { 512 };
    let backend = if common::artifacts_available() {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };

    section("Fig 9: async-a2a convergence runs (α=0.5)");
    for c in [2usize, 4, 8] {
        if n % c != 0 {
            continue;
        }
        let p = ProblemSpec::new(n).with_eps(0.05).build(41);
        b.run(&format!("async-a2a nodes={c} n={n}"), || {
            common::solve_to_convergence(&p, Variant::AsyncA2A, c, backend, 0.5)
        });
    }

    section("async-star convergence runs (α=0.5)");
    for c in [2usize, 4] {
        if n % c != 0 {
            continue;
        }
        let p = ProblemSpec::new(n).with_eps(0.05).build(43);
        b.run(&format!("async-star nodes={c} n={n}"), || {
            common::solve_to_convergence(&p, Variant::AsyncStar, c, backend, 0.5)
        });
    }
}
