//! Fig 25 — convergence time of the three settings on the §V-B4
//! financial worked example, plus the λ-search pipeline on the larger
//! synthetic book.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::finance::{synthetic_portfolio, worst_case_loss, LambdaSearch, WorstCaseSpec};
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::StopPolicy;

fn main() {
    let b = Bench::default();
    let policy = StopPolicy { threshold: 1e-12, max_iters: 20_000, ..Default::default() };

    section("Fig 25: worked example across the three settings");
    let spec = WorstCaseSpec::paper_example();
    for (variant, alpha) in [
        (Variant::SyncA2A, 1.0),
        (Variant::SyncStar, 1.0),
        (Variant::AsyncA2A, 0.5),
    ] {
        let cfg = SolveConfig {
            variant,
            backend: BackendKind::Native,
            clients: 3,
            alpha,
            net: LatencyModel::lan(),
            ..Default::default()
        };
        b.run(&format!("{} worked example", variant.name()), || {
            worst_case_loss(&spec, &cfg, policy, LambdaSearch::fixed(spec.lambda))
        });
    }

    section("lambda-search on the synthetic book");
    let scenarios = if common::paper_scale() { 256 } else { 64 };
    let data = synthetic_portfolio(12, scenarios, 7);
    let spec = WorstCaseSpec {
        returns: data.historical,
        targets: data.analyst_view,
        weights: vec![1.0 / scenarios as f64; scenarios],
        lambda: 0.5,
        delta: 1e-4,
        eps: 0.01,
        margin: 0.01,
    };
    let cfg = SolveConfig {
        variant: Variant::SyncA2A,
        backend: BackendKind::Native,
        clients: 4,
        net: LatencyModel::lan(),
        ..Default::default()
    };
    let pol = StopPolicy { threshold: 1e-10, max_iters: 20_000, ..Default::default() };
    b.run(&format!("bisection search, {scenarios} scenarios"), || {
        worst_case_loss(&spec, &cfg, pol, LambdaSearch::bisection(1e-3, 16.0, 1e-6, 12))
    });
}
