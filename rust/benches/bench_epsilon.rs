//! Figs 4–5 — ε sensitivity of the centralized solver on the paper's
//! 4×4 worked example (iteration count ∝ 1/ε).

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::BackendKind;
use fedsink::runtime::make_backend;
use fedsink::sinkhorn::{CentralizedSolver, StopPolicy};
use fedsink::workload::Problem;

fn main() {
    let b = Bench::default();
    section("Figs 4-5: time to convergence vs epsilon (4x4 example)");
    let solver = CentralizedSolver::new(make_backend(BackendKind::Native, "", 1).unwrap());
    for &eps in &[5e-2, 5e-3, 1e-3, 1e-4] {
        let p = Problem::paper_4x4(eps);
        let policy = StopPolicy {
            threshold: 1e-15,
            max_iters: 2_000_000,
            check_every: 100,
            ..Default::default()
        };
        let r = b.run(&format!("eps={eps:.0e}"), || solver.solve(&p, policy, 1.0).iterations);
        let out = solver.solve(&p, policy, 1.0);
        println!(
            "    -> {} iterations ({}), {:.2} iters/(1/eps)",
            out.iterations,
            if out.converged() { "converged" } else { "cap" },
            out.iterations as f64 * eps
        );
        let _ = r;
    }
}
