//! Network-substrate micro-benchmarks: AllGather / Gather+Scatter /
//! latest-wins drains vs payload size and node count — the comm-side
//! costs behind Figs 6/8/14.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::net::{allgather, LatencyModel, SimNet, TagKind};
use std::sync::Arc;

fn main() {
    let b = Bench::default();

    section("AllGather wall time vs payload (zero-latency fabric)");
    for &nodes in &[2usize, 4, 8] {
        for &len in &[256usize, 4096, 65536] {
            b.run(&format!("allgather nodes={nodes} len={len}"), || {
                run_allgather(nodes, len, LatencyModel::zero())
            });
        }
    }

    section("AllGather wall time vs payload (LAN profile)");
    for &nodes in &[2usize, 4] {
        for &len in &[256usize, 65536] {
            b.run(&format!("allgather+lan nodes={nodes} len={len}"), || {
                run_allgather(nodes, len, LatencyModel::lan())
            });
        }
    }

    section("latest-wins drain under backlog");
    for &backlog in &[1usize, 16, 256] {
        b.run(&format!("drain backlog={backlog}"), || {
            let net = Arc::new(SimNet::new(2, LatencyModel::zero(), 3));
            let a = net.endpoint(0);
            let bep = net.endpoint(1);
            for k in 0..backlog {
                a.send(1, TagKind::U, 0, vec![k as f64; 1024], k as u64);
            }
            bep.try_recv_latest(0, TagKind::U, 0)
        });
    }
}

fn run_allgather(nodes: usize, len: usize, lat: LatencyModel) {
    let net = Arc::new(SimNet::new(nodes, lat, 1));
    crossbeam_utils::thread::scope(|s| {
        for me in 0..nodes {
            let net = net.clone();
            s.spawn(move |_| {
                let ep = net.endpoint(me);
                let mine = vec![me as f64; len];
                let _ = allgather(&ep, TagKind::U, 0, &mine, 0);
            });
        }
    })
    .unwrap();
}
