//! Figs 6/14/18 — per-node comp/comm split at a fixed iteration budget,
//! across node counts, on both backends ("GPU-speed" XLA vs "CPU-speed"
//! native).

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::{BackendKind, Variant};
use fedsink::workload::ProblemSpec;

fn main() {
    let b = Bench::default();
    let n = if common::paper_scale() { 10000 } else { 1024 };
    let iters = if common::paper_scale() { 250 } else { 50 };
    let p = ProblemSpec::new(n).with_eps(0.05).build(77);

    for (title, backend) in [
        ("Fig 6: sync-a2a, XLA backend (GPU-speed stand-in)", BackendKind::Xla),
        ("Fig 18: sync-a2a, native backend (CPU-speed)", BackendKind::Native),
    ] {
        if backend == BackendKind::Xla && !common::artifacts_available() {
            eprintln!("skipping XLA timing bench (no artifacts)");
            continue;
        }
        section(title);
        for c in [1usize, 2, 4, 8] {
            if n % c != 0 {
                continue;
            }
            let variant = if c == 1 { Variant::Centralized } else { Variant::SyncA2A };
            b.run(&format!("{} nodes={c} n={n} iters={iters}", backend.name()), || {
                common::solve_fixed_iters(&p, variant, c, backend, iters)
            });
        }
    }

    section("Fig 14: async-a2a comp/comm at fixed budget");
    for c in [2usize, 4, 8] {
        if n % c != 0 {
            continue;
        }
        b.run(&format!("async nodes={c} n={n} iters={iters}"), || {
            common::solve_fixed_iters(&p, Variant::AsyncA2A, c, BackendKind::Native, iters)
        });
    }
}
