//! App A, Figs 26–28 — local iterations w: time-to-convergence of the
//! sync federation as w grows (the paper finds pure slowdown).

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::StopPolicy;
use fedsink::workload::ProblemSpec;

fn main() {
    let b = Bench::default();
    let n = if common::paper_scale() { 1000 } else { 256 };
    let p = ProblemSpec::new(n).with_eps(0.05).build(88);
    section("Figs 26-28: sync-a2a convergence vs local iterations w");
    for &w in &[1usize, 2, 4, 8] {
        let cfg = SolveConfig {
            variant: Variant::SyncA2A,
            backend: BackendKind::Native,
            clients: 4,
            local_iters: w,
            net: LatencyModel::lan(),
            ..Default::default()
        };
        let policy = StopPolicy { threshold: 1e-12, max_iters: 2000, ..Default::default() };
        let mut iters = 0;
        b.run(&format!("w={w}"), || {
            let out = run_federated(&p, &cfg, policy, false);
            iters = out.iterations;
        });
        println!("    -> {iters} compute iterations to convergence");
    }
}
