//! Tables VII–XXXVI — the appendix performance grids, as timed benches:
//! centralized / sync-a2a / sync-star / async-a2a convergence runs over
//! the n × sparsity grid. The `fedsink perf-grid` subcommand prints the
//! full paper-format tables; this target provides the stable timing
//! series for EXPERIMENTS.md.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::{BackendKind, Variant};
use fedsink::workload::{CondClass, ProblemSpec};

fn main() {
    let b = Bench::default();
    let backend = if common::artifacts_available() {
        BackendKind::Xla
    } else {
        eprintln!("artifacts missing; using native backend");
        BackendKind::Native
    };

    for (title, variant, clients, alpha) in [
        ("Tables VII-IX: centralized", Variant::Centralized, 1usize, 1.0),
        ("Tables X-XVIII: sync all-to-all (4 nodes)", Variant::SyncA2A, 4, 1.0),
        ("Tables XIX-XXVII: sync star (4 nodes)", Variant::SyncStar, 4, 1.0),
        ("Tables XXVIII-XXXVI: async a2a (4 nodes, α=0.5)", Variant::AsyncA2A, 4, 0.5),
    ] {
        section(title);
        for &n in &common::sizes() {
            if n % clients != 0 {
                continue;
            }
            for &s in &[0.0, 0.9] {
                let p = ProblemSpec::new(n)
                    .with_eps(0.05)
                    .with_sparsity(s, 4)
                    .with_condition(CondClass::Well)
                    .build(21);
                b.run(&format!("{} n={n} s={s}", variant.name()), || {
                    common::solve_to_convergence(&p, variant, clients, backend, alpha)
                });
            }
        }
    }
}
