#![allow(dead_code)] // shared across bench targets; each uses a subset

//! Shared bench scaffolding (no `criterion` offline — see benchkit).

use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::StopPolicy;
use fedsink::workload::Problem;

/// Bench-scale knobs: `FEDSINK_SCALE=paper` widens the grids, default
/// keeps `cargo bench` to minutes.
pub fn sizes() -> Vec<usize> {
    if paper_scale() {
        vec![1000, 5000, 10000]
    } else {
        vec![256, 1024]
    }
}

pub fn paper_scale() -> bool {
    std::env::var("FEDSINK_SCALE").as_deref() == Ok("paper")
}

pub fn artifacts_available() -> bool {
    // Artifacts are only usable when the PJRT runtime is compiled in.
    if !cfg!(feature = "xla-backend") {
        return false;
    }
    let dir = fedsink::config::default_artifacts_dir();
    std::path::Path::new(&dir).join("manifest.json").exists()
}

/// One end-to-end solve at a fixed iteration budget (timing tables).
pub fn solve_fixed_iters(
    p: &Problem,
    variant: Variant,
    clients: usize,
    backend: BackendKind,
    iters: usize,
) -> f64 {
    let cfg = SolveConfig {
        variant,
        backend,
        clients,
        net: LatencyModel::lan(),
        ..Default::default()
    };
    let policy = StopPolicy {
        threshold: 0.0,
        max_iters: iters,
        check_every: iters + 1,
        ..Default::default()
    };
    let out = run_federated(p, &cfg, policy, false);
    out.secs
}

/// One convergence-bounded solve (perf-grid tables).
pub fn solve_to_convergence(
    p: &Problem,
    variant: Variant,
    clients: usize,
    backend: BackendKind,
    alpha: f64,
) -> (bool, usize, f64) {
    let cfg = SolveConfig {
        variant,
        backend,
        clients,
        alpha,
        net: LatencyModel::lan(),
        ..Default::default()
    };
    let policy = StopPolicy { threshold: 1e-13, max_iters: 1500, ..Default::default() };
    let out = run_federated(p, &cfg, policy, false);
    (out.converged, out.iterations, out.secs)
}
