//! Table I + Fig 13 — async damping step size: time-to-convergence per
//! (α × node count), CPU-speed backend like the paper's §IV-C2.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::BackendKind;
use fedsink::config::Variant;
use fedsink::workload::ProblemSpec;

fn main() {
    let b = Bench::default();
    let n = if common::paper_scale() { 10000 } else { 512 };
    section("Table I: async time-to-convergence vs alpha x nodes");
    for c in [2usize, 4, 8] {
        if n % c != 0 {
            continue;
        }
        for &alpha in &[0.1, 0.25, 0.5] {
            let p = ProblemSpec::new(n).with_eps(0.05).build(55);
            b.run(&format!("nodes={c} alpha={alpha}"), || {
                common::solve_to_convergence(&p, Variant::AsyncA2A, c, BackendKind::Native, alpha)
            });
        }
    }
}
