//! Figs 7–8 + §IV-B3 — vectorized N-histogram solves: compute time vs N
//! and serial-vs-vectorized dispatch.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::BackendKind;
use fedsink::config::Variant;
use fedsink::workload::ProblemSpec;

fn main() {
    let b = Bench::default();
    let backend = if common::artifacts_available() {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };
    let n = 512;
    let iters = 15; // the paper's fixed budget for this study

    section("Fig 7: compute time vs N (centralized and 2/4-node sync)");
    for &nh in &[1usize, 64, 512, 4096] {
        let p = ProblemSpec::new(n).with_hists(nh).with_eps(0.1).build(33);
        for c in [1usize, 2, 4] {
            let variant = if c == 1 { Variant::Centralized } else { Variant::SyncA2A };
            b.run(&format!("N={nh} nodes={c}"), || {
                common::solve_fixed_iters(&p, variant, c, backend, iters)
            });
        }
    }

    section("§IV-B3: serial vs vectorized (N=64)");
    let nh = 64;
    let p = ProblemSpec::new(n).with_hists(nh).with_eps(0.1).build(35);
    b.run("vectorized: one n x N solve", || {
        common::solve_fixed_iters(&p, Variant::Centralized, 1, backend, iters)
    });
    let single = ProblemSpec::new(n).with_hists(1).with_eps(0.1).build(35);
    b.run("serial: one histogram at a time (x1 shown)", || {
        common::solve_fixed_iters(&single, Variant::Centralized, 1, backend, iters)
    });
}
