//! Figs 15–17 + Table V — staleness: fixed-budget async runs that feed
//! the τ tracker; prints the per-node-count delay statistics alongside
//! the timing.

mod common;

use fedsink::benchkit::{section, Bench};
use fedsink::config::{BackendKind, SolveConfig, Variant};
use fedsink::coordinator::run_federated;
use fedsink::metrics::Summary;
use fedsink::net::LatencyModel;
use fedsink::sinkhorn::StopPolicy;
use fedsink::workload::ProblemSpec;

fn main() {
    let b = Bench::default();
    let n = if common::paper_scale() { 10000 } else { 512 };
    let iters = 500;
    section("Table V: tau statistics from fixed-budget async runs");
    for c in [2usize, 4, 8] {
        if n % c != 0 {
            continue;
        }
        let p = ProblemSpec::new(n).with_eps(0.05).build(61);
        let cfg = SolveConfig {
            variant: Variant::AsyncA2A,
            backend: BackendKind::Native,
            clients: c,
            alpha: 0.5,
            net: LatencyModel::lan(),
            ..Default::default()
        };
        let policy = StopPolicy {
            threshold: 0.0,
            max_iters: iters,
            check_every: iters + 1,
            ..Default::default()
        };
        let mut taus: Vec<f64> = Vec::new();
        b.run(&format!("async T={iters} nodes={c}"), || {
            let out = run_federated(&p, &cfg, policy, false);
            taus.extend(out.taus.iter().map(|&t| t as f64));
        });
        let nz: Vec<f64> = taus.iter().cloned().filter(|&t| t >= 1.0).collect();
        let s = Summary::of(&nz);
        println!(
            "    -> tau: max={} min={} mean={:.2} std={:.2} ({} samples)",
            s.max, s.min, s.mean, s.std, nz.len()
        );
    }
}
