//! Shared test-harness substrate: the hard-timeout wrapper that pins
//! "this run degrades, never hangs" across the integration suites.
//!
//! Lives in the library (not a `tests/` helper module) so every test
//! binary — faults, pool parity, service — bounds its blocking runs by
//! the **same** budget, and so CI's job-level `timeout-minutes` can be
//! reasoned about against one number instead of per-file copies.

use std::sync::mpsc;
use std::time::Duration;

/// Hard wall-clock budget for any single bounded test run. Deliberately
/// far above what a healthy run needs on a loaded CI runner: tripping it
/// means a liveness bug (a blocking wait the recovery policy does not
/// bound), not a slow machine.
pub const HARD_TIMEOUT_SECS: u64 = 30;

/// Run `f` on its own thread and fail — rather than wedge the test
/// binary — if it has not returned within [`HARD_TIMEOUT_SECS`]. A
/// recovery-path bug that blocks forever shows up as a clean test
/// failure with `what` in the message.
pub fn run_with_timeout<T: Send + 'static>(
    what: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(HARD_TIMEOUT_SECS)).unwrap_or_else(|e| {
        panic!("{what}: run did not finish within {HARD_TIMEOUT_SECS}s ({e:?})")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_the_closure_result_through() {
        // (The timeout leg itself is exercised by the fault suite's
        // crash tests — tripping it here would cost HARD_TIMEOUT_SECS
        // of wall time per run.)
        assert_eq!(run_with_timeout("quick", || 41 + 1), 42);
    }
}
