//! `fedsink` — the Federated Sinkhorn launcher.
//!
//! One subcommand per paper experiment (DESIGN.md §5) plus a general
//! `solve` entry point. Python never runs here: all compute goes through
//! the AOT artifacts (PJRT) or the native kernels.

use fedsink::cli::{ArgSpec, CliError, Parsed};
use fedsink::config::{BackendKind, DomainChoice, ExchangeMode, SolveConfig, Variant};
use fedsink::experiments::{self, Scale};
use fedsink::net::{LatencyModel, WireFormat};
use fedsink::runtime::GreedySpec;
use fedsink::sinkhorn::StopPolicy;
use fedsink::workload::CondClass;

const COMMANDS: &[(&str, &str)] = &[
    ("solve", "run one federated/centralized solve on a synthetic problem"),
    ("serve", "multi-tenant solve service: batched absorbed solves over a shared geometry"),
    ("epsilon-study", "Figs 4-5: regularization sweep on the 4x4 example"),
    ("coherence", "§IV-B1: federated == centralized objective check"),
    ("timing", "Figs 6/14/18/23/24: comp vs comm per node"),
    ("vectorized", "§IV-B3 + Figs 7-8: N-histogram vectorization"),
    ("async-study", "Fig 9/21/22: async non-determinism traces"),
    ("stepsize", "Table I + Figs 10-12: damping step size sweep"),
    ("robustness", "Tables II-IV + Fig 13: convergence robustness grids"),
    ("delays", "Figs 15-17 + Table V: staleness (tau) study"),
    ("perf-grid", "Tables VII-XXXVI (+ VI): performance grids"),
    ("local-iters", "App A, Figs 26-28: local iterations w"),
    ("finance", "§V + Fig 25: Blanchet-Murthy worst-case loss"),
    ("info", "print artifact manifest / environment info"),
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        std::process::exit(2);
    };
    let rest = rest.to_vec();
    let code = match dispatch(cmd, &rest) {
        Ok(()) => 0,
        Err(e) => match e.downcast_ref::<CliError>() {
            Some(CliError::Help(u)) => {
                println!("{u}");
                0
            }
            _ => {
                eprintln!("error: {e:#}");
                1
            }
        },
    };
    std::process::exit(code);
}

fn print_usage() {
    println!("usage: fedsink <command> [flags]\n\ncommands:");
    for (name, help) in COMMANDS {
        println!("  {name:<16} {help}");
    }
    println!(
        "\nglobal env: FEDSINK_SCALE=quick|default|paper, FEDSINK_ARTIFACTS=<dir>, \
         FEDSINK_DOMAIN=linear|log|auto, FEDSINK_CONFIG=<file>, \
         FEDSINK_THREADS=<worker-pool size>, \
         FEDSINK_PAR_MIN_WORK=<per-band work floor before kernels fan out>"
    );
}

fn dispatch(cmd: &str, rest: &[String]) -> anyhow::Result<()> {
    match cmd {
        "solve" => cmd_solve(rest),
        "serve" => cmd_serve(rest),
        "epsilon-study" => cmd_epsilon(rest),
        "coherence" => cmd_coherence(rest),
        "timing" => cmd_timing(rest),
        "vectorized" => cmd_vectorized(rest),
        "async-study" => cmd_async_study(rest),
        "stepsize" => cmd_stepsize(rest),
        "robustness" => cmd_robustness(rest),
        "delays" => cmd_delays(rest),
        "perf-grid" => cmd_perf_grid(rest),
        "local-iters" => cmd_local_iters(rest),
        "finance" => cmd_finance(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command {other:?}")
        }
    }
}

// ---------------------------------------------------------------------------
// Shared flag groups
// ---------------------------------------------------------------------------

fn common_spec(spec: ArgSpec) -> ArgSpec {
    spec.opt("scale", "S", "env", "quick|default|paper (default: FEDSINK_SCALE or default)")
        .opt("backend", "B", "xla", "xla|native")
        .opt("net", "PROFILE", "lan", "zero|lan|wan latency profile")
        .opt_req("out", "PATH", "write the JSON result document here")
        .opt("seed", "U64", "42", "experiment seed")
        .opt(
            "threads",
            "N",
            "env",
            "worker-pool size: resident compute threads shared by every node \
             (default: FEDSINK_THREADS or all cores)",
        )
}

/// Resolve `--threads` and size the persistent worker pool before any
/// solve dispatches kernels (the pool is process-global; first sizing
/// wins). Returns the effective count.
fn threads_of(p: &Parsed) -> anyhow::Result<usize> {
    match p.get("threads") {
        Some("env") | None => {}
        Some(s) => {
            let n: usize = s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --threads (expected a positive integer)"))?;
            anyhow::ensure!(n >= 1, "--threads must be >= 1");
            fedsink::config::init_compute_threads(n);
        }
    }
    let n = fedsink::config::compute_threads_from_settings();
    fedsink::runtime::Pool::init_global(n);
    Ok(n)
}

fn scale_of(p: &Parsed) -> Scale {
    match p.get("scale") {
        Some("env") | None => Scale::from_env(),
        Some(s) => Scale::parse(s).unwrap_or_else(|| {
            eprintln!("unknown scale {s:?}, using default");
            Scale::Default
        }),
    }
}

fn backend_of(p: &Parsed) -> anyhow::Result<BackendKind> {
    BackendKind::parse(p.get("backend").unwrap_or("xla"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend"))
}

fn net_of(p: &Parsed) -> anyhow::Result<LatencyModel> {
    LatencyModel::parse(p.get("net").unwrap_or("lan"))
        .ok_or_else(|| anyhow::anyhow!("bad --net"))
}

/// The `--wire-format` / `--stream-exchange` flag pair shared by the
/// solve/timing/perf-grid commands.
fn wire_spec(spec: ArgSpec) -> ArgSpec {
    spec.opt(
        "wire-format",
        "W",
        "f64",
        "f64|f32|deltaf32 wire codec for scaling/chunk/Gref streams (lossy \
         formats ~halve the beta term; error-feedback keeps the loss bounded)",
    )
    .switch(
        "stream-exchange",
        "fold peer scaling slices into the block product as their frames \
         arrive (sync protocols) instead of waiting out the gather barrier",
    )
    .opt(
        "wire-keyframe-every",
        "K",
        "0",
        "force a full DeltaF32 keyframe every K encoded rounds per stream, \
         bounding reconstruction drift (0 = key only on stream (re)priming)",
    )
}

fn wire_of(p: &Parsed) -> anyhow::Result<WireFormat> {
    WireFormat::parse(p.get("wire-format").unwrap_or("f64"))
        .ok_or_else(|| anyhow::anyhow!("bad --wire-format (expected f64|f32|deltaf32)"))
}

/// The greedy-exchange flag trio (`--exchange` / `--greedy-topk` /
/// `--srtt-staleness`) shared by the solve and perf-grid commands.
fn exchange_spec(spec: ArgSpec) -> ArgSpec {
    spec.opt(
        "exchange",
        "MODE",
        "full",
        "full|greedy: dense slice exchange every round, or top-k violation \
         rows shipped as sparse index+value frames (Greenkhorn-style)",
    )
    .opt(
        "greedy-topk",
        "K",
        "0.5",
        "greedy row budget per half-iteration: an integer row count, or a \
         fraction in (0,1) = share of the violation mass to cover",
    )
    .switch(
        "srtt-staleness",
        "scale async staleness bounds by the measured link SRTT (needs an \
         active fault plan to prime the RTT estimator; no-op otherwise)",
    )
}

fn exchange_of(p: &Parsed) -> anyhow::Result<ExchangeMode> {
    ExchangeMode::parse(p.get("exchange").unwrap_or("full"))
        .ok_or_else(|| anyhow::anyhow!("bad --exchange (expected full|greedy)"))
}

/// Chaos flag group (solve/robustness): a deterministic fault plan plus
/// the recovery policy that answers it. All probabilities apply to every
/// link; crash/straggler injections target one node.
fn fault_spec(spec: ArgSpec) -> ArgSpec {
    spec.opt("drop-prob", "P", "0", "per-attempt frame drop probability on every link")
        .opt("dup-prob", "P", "0", "per-frame duplicate-delivery probability")
        .opt("reorder-prob", "P", "0", "per-frame reorder probability")
        .opt("fault-spike-prob", "P", "0", "fault-layer delay-spike probability")
        .opt("fault-spike-mult", "M", "8", "delay multiplier when a spike fires")
        .opt(
            "crash-at",
            "NODE:ITER",
            "",
            "crash injection: NODE exits silently at local iteration ITER \
             (bare ITER = node 0; star servers are node C)",
        )
        .opt("straggler", "NODE:MULT", "", "multiply every send delay of NODE by MULT")
        .opt("fault-seed", "U64", "7", "fault-schedule seed (independent of --seed)")
        .opt(
            "recv-timeout",
            "SECS",
            "0.5",
            "per-attempt receive timeout once the fault plan is active",
        )
        .opt("strikes", "R", "4", "consecutive timeouts before a peer is declared dead")
        .opt(
            "on-node-loss",
            "MODE",
            "abort",
            "abort|exclude: stop with a structured partial outcome, or freeze \
             the dead node's slice and continue degraded (sync protocols)",
        )
}

fn faults_of(p: &Parsed) -> anyhow::Result<fedsink::net::FaultPlan> {
    let mut plan = fedsink::net::FaultPlan::none();
    plan.seed = p.get_u64("fault-seed")?;
    plan.default_link.drop_prob = p.get_f64("drop-prob")?;
    plan.default_link.dup_prob = p.get_f64("dup-prob")?;
    plan.default_link.reorder_prob = p.get_f64("reorder-prob")?;
    plan.default_link.delay_spike =
        (p.get_f64("fault-spike-prob")?, p.get_f64("fault-spike-mult")?);
    for prob in [
        plan.default_link.drop_prob,
        plan.default_link.dup_prob,
        plan.default_link.reorder_prob,
        plan.default_link.delay_spike.0,
    ] {
        anyhow::ensure!((0.0..=1.0).contains(&prob), "fault probabilities must be in [0, 1]");
    }
    if let Some(s) = p.get("crash-at") {
        if !s.is_empty() {
            let (node, iter) = match s.split_once(':') {
                Some((n, i)) => (
                    n.parse()
                        .map_err(|_| anyhow::anyhow!("bad --crash-at node (expected NODE:ITER)"))?,
                    i.parse()
                        .map_err(|_| anyhow::anyhow!("bad --crash-at iter (expected NODE:ITER)"))?,
                ),
                None => (
                    0usize,
                    s.parse()
                        .map_err(|_| anyhow::anyhow!("bad --crash-at (expected ITER or NODE:ITER)"))?,
                ),
            };
            plan.nodes.entry(node).or_default().crash_at_iter = Some(iter);
        }
    }
    if let Some(s) = p.get("straggler") {
        if !s.is_empty() {
            let (node, mult) = s
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad --straggler (expected NODE:MULT)"))?;
            let node: usize = node
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --straggler node (expected NODE:MULT)"))?;
            let mult: f64 = mult
                .parse()
                .map_err(|_| anyhow::anyhow!("bad --straggler mult (expected NODE:MULT)"))?;
            anyhow::ensure!(mult >= 1.0, "--straggler multiplier must be >= 1");
            plan.nodes.entry(node).or_default().straggler_mult = mult;
        }
    }
    Ok(plan)
}

fn recovery_of(p: &Parsed) -> anyhow::Result<fedsink::net::Recovery> {
    let on_node_loss = fedsink::net::NodeLoss::parse(p.get("on-node-loss").unwrap_or("abort"))
        .ok_or_else(|| anyhow::anyhow!("bad --on-node-loss (expected abort|exclude)"))?;
    let recv_timeout_secs = p.get_f64("recv-timeout")?;
    anyhow::ensure!(recv_timeout_secs > 0.0, "--recv-timeout must be positive");
    let strikes = p.get_u64("strikes")? as u32;
    anyhow::ensure!(strikes >= 1, "--strikes must be >= 1");
    Ok(fedsink::net::Recovery { recv_timeout_secs, strikes, on_node_loss })
}

fn domain_of(p: &Parsed) -> anyhow::Result<DomainChoice> {
    match p.get("domain") {
        // `env` defers to FEDSINK_DOMAIN / the FEDSINK_CONFIG file
        // (falling back to auto), mirroring the --scale convention.
        Some("env") | None => Ok(fedsink::config::domain_choice_from_settings()),
        Some(s) => DomainChoice::parse(s)
            .ok_or_else(|| anyhow::anyhow!("bad --domain (expected linear|log|auto)")),
    }
}

/// Stabilized log-path tuning from `--truncation-threshold` /
/// `--absorb-threshold` / `--fleet-absorb` (defaults =
/// `Stabilization::default()`).
fn stab_of(p: &Parsed) -> anyhow::Result<fedsink::linalg::Stabilization> {
    let mut stab = fedsink::linalg::Stabilization::default();
    if p.get("truncation-threshold").is_some() {
        stab.truncation_theta = p.get_f64("truncation-threshold")?;
        anyhow::ensure!(
            stab.truncation_theta < 0.0,
            "--truncation-threshold is a log-space cutoff and must be negative"
        );
    }
    if p.get("absorb-threshold").is_some() {
        stab.absorb_threshold = p.get_f64("absorb-threshold")?;
        anyhow::ensure!(
            stab.absorb_threshold > 0.0,
            "--absorb-threshold must be positive (use `inf` to disable the hybrid)"
        );
    }
    stab.fleet_absorb = p.has("fleet-absorb");
    if stab.fleet_absorb {
        anyhow::ensure!(
            stab.hybrid_enabled(),
            "--fleet-absorb synchronizes the absorption-hybrid schedule; \
             it needs a finite --absorb-threshold"
        );
    }
    Ok(stab)
}

/// The AOT artifact grid only lowers linear-domain updates; reject the
/// impossible combination up front instead of panicking deep in
/// `runtime/` mid-solve. (`auto` is allowed — it degrades to linear with
/// a warning when the backend lacks a log operator.)
fn check_domain_backend(domain: DomainChoice, backend: BackendKind) -> anyhow::Result<()> {
    if domain == DomainChoice::Log && backend == BackendKind::Xla {
        anyhow::bail!(
            "--domain log is not available on the xla backend (the AOT artifact \
             grid has no log-domain lowering); use --backend native"
        );
    }
    Ok(())
}

/// Greedy exchange leans on the native operators' incremental
/// `greedy_update` path; the XLA artifacts only lower full-slice
/// updates. Reject the combination before any threads spawn.
fn check_exchange_backend(exchange: ExchangeMode, backend: BackendKind) -> anyhow::Result<()> {
    if exchange == ExchangeMode::Greedy && backend == BackendKind::Xla {
        anyhow::bail!(
            "--exchange greedy needs the native backend's incremental operators \
             (the AOT artifact grid has no top-k lowering); use --backend native"
        );
    }
    Ok(())
}

fn out_of(p: &Parsed) -> Option<String> {
    p.get("out").map(|s| s.to_string())
}

// ---------------------------------------------------------------------------
// Commands
// ---------------------------------------------------------------------------

fn cmd_solve(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new()
            .opt(
                "variant",
                "V",
                "sync-a2a",
                "centralized|sync-a2a|async-a2a|sync-star|async-star|ring|gossip",
            )
            .opt(
                "coordinator",
                "TOPO",
                "",
                "alias for --variant by topology name (e.g. --coordinator ring|gossip); \
                 overrides --variant when set",
            )
            .opt("n", "SIZE", "256", "problem size")
            .opt("clients", "C", "4", "number of clients")
            .opt("hists", "N", "1", "target histograms")
            .opt("eps", "EPS", "0.05", "entropic regularization")
            .opt("alpha", "A", "1.0", "damping step size")
            .opt("local-iters", "W", "1", "local iterations per exchange")
            .opt("threshold", "T", "1e-10", "marginal-error threshold")
            .opt("max-iters", "K", "1500", "iteration cap")
            .opt("sparsity", "S", "0.0", "off-diagonal block sparsity")
            .opt("cond", "CLASS", "well", "well|medium|ill")
            .opt(
                "domain",
                "D",
                "env",
                "linear|log|auto numerics domain (default: FEDSINK_DOMAIN or auto; \
                 auto: log iff exp(-C/eps) underflows)",
            )
            .opt(
                "truncation-threshold",
                "TH",
                "-60",
                "log-space sparse truncation threshold theta (< 0)",
            )
            .opt(
                "absorb-threshold",
                "TAU",
                "15",
                "log-scaling drift before the hybrid re-absorbs the kernel (> 0, inf = off)",
            )
            .switch(
                "fleet-absorb",
                "fleet-synchronized absorption: the coordinator broadcasts one \
                 reference dual and every node re-absorbs in lock-step",
            ),
    );
    let spec = fault_spec(exchange_spec(wire_spec(spec)));
    let p = spec.parse("solve", args).map_err(anyhow::Error::new)?;
    let threads = threads_of(&p)?;
    let variant = match p.get("coordinator").filter(|s| !s.is_empty()) {
        Some(s) => Variant::parse(s).ok_or_else(|| anyhow::anyhow!("bad --coordinator"))?,
        None => Variant::parse(p.get("variant").unwrap())
            .ok_or_else(|| anyhow::anyhow!("bad --variant"))?,
    };
    let domain = domain_of(&p)?;
    let backend = backend_of(&p)?;
    check_domain_backend(domain, backend)?;
    let exchange = exchange_of(&p)?;
    check_exchange_backend(exchange, backend)?;
    let cond = CondClass::parse(p.get("cond").unwrap())
        .ok_or_else(|| anyhow::anyhow!("bad --cond"))?;
    let n = p.get_usize("n")?;
    let clients = p.get_usize("clients")?;
    let problem = experiments::build_problem(
        n,
        p.get_usize("hists")?,
        p.get_f64("eps")?,
        p.get_f64("sparsity")?,
        clients.max(2),
        cond,
        p.get_u64("seed")?,
    );
    let cfg = SolveConfig {
        variant,
        backend,
        domain,
        stab: stab_of(&p)?,
        clients,
        alpha: p.get_f64("alpha")?,
        local_iters: p.get_usize("local-iters")?,
        net: net_of(&p)?,
        seed: p.get_u64("seed")?,
        wire: wire_of(&p)?,
        stream_exchange: p.has("stream-exchange"),
        wire_keyframe_every: p.get_usize("wire-keyframe-every")?,
        compute_threads: threads,
        faults: faults_of(&p)?,
        recovery: recovery_of(&p)?,
        exchange,
        greedy_topk: GreedySpec::parse(p.get("greedy-topk").unwrap_or("0.5"))?,
        srtt_staleness: p.has("srtt-staleness"),
        ..Default::default()
    };
    if cfg.stab.fleet_absorb {
        // The fleet protocol synchronizes the log-domain hybrid; don't
        // let a linear-domain run silently benchmark the baseline.
        use fedsink::linalg::Domain;
        if domain == DomainChoice::Linear {
            anyhow::bail!(
                "--fleet-absorb synchronizes the log-domain absorption-hybrid \
                 and has no effect with --domain linear"
            );
        }
        if domain.resolve(&problem) == Domain::Linear {
            eprintln!(
                "warning: --fleet-absorb is a no-op here — the auto-resolved \
                 domain for this problem is linear (the absorption-hybrid only \
                 runs in the log domain; use --domain log or a smaller --eps)"
            );
        }
    }
    if cfg.stream_exchange && cfg.stab.fleet_absorb {
        // RunCtx::stream_on() silently defers to the fleet protocol
        // (streamed folds can't replay a mid-product retruncation).
        eprintln!(
            "warning: --stream-exchange is deferred under --fleet-absorb — \
             fleet-synchronized runs exchange on the gather barrier"
        );
    }
    let policy = StopPolicy {
        threshold: p.get_f64("threshold")?,
        max_iters: p.get_usize("max-iters")?,
        ..Default::default()
    };
    let out = fedsink::coordinator::run_federated(&problem, &cfg, policy, false);
    println!(
        "{} [{} domain]: n={n} c={clients} -> stop={:?} iters={} err={:.3e} in {:.3}s",
        variant.name(),
        out.state.domain.name(),
        out.stop,
        out.iterations,
        out.node_stats.first().map(|s| s.final_err).unwrap_or(f64::NAN),
        out.secs
    );
    if let Some(st) = &out.stab {
        println!(
            "  hybrid: {} updates, {} absorbs ({} full rebuilds) -> {:.1}% linear iterations",
            st.updates,
            st.absorbs,
            st.rebuilds,
            100.0 * st.linear_fraction()
        );
        if st.absorb_triggers.len() > 1 {
            let triggers: Vec<String> =
                st.absorb_triggers.iter().map(|t| t.to_string()).collect();
            println!("  per-histogram absorb triggers: [{}]", triggers.join(", "));
        }
        if st.fleet_commands > 0 {
            println!(
                "  fleet: {} coordinator commands ({} fleet-driven rebuilds, {} emergency)",
                st.fleet_commands,
                st.fleet_rebuilds,
                st.rebuilds - st.fleet_rebuilds
            );
        }
    }
    if let Some(g) = &out.greedy {
        println!(
            "  greedy: {} updates, {:.1}% of rows selected covering {:.1}% of violation mass",
            g.calls,
            100.0 * g.row_fraction(),
            100.0 * g.mass_fraction()
        );
    }
    for s in &out.node_stats {
        println!(
            "  node {:>2} ({:<7}) comp={:.3}s comm={:.3}s iters={}",
            s.id,
            s.role,
            s.comp_secs(),
            s.comm_secs(),
            s.iterations
        );
    }
    if out.traffic.total_msgs > 0 {
        let per: Vec<String> = out
            .traffic
            .by_kind
            .iter()
            .filter(|&&(_, bytes, _)| bytes > 0)
            .map(|&(name, bytes, msgs)| format!("{name}={bytes}B/{msgs}msg"))
            .collect();
        println!(
            "  wire[{}{}]: {} bytes total ({})",
            cfg.wire.name(),
            if cfg.stream_exchange { ", streamed" } else { "" },
            out.traffic.total_bytes,
            per.join(", ")
        );
    }
    let t = &out.traffic;
    if t.drops + t.dups + t.reorders + t.retransmits + t.spikes > 0 {
        println!(
            "  faults: drops={} dups={} reorders={} retransmits={} spikes={}",
            t.drops, t.dups, t.reorders, t.retransmits, t.spikes
        );
    }
    if out.degraded {
        println!(
            "  degraded: lost nodes {:?} ({} of {} survived)",
            out.lost_nodes,
            out.node_stats.len() - out.lost_nodes.len(),
            out.node_stats.len()
        );
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new()
        .opt("n", "SIZE", "192", "shared cost-geometry size")
        .opt("eps", "EPS", "0.005", "entropic regularization of the request stream")
        .opt("cond", "CLASS", "ill", "well|medium|ill cost conditioning")
        .opt("requests", "R", "64", "synthetic requests to serve")
        .opt("tenants", "T", "8", "tenant (base-histogram) count")
        .opt("perturb", "P", "1.0", "log-space per-request histogram perturbation scale")
        .opt(
            "arrival-rate",
            "L",
            "0",
            "open-loop Poisson arrivals per virtual second (0 = one burst at t=0)",
        )
        .opt("threshold", "E", "1e-9", "base per-request marginal tolerance")
        .opt(
            "tolerance-jitter",
            "J",
            "1.0",
            "per-request tolerance jitter in decades (drives per-column stopping)",
        )
        .opt("max-batch", "W", "32", "max histograms coalesced into one batched solve")
        .opt(
            "drift-margin",
            "M",
            "0.5",
            "fraction of the absorb threshold a member's predicted dual drift \
             may consume before admission opens a new batch",
        )
        .opt("alpha", "A", "1.0", "damping step size")
        .opt("max-iters", "K", "6000", "per-batch iteration cap")
        .opt(
            "domain",
            "D",
            "env",
            "linear|log|auto numerics domain (default: FEDSINK_DOMAIN or auto)",
        )
        .opt(
            "truncation-threshold",
            "TH",
            "-60",
            "log-space sparse truncation threshold theta (< 0)",
        )
        .opt(
            "absorb-threshold",
            "TAU",
            "15",
            "log-scaling drift before the hybrid re-absorbs the kernel (> 0, inf = off)",
        )
        .opt("seed", "U64", "42", "geometry + workload seed")
        .opt("threads", "N", "env", "worker-pool size (default: FEDSINK_THREADS or all cores)")
        .opt_req("out", "PATH", "write the BENCH_service.json report here")
        .switch(
            "compare-standalone",
            "also solve every request standalone at its own tolerance and \
             report the rebuild/iteration amortization of batching",
        );
    let p = spec.parse("serve", args).map_err(anyhow::Error::new)?;
    use fedsink::service::{run_service, synth_requests, ServiceConfig, WorkloadSpec};
    let threads = threads_of(&p)?;
    let n = p.get_usize("n")?;
    let eps = p.get_f64("eps")?;
    let cond = CondClass::parse(p.get("cond").unwrap())
        .ok_or_else(|| anyhow::anyhow!("bad --cond"))?;
    let seed = p.get_u64("seed")?;
    let geometry = experiments::build_problem(n, 1, eps, 0.0, 2, cond, seed);
    let domain = domain_of(&p)?.resolve(&geometry);
    let wl = WorkloadSpec {
        requests: p.get_usize("requests")?,
        tenants: p.get_usize("tenants")?,
        perturb: p.get_f64("perturb")?,
        arrival_rate: p.get_f64("arrival-rate")?,
        threshold: p.get_f64("threshold")?,
        tolerance_jitter: p.get_f64("tolerance-jitter")?,
        seed,
    };
    anyhow::ensure!(wl.requests >= 1, "--requests must be >= 1");
    let mut requests = synth_requests(n, &wl);
    for r in &mut requests {
        r.eps = eps;
    }
    let cfg = ServiceConfig {
        alpha: p.get_f64("alpha")?,
        max_iters: p.get_usize("max-iters")?,
        max_batch: p.get_usize("max-batch")?,
        drift_margin: p.get_f64("drift-margin")?,
        stab: stab_of(&p)?,
        domain,
        compare_standalone: p.has("compare-standalone"),
    };
    anyhow::ensure!(cfg.max_batch >= 1, "--max-batch must be >= 1");
    let backend = fedsink::runtime::make_backend(BackendKind::Native, "", threads)?;
    let rep = run_service(backend, &geometry, &requests, &cfg);
    println!(
        "serve [{} domain]: n={n} eps={eps} requests={} tenants={} -> \
         batches={} splits={} occupancy={:.2}",
        domain.name(),
        rep.requests.len(),
        wl.tenants,
        rep.batches.len(),
        rep.splits,
        rep.occupancy_mean
    );
    println!(
        "  latency: p50={:.4}s p90={:.4}s p99={:.4}s throughput={:.2} req/s makespan={:.3}s",
        rep.latency_p50, rep.latency_p90, rep.latency_p99, rep.throughput_rps, rep.makespan_secs
    );
    println!(
        "  batched: unconverged={} early_frozen={} compactions={} rebuilds={} absorbs={}",
        rep.unconverged(),
        rep.early_frozen(),
        rep.batches.iter().map(|b| b.compactions).sum::<usize>(),
        rep.rebuilds(),
        rep.absorbs()
    );
    if let Some(s) = rep.standalone {
        println!(
            "  standalone: solves={} iterations={} rebuilds={} absorbs={} unconverged={} \
             (batched amortization: {} rebuilds vs {} standalone)",
            s.solves,
            s.iterations,
            s.rebuilds,
            s.absorbs,
            s.unconverged,
            rep.rebuilds(),
            s.rebuilds
        );
    }
    if let Some(path) = out_of(&p) {
        experiments::dump_json(&path, &rep.to_json())?;
    }
    Ok(())
}

fn cmd_epsilon(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new()
            .opt(
                "epsilons",
                "LIST",
                "5e-1,1e-1,5e-2,2e-2,1e-2,1e-3",
                "comma list of epsilon values",
            )
            .opt("max-iters", "K", "2000000", "iteration cap")
            .opt(
                "domain",
                "D",
                "linear",
                "numerics domain for the main sweep (linear reproduces the f64 collapse)",
            )
            .opt(
                "small-epsilons",
                "LIST",
                "1e-3,5e-4,1e-4",
                "log-domain extension sweep the linear path cannot complete (empty = skip)",
            ),
    );
    let p = spec.parse("epsilon-study", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    // This study always runs on the native backend, so no backend/domain
    // compatibility check is needed here.
    let domain = domain_of(&p)?;
    let small = match p.get("small-epsilons") {
        Some("") | None => Vec::new(),
        Some(_) => p.get_list("small-epsilons", |s| s.parse().ok())?,
    };
    let a = experiments::epsilon::EpsilonArgs {
        epsilons: p.get_list("epsilons", |s| s.parse().ok())?,
        small_epsilons: small,
        domain,
        max_iters: p.get_usize("max-iters")?,
        out: out_of(&p),
    };
    experiments::epsilon::run(&a)?;
    Ok(())
}

fn cmd_coherence(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(ArgSpec::new().opt("n", "SIZE", "256", "problem size"));
    let p = spec.parse("coherence", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let a = experiments::coherence::CoherenceArgs {
        n: p.get_usize("n")?,
        eps: 0.05,
        backend: backend_of(&p)?,
        out: out_of(&p),
    };
    experiments::coherence::run(&a)?;
    Ok(())
}

fn cmd_timing(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(wire_spec(
        ArgSpec::new()
            .opt("variant", "V", "sync-a2a", "federated variant/topology for c > 1 (incl. ring|gossip)")
            .opt("n", "SIZE", "0", "problem size (0 = scale default)")
            .opt("iters", "K", "0", "fixed iteration budget (0 = scale default)")
            .opt("nodes", "LIST", "", "node counts (empty = scale default)"),
    ));
    let p = spec.parse("timing", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::timing::TimingArgs::at_scale(scale_of(&p));
    a.variant = Variant::parse(p.get("variant").unwrap())
        .ok_or_else(|| anyhow::anyhow!("bad --variant"))?;
    a.backend = backend_of(&p)?;
    a.net = net_of(&p)?;
    a.out = out_of(&p);
    a.wire = wire_of(&p)?;
    a.stream_exchange = p.has("stream-exchange");
    a.wire_keyframe_every = p.get_usize("wire-keyframe-every")?;
    if p.get_usize("n")? > 0 {
        a.n = p.get_usize("n")?;
    }
    if p.get_usize("iters")? > 0 {
        a.iters = p.get_usize("iters")?;
    }
    if p.get("nodes").map(|s| !s.is_empty()).unwrap_or(false) {
        a.nodes = p.get_list("nodes", |s| s.parse().ok())?;
    }
    experiments::timing::run(&a)?;
    Ok(())
}

fn cmd_vectorized(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new().switch("serial-compare", "also run the §IV-B3 serial-vs-vectorized probe"),
    );
    let p = spec.parse("vectorized", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::vectorized::VectorizedArgs::at_scale(scale_of(&p));
    a.backend = backend_of(&p)?;
    a.net = net_of(&p)?;
    a.out = out_of(&p);
    if !p.has("serial-compare") {
        a.serial_compare = None;
    }
    experiments::vectorized::run(&a)?;
    Ok(())
}

fn cmd_async_study(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new()
            .opt("runs", "R", "0", "number of repeated runs (0 = scale default)")
            .opt("clients", "C", "2", "clients")
            .opt("alpha", "A", "1.0", "damping step size"),
    );
    let p = spec.parse("async-study", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::async_study::AsyncStudyArgs::at_scale(scale_of(&p));
    a.backend = backend_of(&p)?;
    a.net = net_of(&p)?;
    a.out = out_of(&p);
    a.clients = p.get_usize("clients")?;
    a.alpha = p.get_f64("alpha")?;
    if p.get_usize("runs")? > 0 {
        a.runs = p.get_usize("runs")?;
    }
    experiments::async_study::run(&a)?;
    Ok(())
}

fn cmd_stepsize(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new().opt("alphas", "LIST", "0.1,0.25,0.5", "damping values to sweep"),
    );
    let p = spec.parse("stepsize", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::stepsize::StepsizeArgs::at_scale(scale_of(&p));
    a.alphas = p.get_list("alphas", |s| s.parse().ok())?;
    a.backend = backend_of(&p)?;
    a.out = out_of(&p);
    experiments::stepsize::run(&a)?;
    Ok(())
}

fn cmd_robustness(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(fault_spec(
        ArgSpec::new()
            .switch("sweep-alpha", "add the Fig 13 alpha sweep")
            .opt("runs", "R", "0", "runs per grid cell (0 = scale default)"),
    ));
    let p = spec.parse("robustness", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::robustness::RobustnessArgs::at_scale(scale_of(&p));
    a.backend = backend_of(&p)?;
    a.out = out_of(&p);
    a.faults = faults_of(&p)?;
    a.recovery = recovery_of(&p)?;
    if p.get_usize("runs")? > 0 {
        a.runs = p.get_usize("runs")?;
    }
    if p.has("sweep-alpha") {
        a.sweep_alpha = Some(vec![0.001, 0.005, 0.05, 0.2, 0.35, 0.5]);
    }
    experiments::robustness::run(&a)?;
    Ok(())
}

fn cmd_delays(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new()
            .opt("sims", "S", "0", "simulations per node count (0 = scale default)")
            .opt("iters", "T", "500", "fixed iterations per simulation"),
    );
    let p = spec.parse("delays", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::delays::DelaysArgs::at_scale(scale_of(&p));
    a.backend = backend_of(&p)?;
    a.net = net_of(&p)?;
    a.out = out_of(&p);
    a.iters = p.get_usize("iters")?;
    if p.get_usize("sims")? > 0 {
        a.sims = p.get_usize("sims")?;
    }
    experiments::delays::run(&a)?;
    Ok(())
}

fn cmd_perf_grid(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(exchange_spec(wire_spec(
        ArgSpec::new()
            .opt("variant", "V", "all", "all or one of the solver variants (incl. ring|gossip)")
            .opt("sizes", "LIST", "", "problem sizes (empty = scale default)")
            .opt("hists", "LIST", "", "histogram counts (empty = scale default)")
            .opt("nodes", "LIST", "", "node counts (empty = scale default)")
            .switch("chi2", "add the Table VI chi-square analysis")
            .switch(
                "fleet-compare",
                "add the per-node vs fleet-synchronized absorption rebuild comparison",
            ),
    )));
    let p = spec.parse("perf-grid", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::perf_grid::PerfGridArgs::at_scale(scale_of(&p));
    a.backend = backend_of(&p)?;
    a.net = net_of(&p)?;
    a.out = out_of(&p);
    a.chi2 = p.has("chi2");
    a.fleet_compare = p.has("fleet-compare");
    a.wire = wire_of(&p)?;
    a.stream_exchange = p.has("stream-exchange");
    a.wire_keyframe_every = p.get_usize("wire-keyframe-every")?;
    a.exchange = exchange_of(&p)?;
    a.greedy_topk = GreedySpec::parse(p.get("greedy-topk").unwrap_or("0.5"))?;
    check_exchange_backend(a.exchange, a.backend)?;
    for (flag, field) in [("sizes", 0usize), ("hists", 1), ("nodes", 2)] {
        if p.get(flag).map(|s| !s.is_empty()).unwrap_or(false) {
            let v: Vec<usize> = p.get_list(flag, |s| s.parse().ok())?;
            match field {
                0 => a.sizes = v,
                1 => a.hists = v,
                _ => a.nodes = v,
            }
        }
    }
    if let Some(v) = p.get("variant") {
        if v != "all" {
            a.variants =
                vec![Variant::parse(v).ok_or_else(|| anyhow::anyhow!("bad --variant"))?];
        }
    }
    experiments::perf_grid::run(&a)?;
    Ok(())
}

fn cmd_local_iters(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new().opt("ws", "LIST", "1,2,4,8", "local-iteration counts to compare"),
    );
    let p = spec.parse("local-iters", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let mut a = experiments::local_iters::LocalItersArgs::at_scale(scale_of(&p));
    a.ws = p.get_list("ws", |s| s.parse().ok())?;
    a.backend = backend_of(&p)?;
    a.out = out_of(&p);
    experiments::local_iters::run(&a)?;
    Ok(())
}

fn cmd_finance(args: &[String]) -> anyhow::Result<()> {
    let spec = common_spec(
        ArgSpec::new()
            .switch("paper-example", "reproduce the §V-B4 3-asset example + Fig 25")
            .opt("scenarios", "S", "64", "synthetic scenario count")
            .opt("assets", "A", "12", "synthetic asset count")
            .opt("clients", "C", "4", "clients for the synthetic run"),
    );
    let p = spec.parse("finance", args).map_err(anyhow::Error::new)?;
    threads_of(&p)?;
    let a = experiments::finance_exp::FinanceArgs {
        paper_example: p.has("paper-example"),
        scenarios: p.get_usize("scenarios")?,
        assets: p.get_usize("assets")?,
        clients: p.get_usize("clients")?,
        backend: backend_of(&p)?,
        out: out_of(&p),
    };
    experiments::finance_exp::run(&a)?;
    Ok(())
}

fn cmd_info(args: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new();
    let _ = spec.parse("info", args).map_err(anyhow::Error::new)?;
    let dir = fedsink::config::default_artifacts_dir();
    println!("artifacts dir: {dir}");
    match fedsink::runtime::Manifest::load(&dir) {
        Ok(man) => {
            println!("manifest grid: {} ({} entries)", man.grid, man.entries.len());
            let mut by_op: std::collections::BTreeMap<&str, usize> = Default::default();
            for e in &man.entries {
                *by_op.entry(e.op.as_str()).or_default() += 1;
            }
            for (op, count) in by_op {
                println!("  {op:<22} {count}");
            }
        }
        Err(e) => println!("manifest: unavailable ({e:#}); run `make artifacts`"),
    }
    println!("scale: {:?} (FEDSINK_SCALE)", Scale::from_env());
    Ok(())
}
