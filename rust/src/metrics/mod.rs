//! Timing, statistics and experiment-record substrate.
//!
//! The paper's evaluation splits every run into **computation time** vs
//! **communication time** per node (Figs 6, 8, 14, 18, 23, 24; every
//! appendix table). [`SplitTimer`] accumulates those two buckets without
//! allocation in the hot loop; [`Summary`] provides the mean/std/median
//! reductions; [`Histogram`] provides the KDE-style binned densities of
//! the delay study (Figs 16–17); and chi-square machinery backs Table VI.

mod stats;
mod timer;

pub use stats::{chi2_sf, chi2_stat, percentile, Histogram, Summary};
pub use timer::{Clock, SplitTimer};

use crate::jsonio::Json;

/// Outcome of one solver run — the row unit of every appendix table.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub variant: String,
    /// Exchange-graph family of the variant (`none`, `a2a`, `star`,
    /// `ring`, `gossip`) — the column the perf/robustness grids group
    /// per-topology comm terms by.
    pub topology: String,
    pub n: usize,
    pub clients: usize,
    pub hists: usize,
    pub sparsity: f64,
    pub cond: String,
    pub iterations: usize,
    pub converged: bool,
    pub comp_secs: f64,
    pub comm_secs: f64,
    pub total_secs: f64,
    pub final_err: f64,
    /// Total fabric bytes of the run, priced on the *encoded* wire
    /// frames (0 for centralized runs, which have no fabric).
    pub wire_bytes: u64,
    /// Per-kind byte split in `[U, V, Ctl, Gref]` order — the comm
    /// buckets next to the wall-time buckets.
    pub wire_bytes_by_kind: [u64; 4],
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", self.variant.as_str().into()),
            ("topology", self.topology.as_str().into()),
            ("n", self.n.into()),
            ("clients", self.clients.into()),
            ("nhist", self.hists.into()),
            ("sparsity", self.sparsity.into()),
            ("cond", self.cond.as_str().into()),
            ("iterations", self.iterations.into()),
            ("converged", self.converged.into()),
            ("comp_secs", self.comp_secs.into()),
            ("comm_secs", self.comm_secs.into()),
            ("total_secs", self.total_secs.into()),
            ("final_err", self.final_err.into()),
            ("wire_bytes", self.wire_bytes.into()),
            ("bytes_u", self.wire_bytes_by_kind[0].into()),
            ("bytes_v", self.wire_bytes_by_kind[1].into()),
            ("bytes_ctl", self.wire_bytes_by_kind[2].into()),
            ("bytes_gref", self.wire_bytes_by_kind[3].into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_series() {
        let s = Summary::of(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12); // sample std
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn histogram_bins_cover_range() {
        let h = Histogram::of(&[1.0, 2.0, 3.0, 50.0], 5);
        assert_eq!(h.counts.iter().sum::<usize>(), 4);
        assert!(h.density().iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn chi2_uniform_has_small_statistic() {
        // Perfectly matching observed/expected → statistic 0, p = 1.
        let obs = [10.0, 10.0, 10.0];
        let exp = [10.0, 10.0, 10.0];
        let x2 = chi2_stat(&obs, &exp);
        assert_eq!(x2, 0.0);
        assert!((chi2_sf(x2, 2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn chi2_sf_matches_table_values() {
        // χ²(df=1): P(X > 3.841) ≈ 0.05
        assert!((chi2_sf(3.841, 1) - 0.05).abs() < 2e-3);
        // χ²(df=2): P(X > 5.991) ≈ 0.05
        assert!((chi2_sf(5.991, 2) - 0.05).abs() < 2e-3);
        // χ²(df=10): P(X > 18.307) ≈ 0.05
        assert!((chi2_sf(18.307, 10) - 0.05).abs() < 2e-3);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.9), 5.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        // Rank floors at 1: tiny q returns the minimum.
        assert_eq!(percentile(&xs, 0.01), 1.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn split_timer_buckets_accumulate() {
        let mut t = SplitTimer::new();
        t.add_comp(0.5);
        t.add_comm(0.25);
        t.add_comp(0.5);
        assert_eq!(t.comp_secs(), 1.0);
        assert_eq!(t.comm_secs(), 0.25);
        assert_eq!(t.total_secs(), 1.25);
    }
}
