//! Timing, statistics and experiment-record substrate.
//!
//! The paper's evaluation splits every run into **computation time** vs
//! **communication time** per node (Figs 6, 8, 14, 18, 23, 24; every
//! appendix table). [`SplitTimer`] accumulates those two buckets without
//! allocation in the hot loop; [`Summary`] provides the mean/std/median
//! reductions; [`Histogram`] provides the KDE-style binned densities of
//! the delay study (Figs 16–17); and chi-square machinery backs Table VI.

mod stats;
mod timer;

pub use stats::{chi2_sf, chi2_stat, percentile, Histogram, Summary};
pub use timer::{Clock, SplitTimer};

use crate::jsonio::Json;

/// Outcome of one solver run — the row unit of every appendix table.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub variant: String,
    /// Exchange-graph family of the variant (`none`, `a2a`, `star`,
    /// `ring`, `gossip`) — the column the perf/robustness grids group
    /// per-topology comm terms by.
    pub topology: String,
    pub n: usize,
    pub clients: usize,
    pub hists: usize,
    pub sparsity: f64,
    pub cond: String,
    pub iterations: usize,
    pub converged: bool,
    pub comp_secs: f64,
    pub comm_secs: f64,
    pub total_secs: f64,
    pub final_err: f64,
    /// Total fabric bytes of the run, priced on the *encoded* wire
    /// frames (0 for centralized runs, which have no fabric).
    pub wire_bytes: u64,
    /// Per-kind `(name, bytes)` split in the fabric's counter order —
    /// kind-generic, so a new [`crate::net::TagKind`] (e.g. the sparse
    /// greedy frames) shows up here without a schema edit.
    pub wire_bytes_by_kind: Vec<(&'static str, u64)>,
    /// Exchange mode of the run (`full` or `greedy`).
    pub exchange: String,
    /// Fabric bytes per federated iteration — the α–β comm term the
    /// greedy column of the perf grids is judged on (0 when the run
    /// made no iterations or moved no bytes).
    pub wire_bytes_per_iter: f64,
    /// Greedy selection telemetry: fraction of candidate rows selected
    /// and fraction of violation mass those rows covered, when the run
    /// used the greedy schedule.
    pub greedy_row_fraction: Option<f64>,
    pub greedy_mass_fraction: Option<f64>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(String, Json)> = vec![
            ("variant".into(), self.variant.as_str().into()),
            ("topology".into(), self.topology.as_str().into()),
            ("n".into(), self.n.into()),
            ("clients".into(), self.clients.into()),
            ("nhist".into(), self.hists.into()),
            ("sparsity".into(), self.sparsity.into()),
            ("cond".into(), self.cond.as_str().into()),
            ("iterations".into(), self.iterations.into()),
            ("converged".into(), self.converged.into()),
            ("comp_secs".into(), self.comp_secs.into()),
            ("comm_secs".into(), self.comm_secs.into()),
            ("total_secs".into(), self.total_secs.into()),
            ("final_err".into(), self.final_err.into()),
            ("wire_bytes".into(), self.wire_bytes.into()),
            ("exchange".into(), self.exchange.as_str().into()),
            ("wire_bytes_per_iter".into(), self.wire_bytes_per_iter.into()),
        ];
        for &(name, bytes) in &self.wire_bytes_by_kind {
            pairs.push((format!("bytes_{}", name.to_ascii_lowercase()), bytes.into()));
        }
        if let Some(f) = self.greedy_row_fraction {
            pairs.push(("greedy_row_fraction".into(), f.into()));
        }
        if let Some(f) = self.greedy_mass_fraction {
            pairs.push(("greedy_mass_fraction".into(), f.into()));
        }
        Json::Obj(pairs.into_iter().collect())
    }

    /// Bytes sent on one kind by name (0 for an unknown name).
    pub fn bytes_of(&self, name: &str) -> u64 {
        self.wire_bytes_by_kind
            .iter()
            .find(|&&(k, _)| k == name)
            .map(|&(_, b)| b)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_series() {
        let s = Summary::of(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.median, 2.0);
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12); // sample std
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn histogram_bins_cover_range() {
        let h = Histogram::of(&[1.0, 2.0, 3.0, 50.0], 5);
        assert_eq!(h.counts.iter().sum::<usize>(), 4);
        assert!(h.density().iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn chi2_uniform_has_small_statistic() {
        // Perfectly matching observed/expected → statistic 0, p = 1.
        let obs = [10.0, 10.0, 10.0];
        let exp = [10.0, 10.0, 10.0];
        let x2 = chi2_stat(&obs, &exp);
        assert_eq!(x2, 0.0);
        assert!((chi2_sf(x2, 2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn chi2_sf_matches_table_values() {
        // χ²(df=1): P(X > 3.841) ≈ 0.05
        assert!((chi2_sf(3.841, 1) - 0.05).abs() < 2e-3);
        // χ²(df=2): P(X > 5.991) ≈ 0.05
        assert!((chi2_sf(5.991, 2) - 0.05).abs() < 2e-3);
        // χ²(df=10): P(X > 18.307) ≈ 0.05
        assert!((chi2_sf(18.307, 10) - 0.05).abs() < 2e-3);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 0.9), 5.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        // Rank floors at 1: tiny q returns the minimum.
        assert_eq!(percentile(&xs, 0.01), 1.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn split_timer_buckets_accumulate() {
        let mut t = SplitTimer::new();
        t.add_comp(0.5);
        t.add_comm(0.25);
        t.add_comp(0.5);
        assert_eq!(t.comp_secs(), 1.0);
        assert_eq!(t.comm_secs(), 0.25);
        assert_eq!(t.total_secs(), 1.25);
    }
}
