//! Summary statistics, histograms, and the χ² test of Table VI.

/// Mean / sample-std / median / min / max of a series.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            median,
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

/// Nearest-rank percentile: smallest element with at least `q·n` of the
/// series at or below it (`q` in `(0, 1]`). The latency-tail reduction
/// for the serving report — p50/p90/p99 over per-request latencies.
/// Returns NaN on an empty series.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    assert!(q > 0.0 && q <= 1.0, "percentile rank out of (0, 1]");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Equal-width histogram — the discrete stand-in for the paper's KDE
/// plots (Figs 16–17): `density()` normalizes to unit area.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
}

impl Histogram {
    pub fn of(xs: &[f64], bins: usize) -> Histogram {
        assert!(bins > 0);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let (lo, hi) = if xs.is_empty() || lo == hi {
            (lo.min(0.0), lo.min(0.0) + 1.0)
        } else {
            (lo, hi)
        };
        let mut counts = vec![0usize; bins];
        let w = (hi - lo) / bins as f64;
        for &x in xs {
            let idx = (((x - lo) / w) as usize).min(bins - 1);
            counts[idx] += 1;
        }
        Histogram { lo, hi, counts }
    }

    /// Per-bin probability density (integrates to 1).
    pub fn density(&self) -> Vec<f64> {
        let total: usize = self.counts.iter().sum();
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        if total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / (total as f64 * w))
            .collect()
    }

    /// Bin centers (for table/plot output).
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

/// Pearson χ² statistic over observed/expected cell counts.
pub fn chi2_stat(observed: &[f64], expected: &[f64]) -> f64 {
    assert_eq!(observed.len(), expected.len());
    observed
        .iter()
        .zip(expected)
        .filter(|(_, &e)| e > 0.0)
        .map(|(&o, &e)| (o - e) * (o - e) / e)
        .sum()
}

/// Survival function `P(χ²_df > x)` via the regularized upper incomplete
/// gamma `Q(df/2, x/2)` (continued fraction / series, Numerical-Recipes
/// style). Accurate to ~1e-10 for the df ranges the experiments use.
pub fn chi2_sf(x: f64, df: usize) -> f64 {
    if x <= 0.0 {
        return 1.0;
    }
    gamma_q(df as f64 / 2.0, x / 2.0)
}

fn gamma_q(a: f64, x: f64) -> f64 {
    if x < a + 1.0 {
        1.0 - gamma_p_series(a, x)
    } else {
        gamma_q_contfrac(a, x)
    }
}

fn ln_gamma(z: f64) -> f64 {
    // Lanczos approximation (g = 7, n = 9).
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if z < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * z).sin()).ln() - ln_gamma(1.0 - z);
    }
    let z = z - 1.0;
    let mut x = COEF[0];
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        x += c / (z + i as f64);
    }
    let t = z + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (z + 0.5) * t.ln() - t + x.ln()
}

fn gamma_p_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

fn gamma_q_contfrac(a: f64, x: f64) -> f64 {
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}
