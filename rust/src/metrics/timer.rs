//! Computation/communication split timing.

use std::time::Instant;

/// Monotonic clock wrapper (mockable origin for tests).
#[derive(Clone, Copy, Debug)]
pub struct Clock {
    origin: Instant,
}

impl Default for Clock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock {
    pub fn new() -> Self {
        Self { origin: Instant::now() }
    }

    /// Seconds since this clock was created.
    #[inline]
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

/// Accumulates the paper's two time buckets per node. The hot loop calls
/// `comp(|| …)` / `comm(|| …)`; no allocation, two float adds per call.
#[derive(Clone, Debug, Default)]
pub struct SplitTimer {
    comp: f64,
    comm: f64,
}

impl SplitTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f`, attributing its wall time to the computation bucket.
    #[inline]
    pub fn comp<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.comp += t0.elapsed().as_secs_f64();
        out
    }

    /// Run `f`, attributing its wall time to the communication bucket.
    #[inline]
    pub fn comm<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.comm += t0.elapsed().as_secs_f64();
        out
    }

    pub fn add_comp(&mut self, secs: f64) {
        self.comp += secs;
    }

    pub fn add_comm(&mut self, secs: f64) {
        self.comm += secs;
    }

    pub fn comp_secs(&self) -> f64 {
        self.comp
    }

    pub fn comm_secs(&self) -> f64 {
        self.comm
    }

    pub fn total_secs(&self) -> f64 {
        self.comp + self.comm
    }
}
