//! Admission policy: which requests may share one absorbed batch.
//!
//! Everything in a batch iterates against a *single* θ-truncated,
//! dual-absorbed kernel support. The support stays exact while every
//! column's dual reference drifts less than the covered capacity from
//! the batch anchor, so the thing to control at admission time is the
//! *spread* of the member histograms: column `h`'s scaling duals track
//! `ln b_h` up to a common shift, so two members whose log-histograms
//! differ by `Δ` in some coordinate pull their duals ~`Δ` apart and eat
//! `Δ/2` each of the shared covered-drift budget. A request whose
//! predicted spread would blow that budget opens a **new** batch instead
//! of forcing fleet-wide retruncations on everyone already admitted.

use super::SolveRequest;
use crate::linalg::AbsorbedLogCsr;
use crate::runtime::HYBRID_MAX_CAPACITY;

/// Floor for `ln b` of an (allowed) zero histogram entry — keeps the
/// spread metric finite; a coordinate that is ~0 in every member
/// contributes nothing to the spread either way.
const LOG_FLOOR: f64 = 1e-300;

/// Batching rules shared by every batch the service opens.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Hard cap on members per batch (GEMM width).
    pub max_batch: usize,
    /// The stabilization pair of the solver the batch will run under —
    /// the absorbed support is built with these.
    pub truncation_theta: f64,
    pub absorb_threshold: f64,
    /// Fraction of the absorb threshold τ the *predicted* per-column
    /// drift may consume (0.5 means a member may sit half an absorption
    /// away from the batch anchor before it is refused). Lower values
    /// trade batch occupancy for fewer mid-solve retruncations.
    pub drift_margin: f64,
}

impl AdmissionPolicy {
    /// Largest admissible spread `max_j (max_h − min_h) ln b_j^h` of a
    /// batch's log-histograms. The worst member sits ~spread/2 from the
    /// batch anchor, so the soft budget is `2 · margin·τ`, clipped by
    /// the hard representability bound of the shared support
    /// (`max_covered`, itself capped by [`HYBRID_MAX_CAPACITY`]) — a
    /// batch is never opened wider than the kernel can stay exact for,
    /// no matter the margin.
    pub fn spread_budget(&self) -> f64 {
        let tau = self.absorb_threshold;
        let hard = AbsorbedLogCsr::max_covered(self.truncation_theta, tau)
            .min(HYBRID_MAX_CAPACITY);
        if !tau.is_finite() {
            // Hybrid disabled: no shared support to protect, only the
            // width cap applies.
            return f64::INFINITY;
        }
        2.0 * (self.drift_margin * tau).min(hard).max(0.0)
    }

    /// Open a fresh batch seeded with `first` (always admitted — a batch
    /// of one is trivially compatible with itself).
    pub fn open(&self, first: &SolveRequest) -> Batcher {
        let lo: Vec<f64> = first.b.iter().map(|&x| x.max(LOG_FLOOR).ln()).collect();
        Batcher {
            eps: first.eps,
            hi: lo.clone(),
            lo,
            count: 1,
            budget: self.spread_budget(),
            max_batch: self.max_batch.max(1),
        }
    }
}

/// One open batch accumulating drift-compatible members.
#[derive(Clone, Debug)]
pub struct Batcher {
    eps: f64,
    /// Per-coordinate envelope of the members' `ln b`.
    lo: Vec<f64>,
    hi: Vec<f64>,
    count: usize,
    budget: f64,
    max_batch: usize,
}

impl Batcher {
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Try to admit `req`. Admission requires the same ε (different
    /// regularizations mean different kernels — nothing to share), room
    /// under the width cap, and a post-admission log-histogram spread
    /// within the drift budget. On refusal the batch is unchanged and
    /// the caller opens a new one.
    pub fn admit(&mut self, req: &SolveRequest) -> bool {
        if req.eps != self.eps || self.count >= self.max_batch {
            return false;
        }
        debug_assert_eq!(req.b.len(), self.lo.len(), "histogram length");
        let mut spread = 0.0f64;
        for (j, &x) in req.b.iter().enumerate() {
            let lx = x.max(LOG_FLOOR).ln();
            spread = spread.max(self.hi[j].max(lx) - self.lo[j].min(lx));
            if spread > self.budget {
                return false;
            }
        }
        for (j, &x) in req.b.iter().enumerate() {
            let lx = x.max(LOG_FLOOR).ln();
            self.lo[j] = self.lo[j].min(lx);
            self.hi[j] = self.hi[j].max(lx);
        }
        self.count += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(b: Vec<f64>, eps: f64) -> SolveRequest {
        SolveRequest { id: 0, b, eps, threshold: 1e-9, arrival: 0.0 }
    }

    fn policy() -> AdmissionPolicy {
        AdmissionPolicy {
            max_batch: 4,
            truncation_theta: -60.0,
            absorb_threshold: 15.0,
            drift_margin: 0.5,
        }
    }

    #[test]
    fn identical_histograms_fill_to_the_width_cap() {
        let p = policy();
        let r = req(vec![0.25; 4], 0.01);
        let mut batch = p.open(&r);
        assert!(batch.admit(&r));
        assert!(batch.admit(&r));
        assert!(batch.admit(&r));
        assert_eq!(batch.len(), 4);
        // Width cap, not drift, refuses the fifth.
        assert!(!batch.admit(&r));
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn default_tuning_budget_is_margin_limited() {
        // margin·τ = 7.5 binds before the hard capacity (300): budget 15.
        assert!((policy().spread_budget() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn far_histogram_opens_a_new_batch() {
        let p = policy();
        let near = req(vec![0.25, 0.25, 0.25, 0.25], 0.01);
        // One coordinate 20 decades of e below the seed: spread ≈ 20 > 15.
        let far = req(vec![0.25 * (-20.0f64).exp(), 0.25, 0.25, 0.25], 0.01);
        let mut batch = p.open(&near);
        assert!(!batch.admit(&far));
        assert_eq!(batch.len(), 1);
        // The refused request seeds its own batch fine.
        let mut other = p.open(&far);
        assert!(other.admit(&far));
    }

    #[test]
    fn eps_mismatch_never_shares_a_batch() {
        let p = policy();
        let r1 = req(vec![0.5, 0.5], 0.01);
        let r2 = req(vec![0.5, 0.5], 0.02);
        let mut batch = p.open(&r1);
        assert!(!batch.admit(&r2));
    }

    #[test]
    fn margin_tightens_the_budget() {
        let mut p = policy();
        let seed = req(vec![0.5, 0.5], 0.01);
        // Spread of ~2.0 between these two.
        let near = req(vec![0.5 * (-2.0f64).exp(), 0.5], 0.01);
        assert!(p.open(&seed).admit(&near));
        p.drift_margin = 0.05; // budget 2·0.75 = 1.5 < 2.0
        assert!(!p.open(&seed).admit(&near));
    }

    #[test]
    fn disabled_hybrid_has_no_drift_budget() {
        let mut p = policy();
        p.absorb_threshold = f64::INFINITY;
        assert_eq!(p.spread_budget(), f64::INFINITY);
        let seed = req(vec![0.5, 0.5], 0.01);
        let far = req(vec![0.5 * (-40.0f64).exp(), 0.5], 0.01);
        assert!(p.open(&seed).admit(&far));
    }
}
