//! Synthetic open-loop request workload for the solve service.
//!
//! Models a multi-tenant population sharing one cost geometry: each
//! tenant owns a base histogram; its requests perturb that base in log
//! space (so perturbation scale maps directly onto the admission
//! policy's spread metric), arrive as a Poisson stream, and carry
//! per-request convergence tolerances jittered across decades.

use super::SolveRequest;
use crate::rng::{child_seed, Rng};

/// Generator knobs for [`synth_requests`].
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    /// Total requests to emit.
    pub requests: usize,
    /// Tenant (base-histogram) count; request `i` belongs to tenant
    /// `i % tenants`.
    pub tenants: usize,
    /// Log-space perturbation scale: each request's log-weights are the
    /// tenant base plus `perturb · U[−1, 1]` per coordinate. Directly
    /// comparable to the admission spread budget.
    pub perturb: f64,
    /// Open-loop Poisson arrival rate (requests/sec of virtual time);
    /// `0` means the whole workload arrives as one burst at t = 0.
    pub arrival_rate: f64,
    /// Base marginal-error tolerance.
    pub threshold: f64,
    /// Per-request tolerance jitter in decades: request tolerance is
    /// `threshold · 10^(−U[0,1]·jitter)`, so some requests demand up to
    /// `jitter` decades tighter convergence than others — the per-column
    /// stopping path is pointless without this heterogeneity.
    pub tolerance_jitter: f64,
    pub seed: u64,
}

/// Emit `spec.requests` histogram-solve requests of dimension `n`,
/// sorted by (strictly increasing) arrival time, ids dense from 0.
pub fn synth_requests(n: usize, spec: &WorkloadSpec) -> Vec<SolveRequest> {
    assert!(n > 0 && spec.requests > 0 && spec.tenants > 0);
    let mut bases = Vec::with_capacity(spec.tenants);
    for t in 0..spec.tenants {
        let mut rng = Rng::seed_from(child_seed(spec.seed, 1 + t as u64));
        let base: Vec<f64> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        bases.push(base);
    }
    let mut rng = Rng::seed_from(child_seed(spec.seed, 0));
    let mut t_arrive = 0.0f64;
    let mut out = Vec::with_capacity(spec.requests);
    for i in 0..spec.requests {
        let base = &bases[i % spec.tenants];
        let logw: Vec<f64> = base
            .iter()
            .map(|&w| w + spec.perturb * rng.uniform_range(-1.0, 1.0))
            .collect();
        // Softmax-normalize into a unit-mass histogram.
        let mx = logw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut b: Vec<f64> = logw.iter().map(|&w| (w - mx).exp()).collect();
        let mass: f64 = b.iter().sum();
        for x in &mut b {
            *x /= mass;
        }
        if spec.arrival_rate > 0.0 {
            t_arrive += -(1.0 - rng.uniform()).ln() / spec.arrival_rate;
        }
        let threshold =
            spec.threshold * 10f64.powf(-rng.uniform() * spec.tolerance_jitter);
        out.push(SolveRequest { id: i as u64, b, eps: 0.0, threshold, arrival: t_arrive });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            requests: 24,
            tenants: 4,
            perturb: 0.5,
            arrival_rate: 10.0,
            threshold: 1e-9,
            tolerance_jitter: 1.0,
            seed: 7,
        }
    }

    #[test]
    fn requests_are_unit_mass_and_time_ordered() {
        let reqs = synth_requests(32, &spec());
        assert_eq!(reqs.len(), 24);
        let mut last = -1.0;
        for r in &reqs {
            let mass: f64 = r.b.iter().sum();
            assert!((mass - 1.0).abs() < 1e-12);
            assert!(r.b.iter().all(|&x| x > 0.0));
            assert!(r.arrival > last);
            last = r.arrival;
            assert!(r.threshold <= 1e-9 && r.threshold >= 1e-10 - 1e-25);
        }
    }

    #[test]
    fn burst_mode_arrives_at_time_zero() {
        let mut s = spec();
        s.arrival_rate = 0.0;
        assert!(synth_requests(8, &s).iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn same_tenant_requests_cluster_in_log_space() {
        let mut s = spec();
        s.perturb = 0.1;
        let reqs = synth_requests(16, &s);
        // Requests 0 and 4 share tenant 0; 0 and 1 do not. The intra-
        // tenant log-spread should be far below the inter-tenant one on
        // average (perturb ≪ base range).
        let spread = |x: &SolveRequest, y: &SolveRequest| {
            x.b.iter()
                .zip(&y.b)
                .map(|(&p, &q)| (p.ln() - q.ln()).abs())
                .fold(0.0f64, f64::max)
        };
        let intra = spread(&reqs[0], &reqs[4]);
        let inter = spread(&reqs[0], &reqs[1]);
        assert!(intra < inter, "intra {intra} vs inter {inter}");
    }

    #[test]
    fn deterministic_under_seed() {
        let a = synth_requests(16, &spec());
        let b = synth_requests(16, &spec());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.b, y.b);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.threshold, y.threshold);
        }
    }
}
