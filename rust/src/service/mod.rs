//! Multi-tenant solve service: many concurrent solve requests against a
//! **shared cost geometry**, coalesced into batched absorbed solves.
//!
//! The paper's workloads solve one histogram set per run; a serving
//! deployment instead sees a stream of `(b, ε, tol)` requests over one
//! cost matrix. Because the Sinkhorn iteration is column-separable,
//! requests admitted into the same batch run as extra GEMM columns for
//! nearly free — one θ-truncation, one absorbed support, one operator —
//! while [`admission`] keeps incompatible histograms (predicted dual
//! drift past the covered capacity) out of the batch rather than letting
//! them force fleet-wide retruncations. Inside a batch, per-column
//! stopping ([`crate::sinkhorn::CentralizedSolver::solve_columns`])
//! freezes each request at *its own* tolerance and streams it back while
//! the rest keep iterating.
//!
//! Scheduling is a deterministic open-loop simulation: request arrivals
//! come from the workload (virtual seconds), service times are the
//! measured wall time of each batch solve, and the queue drains in FIFO
//! order one batch at a time.

pub mod admission;
pub mod workload;

pub use admission::{AdmissionPolicy, Batcher};
pub use workload::{synth_requests, WorkloadSpec};

use crate::jsonio::Json;
use crate::linalg::{Domain, Mat, Stabilization};
use crate::metrics::percentile;
use crate::runtime::ComputeBackend;
use crate::sinkhorn::{CentralizedSolver, StopPolicy};
use crate::workload::Problem;
use std::collections::BTreeMap;
use std::sync::Arc;

/// One tenant request: a target histogram over the shared geometry's
/// support, its own regularization ε and convergence tolerance, and an
/// arrival time in virtual seconds.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub id: u64,
    /// Target marginal, length `n`, unit mass.
    pub b: Vec<f64>,
    pub eps: f64,
    /// Per-request a-marginal L1 tolerance (per-column stopping target).
    pub threshold: f64,
    /// Arrival time (virtual seconds from service start).
    pub arrival: f64,
}

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    /// Index into [`ServiceReport::batches`] of the batch that served it.
    pub batch: usize,
    /// Iteration the column froze at (batch-local count).
    pub iterations: usize,
    pub err: f64,
    pub threshold: f64,
    pub converged: bool,
    /// Seconds queued before its batch started.
    pub queue_wait: f64,
    /// Seconds from batch start to this column's freeze.
    pub solve_secs: f64,
    /// `queue_wait + solve_secs` — what the tenant observes.
    pub latency: f64,
    /// The scaling pair frozen at convergence (domain of the run) —
    /// what a real deployment would stream back to the tenant.
    pub u: Vec<f64>,
    pub v: Vec<f64>,
}

/// Per-batch accounting.
#[derive(Clone, Debug)]
pub struct BatchRecord {
    pub size: usize,
    /// Iterations of the slowest surviving column.
    pub iterations: usize,
    pub secs: f64,
    pub compactions: usize,
    /// Members frozen strictly before the batch finished.
    pub early_frozen: usize,
    pub updates: usize,
    pub absorbs: usize,
    pub rebuilds: usize,
}

/// Service knobs.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub alpha: f64,
    pub max_iters: usize,
    pub max_batch: usize,
    /// See [`AdmissionPolicy::drift_margin`].
    pub drift_margin: f64,
    pub stab: Stabilization,
    pub domain: Domain,
    /// Also run every request standalone (same tolerance) and report the
    /// amortization: batched rebuild/absorb totals vs the sum over
    /// standalone runs.
    pub compare_standalone: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            alpha: 1.0,
            max_iters: 6000,
            max_batch: 32,
            drift_margin: 0.5,
            stab: Stabilization::default(),
            domain: Domain::Log,
            compare_standalone: false,
        }
    }
}

/// Totals of the per-request standalone baseline.
#[derive(Clone, Copy, Debug, Default)]
pub struct StandaloneBaseline {
    pub solves: usize,
    pub iterations: usize,
    pub rebuilds: usize,
    pub absorbs: usize,
    pub unconverged: usize,
}

/// Everything a `serve` run reports.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    /// Per-request outcomes, in request-id order.
    pub requests: Vec<RequestResult>,
    pub batches: Vec<BatchRecord>,
    /// Admission refusals that closed an otherwise-open batch.
    pub splits: usize,
    pub makespan_secs: f64,
    pub throughput_rps: f64,
    pub latency_p50: f64,
    pub latency_p90: f64,
    pub latency_p99: f64,
    /// Mean batch width.
    pub occupancy_mean: f64,
    pub standalone: Option<StandaloneBaseline>,
}

impl ServiceReport {
    pub fn unconverged(&self) -> usize {
        self.requests.iter().filter(|r| !r.converged).count()
    }

    pub fn early_frozen(&self) -> usize {
        self.batches.iter().map(|b| b.early_frozen).sum()
    }

    pub fn rebuilds(&self) -> usize {
        self.batches.iter().map(|b| b.rebuilds).sum()
    }

    pub fn absorbs(&self) -> usize {
        self.batches.iter().map(|b| b.absorbs).sum()
    }

    pub fn to_json(&self) -> Json {
        let latencies: Vec<f64> = self.requests.iter().map(|r| r.latency).collect();
        let sizes: Vec<f64> = self.batches.iter().map(|b| b.size as f64).collect();
        let mut pairs = vec![
            ("requests", Json::from(self.requests.len())),
            ("batches", Json::from(self.batches.len())),
            ("splits", Json::from(self.splits)),
            ("makespan_secs", Json::from(self.makespan_secs)),
            ("throughput_rps", Json::from(self.throughput_rps)),
            ("latency_p50", Json::from(self.latency_p50)),
            ("latency_p90", Json::from(self.latency_p90)),
            ("latency_p99", Json::from(self.latency_p99)),
            ("occupancy_mean", Json::from(self.occupancy_mean)),
            ("early_frozen", Json::from(self.early_frozen())),
            ("unconverged", Json::from(self.unconverged())),
            ("compactions", Json::from(self.batches.iter().map(|b| b.compactions).sum::<usize>())),
            ("rebuilds", Json::from(self.rebuilds())),
            ("absorbs", Json::from(self.absorbs())),
            ("updates", Json::from(self.batches.iter().map(|b| b.updates).sum::<usize>())),
            ("batch_sizes", Json::nums(&sizes)),
            ("latencies", Json::nums(&latencies)),
        ];
        if let Some(s) = self.standalone {
            pairs.push((
                "standalone",
                Json::obj(vec![
                    ("solves", s.solves.into()),
                    ("iterations", s.iterations.into()),
                    ("rebuilds", s.rebuilds.into()),
                    ("absorbs", s.absorbs.into()),
                    ("unconverged", s.unconverged.into()),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

/// Derive the per-ε problem for a batch: the geometry's cost matrix with
/// the batch's packed histogram columns. Cloning the per-ε base shares
/// every lazily-built kernel cache (`Arc`-backed), so all batches at one
/// ε pay the θ-truncation exactly once; a *new* ε needs its own caches
/// and gets a fresh [`Problem::from_parts`].
fn problem_for(
    geometry: &Problem,
    eps_map: &mut BTreeMap<u64, Problem>,
    eps: f64,
    b: Mat,
) -> Problem {
    let base = eps_map.entry(eps.to_bits()).or_insert_with(|| {
        if eps == geometry.eps {
            geometry.clone()
        } else {
            let mut p = Problem::from_parts(
                geometry.a.clone(),
                geometry.b.clone(),
                geometry.cost.clone(),
                eps,
            );
            p.masked_cost_min = geometry.masked_cost_min;
            p
        }
    });
    let mut p = base.clone();
    p.b = b;
    p
}

/// Drain `requests` (any order; scheduled FIFO by arrival) through
/// batched absorbed solves over `geometry`'s cost matrix. Returns the
/// per-request, per-batch, and aggregate accounting.
pub fn run_service(
    backend: Arc<dyn ComputeBackend>,
    geometry: &Problem,
    requests: &[SolveRequest],
    cfg: &ServiceConfig,
) -> ServiceReport {
    assert!(!requests.is_empty(), "empty request stream");
    let n = geometry.n;
    for r in requests {
        assert_eq!(r.b.len(), n, "request {} histogram length", r.id);
    }
    let policy = AdmissionPolicy {
        max_batch: cfg.max_batch,
        truncation_theta: cfg.stab.truncation_theta,
        absorb_threshold: cfg.stab.absorb_threshold,
        drift_margin: cfg.drift_margin,
    };
    let solver = CentralizedSolver::new(backend.clone()).with_stabilization(cfg.stab);
    let stop = StopPolicy {
        threshold: 0.0, // ignored: per-column thresholds rule
        max_iters: cfg.max_iters,
        timeout_secs: 0.0,
        check_every: 1,
    };

    // FIFO by arrival (ties keep submission order).
    let mut order: Vec<usize> = (0..requests.len()).collect();
    order.sort_by(|&i, &j| {
        requests[i]
            .arrival
            .partial_cmp(&requests[j].arrival)
            .unwrap()
            .then(i.cmp(&j))
    });

    let mut eps_map: BTreeMap<u64, Problem> = BTreeMap::new();
    let mut results: Vec<Option<RequestResult>> = vec![None; requests.len()];
    let mut batches = Vec::new();
    let mut splits = 0usize;
    let mut t_free = 0.0f64;
    let mut next = 0usize;

    while next < order.len() {
        let first = &requests[order[next]];
        // The server goes idle until the head request arrives.
        let t_start = t_free.max(first.arrival);
        let mut batch = policy.open(first);
        let mut members = vec![order[next]];
        next += 1;
        // Coalesce the arrived FIFO prefix while admission allows; the
        // first refusal closes the batch (a split) so the stream stays
        // in order.
        while next < order.len() {
            let r = &requests[order[next]];
            if r.arrival > t_start || batch.len() >= cfg.max_batch {
                break;
            }
            if !batch.admit(r) {
                splits += 1;
                break;
            }
            members.push(order[next]);
            next += 1;
        }

        let w = members.len();
        let mut b_pack = Mat::zeros(n, w);
        for (k, &m) in members.iter().enumerate() {
            for i in 0..n {
                b_pack[(i, k)] = requests[m].b[i];
            }
        }
        let pb = problem_for(geometry, &mut eps_map, first.eps, b_pack);
        let thresholds: Vec<f64> = members.iter().map(|&m| requests[m].threshold).collect();
        let outcome = solver.solve_columns(
            &pb,
            stop,
            &thresholds,
            cfg.alpha,
            cfg.domain,
            // Results are collected below; nothing streams out-of-process.
            &mut |_col, _out| {},
        );

        let batch_idx = batches.len();
        let mut early = 0usize;
        for (k, &m) in members.iter().enumerate() {
            let col = &outcome.columns[k];
            if col.converged && col.iterations < outcome.iterations {
                early += 1;
            }
            let queue_wait = t_start - requests[m].arrival;
            results[m] = Some(RequestResult {
                id: requests[m].id,
                batch: batch_idx,
                iterations: col.iterations,
                err: col.err,
                threshold: requests[m].threshold,
                converged: col.converged,
                queue_wait,
                solve_secs: col.secs,
                latency: queue_wait + col.secs,
                u: col.u.clone(),
                v: col.v.clone(),
            });
        }
        let stab = outcome.stab.clone().unwrap_or_default();
        batches.push(BatchRecord {
            size: w,
            iterations: outcome.iterations,
            secs: outcome.secs,
            compactions: outcome.compactions,
            early_frozen: early,
            updates: stab.updates,
            absorbs: stab.absorbs,
            rebuilds: stab.rebuilds,
        });
        t_free = t_start + outcome.secs;
    }

    let standalone = cfg.compare_standalone.then(|| {
        let mut base = StandaloneBaseline { solves: requests.len(), ..Default::default() };
        for r in requests {
            let mut b1 = Mat::zeros(n, 1);
            for i in 0..n {
                b1[(i, 0)] = r.b[i];
            }
            let p1 = problem_for(geometry, &mut eps_map, r.eps, b1);
            let out = solver.solve_in(
                &p1,
                StopPolicy { threshold: r.threshold, ..stop },
                cfg.alpha,
                cfg.domain,
            );
            base.iterations += out.iterations;
            if !out.converged() {
                base.unconverged += 1;
            }
            if let Some(s) = out.stab {
                base.rebuilds += s.rebuilds;
                base.absorbs += s.absorbs;
            }
        }
        base
    });

    let requests_out: Vec<RequestResult> = results.into_iter().map(Option::unwrap).collect();
    let latencies: Vec<f64> = requests_out.iter().map(|r| r.latency).collect();
    let makespan = t_free.max(f64::MIN_POSITIVE);
    let occupancy = requests_out.len() as f64 / batches.len().max(1) as f64;
    ServiceReport {
        splits,
        makespan_secs: makespan,
        throughput_rps: requests_out.len() as f64 / makespan,
        latency_p50: percentile(&latencies, 0.50),
        latency_p90: percentile(&latencies, 0.90),
        latency_p99: percentile(&latencies, 0.99),
        occupancy_mean: occupancy,
        requests: requests_out,
        batches,
        standalone,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::experiments::build_problem;
    use crate::runtime::make_backend;
    use crate::workload::CondClass;

    fn native() -> Arc<dyn ComputeBackend> {
        make_backend(BackendKind::Native, "", 1).unwrap()
    }

    #[test]
    fn burst_workload_batches_and_converges() {
        let geometry = build_problem(24, 1, 0.05, 0.0, 2, CondClass::Well, 11);
        let spec = WorkloadSpec {
            requests: 12,
            tenants: 3,
            perturb: 0.3,
            arrival_rate: 0.0,
            threshold: 1e-8,
            tolerance_jitter: 1.0,
            seed: 5,
        };
        let mut reqs = synth_requests(24, &spec);
        for r in &mut reqs {
            r.eps = geometry.eps;
        }
        let cfg = ServiceConfig { max_batch: 8, ..Default::default() };
        let rep = run_service(native(), &geometry, &reqs, &cfg);
        assert_eq!(rep.requests.len(), 12);
        assert_eq!(rep.unconverged(), 0, "{rep:?}");
        // Burst + small spread: far fewer batches than requests.
        assert!(rep.batches.len() <= 4, "batches {}", rep.batches.len());
        assert!(rep.occupancy_mean >= 3.0);
        for r in &rep.requests {
            assert!(r.err < r.threshold, "req {}: {} !< {}", r.id, r.err, r.threshold);
            assert!(r.latency >= r.solve_secs);
        }
        // Heterogeneous tolerances ⇒ some column froze before the batch.
        assert!(rep.early_frozen() > 0);
        let j = rep.to_json();
        assert_eq!(j.get("requests").and_then(Json::as_usize), Some(12));
        assert_eq!(j.get("unconverged").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn incompatible_eps_lands_in_separate_batches() {
        let geometry = build_problem(16, 1, 0.05, 0.0, 2, CondClass::Well, 3);
        let b: Vec<f64> = (0..16).map(|i| geometry.b[(i, 0)]).collect();
        let mk = |id: u64, eps: f64| SolveRequest {
            id,
            b: b.clone(),
            eps,
            threshold: 1e-8,
            arrival: 0.0,
        };
        let reqs = vec![mk(0, 0.05), mk(1, 0.1), mk(2, 0.05)];
        let cfg = ServiceConfig { max_batch: 8, ..Default::default() };
        let rep = run_service(native(), &geometry, &reqs, &cfg);
        assert_eq!(rep.unconverged(), 0);
        // FIFO split at the ε boundary: [0], [1], [2] or [0], [1], [2]
        // merged never — 3 batches, ≥1 split counted at the refusal.
        assert_eq!(rep.batches.len(), 3);
        assert!(rep.splits >= 1);
    }
}
