//! Centralized Sinkhorn–Knopp solver over a [`ComputeBackend`].

use super::ops::{full_marginal_errors, objective};
use super::{State, StopPolicy};
use crate::linalg::Mat;
use crate::metrics::Clock;
use crate::runtime::{ComputeBackend, Target};
use crate::workload::Problem;
use std::sync::Arc;

/// Why a solve stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    Converged,
    MaxIters,
    Timeout,
}

/// One convergence-history sample (ε-study, Figs 4/9/19–22 traces).
#[derive(Clone, Copy, Debug)]
pub struct HistoryPoint {
    pub iter: usize,
    pub secs: f64,
    pub err_a: f64,
    pub err_b: f64,
    pub objective: f64,
}

/// Solve result.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub state: State,
    pub iterations: usize,
    pub stop: StopReason,
    /// Max-over-histograms a-marginal error at the last check.
    pub final_err: f64,
    pub secs: f64,
    pub history: Vec<HistoryPoint>,
}

impl SolveOutcome {
    pub fn converged(&self) -> bool {
        self.stop == StopReason::Converged
    }
}

/// The centralized baseline: both scaling updates on one node, dispatched
/// through whichever backend (XLA artifacts / native) is configured.
pub struct CentralizedSolver {
    backend: Arc<dyn ComputeBackend>,
}

impl CentralizedSolver {
    pub fn new(backend: Arc<dyn ComputeBackend>) -> Self {
        Self { backend }
    }

    /// Plain solve (no per-iteration history).
    pub fn solve(&self, p: &Problem, policy: StopPolicy, alpha: f64) -> SolveOutcome {
        self.run(p, policy, alpha, false)
    }

    /// Solve recording the error/objective trace at every check point.
    pub fn solve_traced(&self, p: &Problem, policy: StopPolicy, alpha: f64) -> SolveOutcome {
        self.run(p, policy, alpha, true)
    }

    fn run(&self, p: &Problem, policy: StopPolicy, alpha: f64, traced: bool) -> SolveOutcome {
        let n = p.n;
        let nh = p.hists();
        let clock = Clock::new();

        // u-update operator: A = K, t = a (broadcast across histograms).
        let mut u_op = self
            .backend
            .block_op(&p.k, Target::Vec(&p.a), Mat::ones(n, nh))
            .expect("u-op");
        // v-update operator: A = Kᵀ, t = b (per-histogram matrix).
        let kt = p.k.transpose();
        let mut v_op = self
            .backend
            .block_op(&kt, Target::Mat(&p.b), Mat::ones(n, nh))
            .expect("v-op");

        let mut history = Vec::new();
        let mut iterations = 0;
        let mut final_err = f64::INFINITY;
        let mut stop = StopReason::MaxIters;

        for k in 1..=policy.max_iters {
            iterations = k;
            // u ← α a/(K v) + (1−α) u ; v ← α b/(Kᵀ u) + (1−α) v.
            let u = u_op.update(v_op.state(), alpha);
            let _v = v_op.update(u, alpha);

            if policy.check_at(k) {
                // a-marginal error via the u-operator: Σ|u∘(K v) − a|.
                let u_now = u_op.state().clone();
                let errs = u_op.marginal(v_op.state(), &u_now);
                let err = errs.iter().cloned().fold(0.0, f64::max);
                final_err = err;
                if traced {
                    let st = State { u: u_op.state().clone(), v: v_op.state().clone() };
                    let (err_a, err_b) = full_marginal_errors(p, &st, 0);
                    history.push(HistoryPoint {
                        iter: k,
                        secs: clock.now(),
                        err_a,
                        err_b,
                        objective: objective(p, &st, 0),
                    });
                }
                if err < policy.threshold {
                    stop = StopReason::Converged;
                    break;
                }
            }
            if policy.timeout_secs > 0.0 && clock.now() > policy.timeout_secs {
                stop = StopReason::Timeout;
                break;
            }
        }

        SolveOutcome {
            state: State { u: u_op.state().clone(), v: v_op.state().clone() },
            iterations,
            stop,
            final_err,
            secs: clock.now(),
            history,
        }
    }
}
