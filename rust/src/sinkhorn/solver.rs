//! Centralized Sinkhorn–Knopp solver over a [`ComputeBackend`].

use super::ops::convergence_sample;
use super::{State, StopPolicy};
use crate::linalg::{Domain, Mat, Stabilization};
use crate::metrics::Clock;
use crate::runtime::{BlockOp, ComputeBackend, GreedySpec, GreedyStats, StabStats, Target};
use crate::workload::Problem;
use std::sync::Arc;

/// Why a solve stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    Converged,
    MaxIters,
    Timeout,
    /// Federated only: this node was crashed by a fault-plan injection
    /// (`crash_at_iter`) — it exited cleanly at an iteration boundary
    /// with whatever state it had.
    Dead,
    /// Federated only: the node aborted after declaring a peer dead
    /// (recovery policy `--on-node-loss abort`) — a structured partial
    /// outcome, never a hang.
    PeerLoss,
}

/// One convergence-history sample (ε-study, Figs 4/9/19–22 traces).
#[derive(Clone, Copy, Debug)]
pub struct HistoryPoint {
    pub iter: usize,
    pub secs: f64,
    pub err_a: f64,
    pub err_b: f64,
    pub objective: f64,
}

/// Solve result.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub state: State,
    pub iterations: usize,
    pub stop: StopReason,
    /// Max-over-histograms a-marginal error at the last check.
    pub final_err: f64,
    pub secs: f64,
    pub history: Vec<HistoryPoint>,
    /// Absorption-hybrid counters (u-op + v-op), when the log-domain
    /// run took the stabilized schedule.
    pub stab: Option<StabStats>,
    /// Greedy top-k counters (u-op + v-op), when the solve ran the
    /// greedy schedule ([`CentralizedSolver::solve_greedy_in`]).
    pub greedy: Option<GreedyStats>,
}

impl SolveOutcome {
    pub fn converged(&self) -> bool {
        self.stop == StopReason::Converged
    }
}

/// Per-column result of a batched multi-histogram solve with per-column
/// stopping ([`CentralizedSolver::solve_columns`]): the frozen scaling
/// pair, the iteration the column converged at (or the batch's last),
/// and its marginal error at the freeze check.
#[derive(Clone, Debug)]
pub struct ColumnOutcome {
    /// Frozen log/linear scalings of this histogram column (length m).
    pub u: Vec<f64>,
    pub v: Vec<f64>,
    pub iterations: usize,
    /// a-marginal L1 error at the freeze (or final) check.
    pub err: f64,
    pub converged: bool,
    /// Wall-clock seconds from batch start to this column's freeze.
    pub secs: f64,
}

/// Batch-level result of [`CentralizedSolver::solve_columns`].
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// One outcome per histogram column, in the problem's column order.
    pub columns: Vec<ColumnOutcome>,
    /// Iterations the *batch* ran (its slowest surviving column).
    pub iterations: usize,
    pub stop: StopReason,
    pub secs: f64,
    /// Merged absorption-hybrid counters across every operator epoch
    /// (compaction rebuilds included); `None` off the stabilized path.
    pub stab: Option<StabStats>,
    /// How many times frozen columns were packed out of the operators.
    pub compactions: usize,
}

/// The centralized baseline: both scaling updates on one node, dispatched
/// through whichever backend (XLA artifacts / native) is configured.
pub struct CentralizedSolver {
    backend: Arc<dyn ComputeBackend>,
    stab: Stabilization,
}

impl CentralizedSolver {
    pub fn new(backend: Arc<dyn ComputeBackend>) -> Self {
        Self { backend, stab: Stabilization::default() }
    }

    /// Override the stabilized log-path tuning (truncation θ, absorption
    /// τ, sparse dispatch cutoff). `Stabilization::disabled()` pins the
    /// solver to the pure dense logsumexp path.
    pub fn with_stabilization(mut self, stab: Stabilization) -> Self {
        self.stab = stab;
        self
    }

    /// Plain linear-domain solve (no per-iteration history).
    pub fn solve(&self, p: &Problem, policy: StopPolicy, alpha: f64) -> SolveOutcome {
        self.run(p, policy, alpha, Domain::Linear, false)
    }

    /// Linear-domain solve recording the error/objective trace at every
    /// check point.
    pub fn solve_traced(&self, p: &Problem, policy: StopPolicy, alpha: f64) -> SolveOutcome {
        self.run(p, policy, alpha, Domain::Linear, true)
    }

    /// Solve in an explicit numerics domain. `Domain::Log` iterates the
    /// log-stabilized scalings (Schmitzer-style max absorption inside the
    /// backend's logsumexp operator) and returns a log-domain [`State`] —
    /// the path that stays exact where `K = exp(−C/ε)` underflows.
    pub fn solve_in(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
    ) -> SolveOutcome {
        self.run(p, policy, alpha, domain, false)
    }

    /// Traced variant of [`CentralizedSolver::solve_in`].
    pub fn solve_traced_in(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
    ) -> SolveOutcome {
        self.run(p, policy, alpha, domain, true)
    }

    /// Build the (u-op, v-op) pair for `p`'s geometry with an explicit
    /// target-histogram matrix `b` and state seeds — the ONE dispatch
    /// over the stabilized paths, shared by [`CentralizedSolver::run`]
    /// (where `b = p.b` and the seeds are all-ones) and the batched
    /// per-column solver (which rebuilds packed ops after freezes when
    /// in-place compaction is unsupported).
    ///
    /// Log-domain construction goes through the stabilized dispatch: the
    /// absorption-hybrid schedule (any histogram count, seeded from the
    /// problem's cached zero-reference absorbed kernel) when enabled,
    /// the θ-truncated sparse logsumexp when the truncated density falls
    /// under the cutoff, dense logsumexp otherwise. Probes are
    /// non-allocating scans; sparse/absorbed kernels are built (and
    /// cached on the problem, shared across solves) only when their path
    /// wins.
    fn build_ops(
        &self,
        p: &Problem,
        domain: Domain,
        b: &Mat,
        u0: Mat,
        v0: Mat,
    ) -> (Box<dyn BlockOp>, Box<dyn BlockOp>) {
        let use_hybrid = domain == Domain::Log
            && self.backend.supports_log()
            && self.stab.hybrid_enabled();
        let use_sparse = domain == Domain::Log
            && !use_hybrid
            && self.backend.supports_sparse_log()
            && self.stab.sparse_density_cutoff > 0.0
            && crate::linalg::LogCsr::density_of(p.log_kernel(), self.stab.truncation_theta)
                < self.stab.sparse_density_cutoff;

        // u-update operator: A = K, t = a (broadcast across histograms);
        // v-update operator: A = Kᵀ, t = b (per-histogram matrix). The
        // transposes come from the problem's shared caches, so repeated
        // solves on one problem build each exactly once.
        if use_hybrid {
            (
                self.backend
                    .log_block_op_stabilized_seeded(
                        p.log_kernel(),
                        Some(p.absorbed_log_kernel(&self.stab)),
                        Target::Vec(&p.a),
                        u0,
                        &self.stab,
                    )
                    .expect("u-op"),
                self.backend
                    .log_block_op_stabilized_seeded(
                        p.log_kernel_t(),
                        Some(p.absorbed_log_kernel_t(&self.stab)),
                        Target::Mat(b),
                        v0,
                        &self.stab,
                    )
                    .expect("v-op"),
            )
        } else if use_sparse {
            let k = p.sparse_log_kernel(self.stab.truncation_theta);
            let kt = p.sparse_log_kernel_t(self.stab.truncation_theta);
            (
                self.backend
                    .sparse_log_block_op(&k, Target::Vec(&p.a), u0)
                    .expect("u-op"),
                self.backend
                    .sparse_log_block_op(&kt, Target::Mat(b), v0)
                    .expect("v-op"),
            )
        } else {
            (
                self.backend
                    .block_op_in_stabilized(
                        domain,
                        p.kernel_for(domain),
                        Target::Vec(&p.a),
                        u0,
                        &self.stab,
                    )
                    .expect("u-op"),
                self.backend
                    .block_op_in_stabilized(
                        domain,
                        p.kernel_t_for(domain),
                        Target::Mat(b),
                        v0,
                        &self.stab,
                    )
                    .expect("v-op"),
            )
        }
    }

    fn run(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
        traced: bool,
    ) -> SolveOutcome {
        let n = p.n;
        let nh = p.hists();
        let clock = Clock::new();
        let one = domain.one();

        let (mut u_op, mut v_op) =
            self.build_ops(p, domain, &p.b, Mat::full(n, nh, one), Mat::full(n, nh, one));

        let mut history = Vec::new();
        let mut iterations = 0;
        let mut final_err = f64::INFINITY;
        let mut stop = StopReason::MaxIters;

        for k in 1..=policy.max_iters {
            iterations = k;
            // u ← α a/(K v) + (1−α) u ; v ← α b/(Kᵀ u) + (1−α) v.
            let u = u_op.update(v_op.state(), alpha);
            let _v = v_op.update(u, alpha);

            if policy.check_at(k) {
                // a-marginal error via the u-operator: Σ|u∘(K v) − a|.
                let u_now = u_op.state().clone();
                let errs = u_op.marginal(v_op.state(), &u_now);
                let err = errs.iter().cloned().fold(0.0, f64::max);
                final_err = err;
                if traced {
                    let st =
                        State { u: u_op.state().clone(), v: v_op.state().clone(), domain };
                    let (err_a, err_b, objective) = convergence_sample(p, &st, 0);
                    history.push(HistoryPoint {
                        iter: k,
                        secs: clock.now(),
                        err_a,
                        err_b,
                        objective,
                    });
                }
                if err < policy.threshold {
                    stop = StopReason::Converged;
                    break;
                }
            }
            if policy.timeout_secs > 0.0 && clock.now() > policy.timeout_secs {
                stop = StopReason::Timeout;
                break;
            }
        }

        SolveOutcome {
            state: State { u: u_op.state().clone(), v: v_op.state().clone(), domain },
            iterations,
            stop,
            final_err,
            secs: clock.now(),
            history,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            greedy: None,
        }
    }

    /// Centralized greedy (Greenkhorn-style) solve: each half-iteration
    /// damps only the top-k most-violated rows through the operators'
    /// incremental [`BlockOp::greedy_update`] schedule. The convergence
    /// check stays the *full* marginal, so greedy can never report a
    /// false convergence off rows it skipped. This is the reference
    /// iterate sequence the federated `--exchange greedy` runs are
    /// tested against.
    pub fn solve_greedy_in(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
        spec: GreedySpec,
    ) -> SolveOutcome {
        let n = p.n;
        let nh = p.hists();
        let clock = Clock::new();
        let one = domain.one();
        let (mut u_op, mut v_op) =
            self.build_ops(p, domain, &p.b, Mat::full(n, nh, one), Mat::full(n, nh, one));
        assert!(
            u_op.supports_greedy() && v_op.supports_greedy(),
            "--exchange greedy needs operators with greedy support (use --backend native)"
        );

        let mut gstats = GreedyStats::default();
        // Rows of each state that moved since the *other* operator's
        // last incremental refresh (`None` = that op has not run yet
        // and pays one full refresh on its first call).
        let mut changed_u: Option<Vec<u32>> = None;
        let mut changed_v: Option<Vec<u32>> = None;
        let mut iterations = 0;
        let mut final_err = f64::INFINITY;
        let mut stop = StopReason::MaxIters;

        for k in 1..=policy.max_iters {
            iterations = k;
            let ou = u_op.greedy_update(v_op.state(), alpha, spec, changed_v.as_deref());
            changed_v = Some(Vec::new());
            gstats.record(&ou, n);
            note_rows(&mut changed_u, &ou.rows);
            let ov = v_op.greedy_update(u_op.state(), alpha, spec, changed_u.as_deref());
            changed_u = Some(Vec::new());
            gstats.record(&ov, n);
            note_rows(&mut changed_v, &ov.rows);

            if policy.check_at(k) {
                let u_now = u_op.state().clone();
                let errs = u_op.marginal(v_op.state(), &u_now);
                let err = errs.iter().cloned().fold(0.0, f64::max);
                final_err = err;
                if err < policy.threshold {
                    stop = StopReason::Converged;
                    break;
                }
            }
            if policy.timeout_secs > 0.0 && clock.now() > policy.timeout_secs {
                stop = StopReason::Timeout;
                break;
            }
        }

        SolveOutcome {
            state: State { u: u_op.state().clone(), v: v_op.state().clone(), domain },
            iterations,
            stop,
            final_err,
            secs: clock.now(),
            history: Vec::new(),
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            greedy: Some(gstats),
        }
    }

    /// Batched multi-histogram solve with **per-column stopping**: every
    /// histogram column of `p.b` carries its own convergence threshold
    /// (`thresholds[h]` replaces `policy.threshold`, which is ignored),
    /// and a column that reaches it is *frozen* — its scaling pair
    /// snapshotted and streamed to `on_frozen(column, outcome)`
    /// immediately — while the rest of the batch keeps iterating.
    ///
    /// Column `h` of the Sinkhorn iteration depends only on column `h`
    /// (products, targets, and damping are all column-separable), so a
    /// frozen column riding along never perturbs the survivors; it only
    /// costs GEMM width. Once at least a quarter of the current batch is
    /// frozen the operators are compacted: the hybrid packs its state
    /// and per-column buffers in place (the absorbed kernel is
    /// column-count independent and survives untouched), other paths
    /// rebuild packed operators around the surviving state. The
    /// quarter-width hysteresis bounds compactions to O(log N) per
    /// batch instead of one per freeze.
    ///
    /// Columns still unconverged at `policy.max_iters` (or timeout) are
    /// returned with `converged = false` and their last checked error;
    /// `on_frozen` fires only for converged columns.
    pub fn solve_columns(
        &self,
        p: &Problem,
        policy: StopPolicy,
        thresholds: &[f64],
        alpha: f64,
        domain: Domain,
        on_frozen: &mut dyn FnMut(usize, &ColumnOutcome),
    ) -> BatchOutcome {
        let n = p.n;
        let nh = p.hists();
        assert_eq!(thresholds.len(), nh, "one tolerance per histogram column");
        let clock = Clock::new();
        let one = domain.one();
        let (mut u_op, mut v_op) =
            self.build_ops(p, domain, &p.b, Mat::full(n, nh, one), Mat::full(n, nh, one));

        // active[slot] = original column of the packed operators' slot.
        let mut active: Vec<usize> = (0..nh).collect();
        let mut results: Vec<Option<ColumnOutcome>> = vec![None; nh];
        let mut last_err = vec![f64::INFINITY; nh];
        let mut retired_stats: Option<StabStats> = None;
        let mut compactions = 0usize;
        let mut iterations = 0usize;
        let mut stop = StopReason::MaxIters;

        for k in 1..=policy.max_iters {
            iterations = k;
            let u = u_op.update(v_op.state(), alpha);
            let _v = v_op.update(u, alpha);

            if policy.check_at(k) {
                let u_now = u_op.state().clone();
                let errs = u_op.marginal(v_op.state(), &u_now);
                let mut frozen_any = false;
                for (slot, &orig) in active.iter().enumerate() {
                    last_err[orig] = errs[slot];
                    if results[orig].is_some() {
                        continue; // frozen already, riding until compaction
                    }
                    if errs[slot] < thresholds[orig] {
                        let col = ColumnOutcome {
                            u: col_of(u_op.state(), slot),
                            v: col_of(v_op.state(), slot),
                            iterations: k,
                            err: errs[slot],
                            converged: true,
                            secs: clock.now(),
                        };
                        on_frozen(orig, &col);
                        results[orig] = Some(col);
                        frozen_any = true;
                    }
                }
                let riding = active.iter().filter(|&&o| results[o].is_some()).count();
                if riding == active.len() {
                    stop = StopReason::Converged;
                    break;
                }
                if frozen_any && riding * 4 >= active.len() {
                    let keep: Vec<usize> = (0..active.len())
                        .filter(|&s| results[active[s]].is_none())
                        .collect();
                    let u_ok = u_op.compact_columns(&keep);
                    let v_ok = u_ok && v_op.compact_columns(&keep);
                    if !(u_ok && v_ok) {
                        // Non-compactable path: rebuild packed operators
                        // around the surviving state, merging the
                        // retiring epoch's counters first.
                        retired_stats = StabStats::merged(
                            retired_stats,
                            StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
                        );
                        let u_pack = if u_ok {
                            u_op.state().clone()
                        } else {
                            u_op.state().select_cols(&keep)
                        };
                        let v_pack = v_op.state().select_cols(&keep);
                        let kept_origs: Vec<usize> =
                            keep.iter().map(|&s| active[s]).collect();
                        let b_pack = p.b.select_cols(&kept_origs);
                        let (nu, nv) = self.build_ops(p, domain, &b_pack, u_pack, v_pack);
                        u_op = nu;
                        v_op = nv;
                    }
                    active = keep.iter().map(|&s| active[s]).collect();
                    compactions += 1;
                }
            }
            if policy.timeout_secs > 0.0 && clock.now() > policy.timeout_secs {
                stop = StopReason::Timeout;
                break;
            }
        }

        // Columns still live at exit: returned unconverged with their
        // last checked error (∞ if no check ever ran).
        for (slot, &orig) in active.iter().enumerate() {
            if results[orig].is_none() {
                results[orig] = Some(ColumnOutcome {
                    u: col_of(u_op.state(), slot),
                    v: col_of(v_op.state(), slot),
                    iterations,
                    err: last_err[orig],
                    converged: false,
                    secs: clock.now(),
                });
            }
        }
        let stab = StabStats::merged(
            retired_stats,
            StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
        );
        BatchOutcome {
            columns: results.into_iter().map(Option::unwrap).collect(),
            iterations,
            stop,
            secs: clock.now(),
            stab,
            compactions,
        }
    }
}

/// Copy one column of an m×N scaling state.
fn col_of(m: &Mat, c: usize) -> Vec<f64> {
    (0..m.rows()).map(|i| m[(i, c)]).collect()
}

/// Merge freshly moved rows into an armed changed-row accumulator
/// (sorted, deduped); a `None` accumulator stays `None` — the consuming
/// operator will take a full refresh on its first call anyway.
fn note_rows(changed: &mut Option<Vec<u32>>, rows: &[u32]) {
    if let Some(ch) = changed.as_mut() {
        ch.extend_from_slice(rows);
        ch.sort_unstable();
        ch.dedup();
    }
}
