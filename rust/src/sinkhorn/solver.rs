//! Centralized Sinkhorn–Knopp solver over a [`ComputeBackend`].

use super::ops::convergence_sample;
use super::{State, StopPolicy};
use crate::linalg::{Domain, Mat, Stabilization};
use crate::metrics::Clock;
use crate::runtime::{ComputeBackend, StabStats, Target};
use crate::workload::Problem;
use std::sync::Arc;

/// Why a solve stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    Converged,
    MaxIters,
    Timeout,
    /// Federated only: this node was crashed by a fault-plan injection
    /// (`crash_at_iter`) — it exited cleanly at an iteration boundary
    /// with whatever state it had.
    Dead,
    /// Federated only: the node aborted after declaring a peer dead
    /// (recovery policy `--on-node-loss abort`) — a structured partial
    /// outcome, never a hang.
    PeerLoss,
}

/// One convergence-history sample (ε-study, Figs 4/9/19–22 traces).
#[derive(Clone, Copy, Debug)]
pub struct HistoryPoint {
    pub iter: usize,
    pub secs: f64,
    pub err_a: f64,
    pub err_b: f64,
    pub objective: f64,
}

/// Solve result.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub state: State,
    pub iterations: usize,
    pub stop: StopReason,
    /// Max-over-histograms a-marginal error at the last check.
    pub final_err: f64,
    pub secs: f64,
    pub history: Vec<HistoryPoint>,
    /// Absorption-hybrid counters (u-op + v-op), when the log-domain
    /// run took the stabilized schedule.
    pub stab: Option<StabStats>,
}

impl SolveOutcome {
    pub fn converged(&self) -> bool {
        self.stop == StopReason::Converged
    }
}

/// The centralized baseline: both scaling updates on one node, dispatched
/// through whichever backend (XLA artifacts / native) is configured.
pub struct CentralizedSolver {
    backend: Arc<dyn ComputeBackend>,
    stab: Stabilization,
}

impl CentralizedSolver {
    pub fn new(backend: Arc<dyn ComputeBackend>) -> Self {
        Self { backend, stab: Stabilization::default() }
    }

    /// Override the stabilized log-path tuning (truncation θ, absorption
    /// τ, sparse dispatch cutoff). `Stabilization::disabled()` pins the
    /// solver to the pure dense logsumexp path.
    pub fn with_stabilization(mut self, stab: Stabilization) -> Self {
        self.stab = stab;
        self
    }

    /// Plain linear-domain solve (no per-iteration history).
    pub fn solve(&self, p: &Problem, policy: StopPolicy, alpha: f64) -> SolveOutcome {
        self.run(p, policy, alpha, Domain::Linear, false)
    }

    /// Linear-domain solve recording the error/objective trace at every
    /// check point.
    pub fn solve_traced(&self, p: &Problem, policy: StopPolicy, alpha: f64) -> SolveOutcome {
        self.run(p, policy, alpha, Domain::Linear, true)
    }

    /// Solve in an explicit numerics domain. `Domain::Log` iterates the
    /// log-stabilized scalings (Schmitzer-style max absorption inside the
    /// backend's logsumexp operator) and returns a log-domain [`State`] —
    /// the path that stays exact where `K = exp(−C/ε)` underflows.
    pub fn solve_in(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
    ) -> SolveOutcome {
        self.run(p, policy, alpha, domain, false)
    }

    /// Traced variant of [`CentralizedSolver::solve_in`].
    pub fn solve_traced_in(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
    ) -> SolveOutcome {
        self.run(p, policy, alpha, domain, true)
    }

    fn run(
        &self,
        p: &Problem,
        policy: StopPolicy,
        alpha: f64,
        domain: Domain,
        traced: bool,
    ) -> SolveOutcome {
        let n = p.n;
        let nh = p.hists();
        let clock = Clock::new();
        let one = domain.one();

        // Log-domain runs go through the stabilized dispatch: the
        // absorption-hybrid schedule (any histogram count, seeded from
        // the problem's cached zero-reference absorbed kernel) when
        // enabled, the θ-truncated sparse logsumexp when the truncated
        // density falls under the cutoff, dense logsumexp otherwise.
        // Probes are non-allocating scans; sparse/absorbed kernels are
        // built (and cached on the problem, shared across solves) only
        // when their path wins.
        let use_hybrid = domain == Domain::Log
            && self.backend.supports_log()
            && self.stab.hybrid_enabled();
        let use_sparse = domain == Domain::Log
            && !use_hybrid
            && self.backend.supports_sparse_log()
            && self.stab.sparse_density_cutoff > 0.0
            && crate::linalg::LogCsr::density_of(p.log_kernel(), self.stab.truncation_theta)
                < self.stab.sparse_density_cutoff;

        // u-update operator: A = K, t = a (broadcast across histograms);
        // v-update operator: A = Kᵀ, t = b (per-histogram matrix). The
        // transposes come from the problem's shared caches, so repeated
        // solves on one problem build each exactly once.
        let (mut u_op, mut v_op) = if use_hybrid {
            (
                self.backend
                    .log_block_op_stabilized_seeded(
                        p.log_kernel(),
                        Some(p.absorbed_log_kernel(&self.stab)),
                        Target::Vec(&p.a),
                        Mat::full(n, nh, one),
                        &self.stab,
                    )
                    .expect("u-op"),
                self.backend
                    .log_block_op_stabilized_seeded(
                        p.log_kernel_t(),
                        Some(p.absorbed_log_kernel_t(&self.stab)),
                        Target::Mat(&p.b),
                        Mat::full(n, nh, one),
                        &self.stab,
                    )
                    .expect("v-op"),
            )
        } else if use_sparse {
            let k = p.sparse_log_kernel(self.stab.truncation_theta);
            let kt = p.sparse_log_kernel_t(self.stab.truncation_theta);
            (
                self.backend
                    .sparse_log_block_op(&k, Target::Vec(&p.a), Mat::full(n, nh, one))
                    .expect("u-op"),
                self.backend
                    .sparse_log_block_op(&kt, Target::Mat(&p.b), Mat::full(n, nh, one))
                    .expect("v-op"),
            )
        } else {
            (
                self.backend
                    .block_op_in_stabilized(
                        domain,
                        p.kernel_for(domain),
                        Target::Vec(&p.a),
                        Mat::full(n, nh, one),
                        &self.stab,
                    )
                    .expect("u-op"),
                self.backend
                    .block_op_in_stabilized(
                        domain,
                        p.kernel_t_for(domain),
                        Target::Mat(&p.b),
                        Mat::full(n, nh, one),
                        &self.stab,
                    )
                    .expect("v-op"),
            )
        };

        let mut history = Vec::new();
        let mut iterations = 0;
        let mut final_err = f64::INFINITY;
        let mut stop = StopReason::MaxIters;

        for k in 1..=policy.max_iters {
            iterations = k;
            // u ← α a/(K v) + (1−α) u ; v ← α b/(Kᵀ u) + (1−α) v.
            let u = u_op.update(v_op.state(), alpha);
            let _v = v_op.update(u, alpha);

            if policy.check_at(k) {
                // a-marginal error via the u-operator: Σ|u∘(K v) − a|.
                let u_now = u_op.state().clone();
                let errs = u_op.marginal(v_op.state(), &u_now);
                let err = errs.iter().cloned().fold(0.0, f64::max);
                final_err = err;
                if traced {
                    let st =
                        State { u: u_op.state().clone(), v: v_op.state().clone(), domain };
                    let (err_a, err_b, objective) = convergence_sample(p, &st, 0);
                    history.push(HistoryPoint {
                        iter: k,
                        secs: clock.now(),
                        err_a,
                        err_b,
                        objective,
                    });
                }
                if err < policy.threshold {
                    stop = StopReason::Converged;
                    break;
                }
            }
            if policy.timeout_secs > 0.0 && clock.now() > policy.timeout_secs {
                stop = StopReason::Timeout;
                break;
            }
        }

        SolveOutcome {
            state: State { u: u_op.state().clone(), v: v_op.state().clone(), domain },
            iterations,
            stop,
            final_err,
            secs: clock.now(),
            history,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
        }
    }
}
