//! Sinkhorn–Knopp core: the centralized solver and the shared pieces
//! (marginal errors, objective, plan assembly, convergence policy) the
//! federated coordinators reuse.
//!
//! The centralized solver is both the paper's baseline and the oracle the
//! property tests pin the federated variants against (synchronous
//! federation generates *the same iterate sequence*, Prop. 1).

mod ops;
mod solver;

pub use ops::{full_marginal_errors, objective, transport_plan};
pub use solver::{CentralizedSolver, HistoryPoint, SolveOutcome, StopReason};

use crate::linalg::Mat;

/// Scaling state `(u, v)`, each `n × N`.
#[derive(Clone, Debug)]
pub struct State {
    pub u: Mat,
    pub v: Mat,
}

impl State {
    pub fn ones(n: usize, hists: usize) -> State {
        State { u: Mat::ones(n, hists), v: Mat::ones(n, hists) }
    }
}

/// Convergence policy shared by all solvers: threshold on the a-marginal
/// L1 error (the paper's criterion), iteration cap, optional wall-clock
/// timeout, and a check cadence.
#[derive(Clone, Copy, Debug)]
pub struct StopPolicy {
    pub threshold: f64,
    pub max_iters: usize,
    pub timeout_secs: f64,
    pub check_every: usize,
}

impl Default for StopPolicy {
    fn default() -> Self {
        Self { threshold: 1e-10, max_iters: 1500, timeout_secs: 0.0, check_every: 1 }
    }
}

impl StopPolicy {
    /// Should we evaluate convergence at iteration `k` (1-based)?
    pub fn check_at(&self, k: usize) -> bool {
        self.check_every <= 1 || k % self.check_every == 0 || k == self.max_iters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::runtime::make_backend;
    use crate::workload::{Problem, ProblemSpec};

    fn native() -> std::sync::Arc<dyn crate::runtime::ComputeBackend> {
        make_backend(BackendKind::Native, "", 1).unwrap()
    }

    #[test]
    fn centralized_converges_on_paper_example() {
        let p = Problem::paper_4x4(0.5);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve(&p, StopPolicy { threshold: 1e-13, ..Default::default() }, 1.0);
        assert!(out.converged(), "stop: {:?}", out.stop);
        let plan = transport_plan(&p.k, &out.state, 0);
        // Marginals recovered.
        for i in 0..4 {
            let row: f64 = (0..4).map(|j| plan[(i, j)]).sum();
            assert!((row - p.a[i]).abs() < 1e-10, "row {i}: {row}");
            let col: f64 = (0..4).map(|j| plan[(j, i)]).sum();
            assert!((col - p.b[(i, 0)]).abs() < 1e-10, "col {i}: {col}");
        }
    }

    #[test]
    fn objective_decreases_toward_limit() {
        // Paper Fig 5: the converged objective approaches ⟨P,C⟩ ≈ 0.3
        // from above as ε shrinks.
        let solver = CentralizedSolver::new(native());
        let mut objs = Vec::new();
        for eps in [0.5, 0.1, 0.01] {
            let p = Problem::paper_4x4(eps);
            let out = solver.solve(
                &p,
                StopPolicy { threshold: 1e-12, max_iters: 200_000, ..Default::default() },
                1.0,
            );
            objs.push(objective(&p, &out.state, 0));
        }
        // Entropy shrinks with ε: the objective rises toward ⟨P,C⟩ ≈ 0.3
        // (cross-checked against a numpy run: −1.098, 0.0252, 0.2725).
        assert!(objs[0] < objs[1] && objs[1] < objs[2], "{objs:?}");
        assert!(objs[2] < 0.31 && objs[2] > 0.25, "limit {:?}", objs[2]);
    }

    #[test]
    fn multi_histogram_solves_match_single() {
        // Vectorized N-histogram solve must equal per-histogram solves.
        let spec = ProblemSpec::new(16).with_hists(3).with_eps(0.5);
        let p = spec.build(21);
        let solver = CentralizedSolver::new(native());
        let pol = StopPolicy { threshold: 1e-12, max_iters: 3000, ..Default::default() };
        let joint = solver.solve(&p, pol, 1.0);
        assert!(joint.converged());
        for h in 0..3 {
            let mut bh = Mat::zeros(16, 1);
            for i in 0..16 {
                bh[(i, 0)] = p.b[(i, h)];
            }
            let single = Problem::from_parts(p.a.clone(), bh, p.cost.clone(), p.eps);
            let out = solver.solve(&single, pol, 1.0);
            for i in 0..16 {
                assert!(
                    (joint.state.u[(i, h)] - out.state.u[(i, 0)]).abs()
                        < 1e-9 * out.state.u[(i, 0)].abs().max(1.0),
                    "hist {h} row {i}"
                );
            }
        }
    }

    #[test]
    fn damped_update_converges_too() {
        // α = 0.5 still converges (slower) — Prop. 2's premise.
        let p = Problem::paper_4x4(0.5);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve(
            &p,
            StopPolicy { threshold: 1e-10, max_iters: 5000, ..Default::default() },
            0.5,
        );
        assert!(out.converged());
    }

    #[test]
    fn iteration_cap_reports_maxiters() {
        let p = Problem::paper_4x4(1e-4); // needs ~13k iters (paper §III)
        let solver = CentralizedSolver::new(native());
        let out = solver.solve(
            &p,
            StopPolicy { threshold: 1e-15, max_iters: 50, ..Default::default() },
            1.0,
        );
        assert!(!out.converged());
        assert!(matches!(out.stop, StopReason::MaxIters));
        assert_eq!(out.iterations, 50);
    }

    #[test]
    fn history_records_monotone_error_for_undamped() {
        let p = Problem::paper_4x4(0.5);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve_traced(
            &p,
            StopPolicy { threshold: 1e-13, ..Default::default() },
            1.0,
        );
        assert!(out.history.len() > 3);
        // Error after iteration 5 must be far below error after 1.
        let first = out.history.first().unwrap().err_a;
        let last = out.history.last().unwrap().err_a;
        assert!(last < first * 1e-3, "first {first}, last {last}");
        // Objective history is populated and finite.
        assert!(out.history.iter().all(|h| h.objective.is_finite()));
    }
}
