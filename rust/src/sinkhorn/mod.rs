//! Sinkhorn–Knopp core: the centralized solver and the shared pieces
//! (marginal errors, objective, plan assembly, convergence policy) the
//! federated coordinators reuse.
//!
//! The centralized solver is both the paper's baseline and the oracle the
//! property tests pin the federated variants against (synchronous
//! federation generates *the same iterate sequence*, Prop. 1).

mod ops;
mod solver;

pub use ops::{full_marginal_errors, objective, transport_plan};
pub use solver::{
    BatchOutcome, CentralizedSolver, ColumnOutcome, HistoryPoint, SolveOutcome, StopReason,
};

use crate::linalg::{Domain, Mat};

/// Scaling state `(u, v)`, each `n × N` — linear scalings or
/// log-scalings depending on `domain`. All whole-problem reductions
/// ([`full_marginal_errors`], [`objective`], [`transport_plan`]) branch
/// on the tag, so a log-domain solve never has to exponentiate its duals
/// back into a representation that would overflow.
#[derive(Clone, Debug)]
pub struct State {
    pub u: Mat,
    pub v: Mat,
    pub domain: Domain,
}

impl State {
    /// Linear-domain all-ones state (the classical initialization).
    pub fn ones(n: usize, hists: usize) -> State {
        State::init(n, hists, Domain::Linear)
    }

    /// Identity scaling state in the given domain: ones linearly, zeros
    /// in the log domain.
    pub fn init(n: usize, hists: usize, domain: Domain) -> State {
        State {
            u: Mat::full(n, hists, domain.one()),
            v: Mat::full(n, hists, domain.one()),
            domain,
        }
    }
}

/// Convergence policy shared by all solvers: threshold on the a-marginal
/// L1 error (the paper's criterion), iteration cap, optional wall-clock
/// timeout, and a check cadence.
#[derive(Clone, Copy, Debug)]
pub struct StopPolicy {
    pub threshold: f64,
    pub max_iters: usize,
    pub timeout_secs: f64,
    pub check_every: usize,
}

impl Default for StopPolicy {
    fn default() -> Self {
        Self { threshold: 1e-10, max_iters: 1500, timeout_secs: 0.0, check_every: 1 }
    }
}

impl StopPolicy {
    /// Should we evaluate convergence at iteration `k` (1-based)?
    pub fn check_at(&self, k: usize) -> bool {
        self.check_every <= 1 || k % self.check_every == 0 || k == self.max_iters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::runtime::make_backend;
    use crate::workload::{Problem, ProblemSpec};

    fn native() -> std::sync::Arc<dyn crate::runtime::ComputeBackend> {
        make_backend(BackendKind::Native, "", 1).unwrap()
    }

    #[test]
    fn centralized_converges_on_paper_example() {
        let p = Problem::paper_4x4(0.5);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve(&p, StopPolicy { threshold: 1e-13, ..Default::default() }, 1.0);
        assert!(out.converged(), "stop: {:?}", out.stop);
        let plan = transport_plan(&p, &out.state, 0);
        // Marginals recovered.
        for i in 0..4 {
            let row: f64 = (0..4).map(|j| plan[(i, j)]).sum();
            assert!((row - p.a[i]).abs() < 1e-10, "row {i}: {row}");
            let col: f64 = (0..4).map(|j| plan[(j, i)]).sum();
            assert!((col - p.b[(i, 0)]).abs() < 1e-10, "col {i}: {col}");
        }
    }

    #[test]
    fn objective_decreases_toward_limit() {
        // Paper Fig 5: the converged objective approaches ⟨P,C⟩ ≈ 0.3
        // from above as ε shrinks.
        let solver = CentralizedSolver::new(native());
        let mut objs = Vec::new();
        for eps in [0.5, 0.1, 0.01] {
            let p = Problem::paper_4x4(eps);
            let out = solver.solve(
                &p,
                StopPolicy { threshold: 1e-12, max_iters: 200_000, ..Default::default() },
                1.0,
            );
            objs.push(objective(&p, &out.state, 0));
        }
        // Entropy shrinks with ε: the objective rises toward ⟨P,C⟩ ≈ 0.3
        // (cross-checked against a numpy run: −1.098, 0.0252, 0.2725).
        assert!(objs[0] < objs[1] && objs[1] < objs[2], "{objs:?}");
        assert!(objs[2] < 0.31 && objs[2] > 0.25, "limit {:?}", objs[2]);
    }

    #[test]
    fn multi_histogram_solves_match_single() {
        // Vectorized N-histogram solve must equal per-histogram solves.
        let spec = ProblemSpec::new(16).with_hists(3).with_eps(0.5);
        let p = spec.build(21);
        let solver = CentralizedSolver::new(native());
        let pol = StopPolicy { threshold: 1e-12, max_iters: 3000, ..Default::default() };
        let joint = solver.solve(&p, pol, 1.0);
        assert!(joint.converged());
        for h in 0..3 {
            let mut bh = Mat::zeros(16, 1);
            for i in 0..16 {
                bh[(i, 0)] = p.b[(i, h)];
            }
            let single = Problem::from_parts(p.a.clone(), bh, p.cost.clone(), p.eps);
            let out = solver.solve(&single, pol, 1.0);
            for i in 0..16 {
                assert!(
                    (joint.state.u[(i, h)] - out.state.u[(i, 0)]).abs()
                        < 1e-9 * out.state.u[(i, 0)].abs().max(1.0),
                    "hist {h} row {i}"
                );
            }
        }
    }

    #[test]
    fn log_domain_converges_where_linear_kernel_underflows() {
        // ε = 1e-3 on the worked example: max C/ε = 3000, so every
        // off-diagonal Gibbs entry is exp(−1000) or smaller — far below
        // f64's ~1e-308 floor. The linear path cannot represent the
        // kernel; the log-stabilized path converges to a valid plan.
        let p = Problem::paper_4x4(1e-3);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve_in(
            &p,
            StopPolicy {
                threshold: 1e-10,
                max_iters: 200_000,
                check_every: 10,
                ..Default::default()
            },
            1.0,
            crate::linalg::Domain::Log,
        );
        assert!(out.converged(), "stop: {:?} err {:.3e}", out.stop, out.final_err);
        assert_eq!(out.state.domain, crate::linalg::Domain::Log);
        let plan = transport_plan(&p, &out.state, 0);
        for i in 0..4 {
            let row: f64 = (0..4).map(|j| plan[(i, j)]).sum();
            assert!((row - p.a[i]).abs() < 1e-8, "row {i}: {row}");
            let col: f64 = (0..4).map(|j| plan[(j, i)]).sum();
            assert!((col - p.b[(i, 0)]).abs() < 1e-8, "col {i}: {col}");
        }
        // At ε → 0 the plan approaches the unregularized optimum with
        // cost ⟨P,C⟩ → 0.3 (paper Fig 5); the entropic term vanishes.
        let cost: f64 = (0..4)
            .flat_map(|i| (0..4).map(move |j| (i, j)))
            .map(|(i, j)| plan[(i, j)] * p.cost[(i, j)])
            .sum();
        assert!((cost - 0.3).abs() < 5e-3, "⟨P,C⟩ = {cost}");
    }

    #[test]
    fn log_and_linear_domains_agree_at_moderate_eps() {
        // 16×16, 3 histograms, ε well inside the linear comfort zone:
        // the two representations must land on the same scalings to
        // 1e-9 relative (α = 1 makes the iterate sequences identical in
        // exact arithmetic).
        let spec = ProblemSpec::new(16).with_hists(3).with_eps(0.5);
        let p = spec.build(31);
        let solver = CentralizedSolver::new(native());
        let pol = StopPolicy { threshold: 1e-12, max_iters: 3000, ..Default::default() };
        let lin = solver.solve_in(&p, pol, 1.0, crate::linalg::Domain::Linear);
        let log = solver.solve_in(&p, pol, 1.0, crate::linalg::Domain::Log);
        assert!(lin.converged() && log.converged());
        for h in 0..3 {
            for i in 0..16 {
                let want_u = lin.state.u[(i, h)];
                let got_u = log.state.u[(i, h)].exp();
                assert!(
                    (got_u - want_u).abs() < 1e-9 * want_u.abs().max(1.0),
                    "u hist {h} row {i}: {got_u} vs {want_u}"
                );
                let want_v = lin.state.v[(i, h)];
                let got_v = log.state.v[(i, h)].exp();
                assert!(
                    (got_v - want_v).abs() < 1e-9 * want_v.abs().max(1.0),
                    "v hist {h} row {i}: {got_v} vs {want_v}"
                );
            }
        }
        // And the assembled plans agree too.
        let pl = transport_plan(&p, &lin.state, 1);
        let pg = transport_plan(&p, &log.state, 1);
        assert!(pl.allclose(&pg, 1e-9));
    }

    #[test]
    fn damped_update_converges_too() {
        // α = 0.5 still converges (slower) — Prop. 2's premise.
        let p = Problem::paper_4x4(0.5);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve(
            &p,
            StopPolicy { threshold: 1e-10, max_iters: 5000, ..Default::default() },
            0.5,
        );
        assert!(out.converged());
    }

    #[test]
    fn iteration_cap_reports_maxiters() {
        let p = Problem::paper_4x4(1e-4); // needs ~13k iters (paper §III)
        let solver = CentralizedSolver::new(native());
        let out = solver.solve(
            &p,
            StopPolicy { threshold: 1e-15, max_iters: 50, ..Default::default() },
            1.0,
        );
        assert!(!out.converged());
        assert!(matches!(out.stop, StopReason::MaxIters));
        assert_eq!(out.iterations, 50);
    }

    #[test]
    fn history_records_monotone_error_for_undamped() {
        let p = Problem::paper_4x4(0.5);
        let solver = CentralizedSolver::new(native());
        let out = solver.solve_traced(
            &p,
            StopPolicy { threshold: 1e-13, ..Default::default() },
            1.0,
        );
        assert!(out.history.len() > 3);
        // Error after iteration 5 must be far below error after 1.
        let first = out.history.first().unwrap().err_a;
        let last = out.history.last().unwrap().err_a;
        assert!(last < first * 1e-3, "first {first}, last {last}");
        // Objective history is populated and finite.
        assert!(out.history.iter().all(|h| h.objective.is_finite()));
    }
}
