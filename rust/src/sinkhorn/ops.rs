//! Whole-problem reductions: marginal errors, objective, plan.
//!
//! Cold-path operations (once per convergence check / at the end of a
//! run); the hot path lives in [`crate::runtime`]. Every reduction
//! branches on the state's [`Domain`]: log-domain states assemble plan
//! entries as `exp(log u + log K + log v)` — each exponent is the log of
//! a plan entry (≤ 0 near the fixed point), so nothing overflows even
//! when the duals are in the thousands.

use super::State;
use crate::linalg::{scale_rows_cols, Domain, Mat};
use crate::workload::Problem;

/// L1 marginal errors `(Σ|P·1 − a|, Σ|Pᵀ·1 − b|)` for histogram `h`.
pub fn full_marginal_errors(p: &Problem, st: &State, h: usize) -> (f64, f64) {
    let n = p.n;
    let uh: Vec<f64> = (0..n).map(|i| st.u[(i, h)]).collect();
    let vh: Vec<f64> = (0..n).map(|i| st.v[(i, h)]).collect();
    let k = p.kernel_for(st.domain);
    let mut err_a = 0.0;
    let mut err_b = vec![0.0; n];
    for i in 0..n {
        let krow = k.row(i);
        let mut row_sum = 0.0;
        match st.domain {
            Domain::Linear => {
                for j in 0..n {
                    let pij = uh[i] * krow[j] * vh[j];
                    row_sum += pij;
                    err_b[j] += pij;
                }
            }
            Domain::Log => {
                for j in 0..n {
                    let pij = (uh[i] + krow[j] + vh[j]).exp();
                    row_sum += pij;
                    err_b[j] += pij;
                }
            }
        }
        err_a += (row_sum - p.a[i]).abs();
    }
    let err_b: f64 = (0..n).map(|j| (err_b[j] - p.b[(j, h)]).abs()).sum();
    (err_a, err_b)
}

/// Entropic objective `⟨P,C⟩ + ε Σ P (log P − 1)` for histogram `h`,
/// computed in the stable form `ε Σ P (log u + log v − 1)` — log-domain
/// states already store `log u`, `log v` directly.
pub fn objective(p: &Problem, st: &State, h: usize) -> f64 {
    let n = p.n;
    let k = p.kernel_for(st.domain);
    let mut total = 0.0;
    for i in 0..n {
        let ui = st.u[(i, h)];
        let krow = k.row(i);
        match st.domain {
            Domain::Linear => {
                let lu = ui.ln();
                for j in 0..n {
                    let vj = st.v[(j, h)];
                    let pij = ui * krow[j] * vj;
                    if pij > 0.0 {
                        total += pij * (lu + vj.ln() - 1.0);
                    }
                }
            }
            Domain::Log => {
                for j in 0..n {
                    let lv = st.v[(j, h)];
                    let pij = (ui + krow[j] + lv).exp();
                    if pij > 0.0 {
                        total += pij * (ui + lv - 1.0);
                    }
                }
            }
        }
    }
    p.eps * total
}

/// Transport plan `P = diag(u_h) K diag(v_h)`, assembled in whichever
/// representation the state carries (always returned linearly — plan
/// entries are probabilities and never overflow).
pub fn transport_plan(p: &Problem, st: &State, h: usize) -> Mat {
    let n = p.n;
    let uh: Vec<f64> = (0..n).map(|i| st.u[(i, h)]).collect();
    let vh: Vec<f64> = (0..n).map(|i| st.v[(i, h)]).collect();
    match st.domain {
        Domain::Linear => scale_rows_cols(p.kernel(), &uh, &vh),
        Domain::Log => {
            let lk = p.log_kernel();
            let mut out = Mat::zeros(n, n);
            for i in 0..n {
                let lkrow = lk.row(i);
                let orow = out.row_mut(i);
                for j in 0..n {
                    orow[j] = (uh[i] + lkrow[j] + vh[j]).exp();
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Problem;

    #[test]
    fn errors_vanish_at_fixed_point() {
        // Construct an exact fixed point: P doubly stochastic by design.
        let p = Problem::paper_4x4(0.5);
        let k = p.kernel().clone();
        // Run enough plain iterations to reach the fixed point.
        let n = 4;
        let mut u = vec![1.0; n];
        let mut v = vec![1.0; n];
        for _ in 0..500 {
            for i in 0..n {
                let q: f64 = (0..n).map(|j| k[(i, j)] * v[j]).sum();
                u[i] = p.a[i] / q;
            }
            for j in 0..n {
                let r: f64 = (0..n).map(|i| k[(i, j)] * u[i]).sum();
                v[j] = p.b[(j, 0)] / r;
            }
        }
        let mut st = State::ones(n, 1);
        for i in 0..n {
            st.u[(i, 0)] = u[i];
            st.v[(i, 0)] = v[i];
        }
        let (ea, eb) = full_marginal_errors(&p, &st, 0);
        assert!(ea < 1e-12 && eb < 1e-14, "({ea}, {eb})");
        // The same fixed point expressed in log-scalings reads the same
        // marginal errors through the log-domain reduction.
        let mut lst = State::init(n, 1, Domain::Log);
        for i in 0..n {
            lst.u[(i, 0)] = u[i].ln();
            lst.v[(i, 0)] = v[i].ln();
        }
        let (lea, leb) = full_marginal_errors(&p, &lst, 0);
        assert!(lea < 1e-12 && leb < 1e-13, "({lea}, {leb})");
    }

    #[test]
    fn objective_matches_direct_formula() {
        let p = Problem::paper_4x4(0.7);
        let mut st = State::ones(4, 1);
        for i in 0..4 {
            st.u[(i, 0)] = 0.5 + 0.1 * i as f64;
            st.v[(i, 0)] = 1.5 - 0.2 * i as f64;
        }
        let got = objective(&p, &st, 0);
        let plan = transport_plan(&p, &st, 0);
        let mut want = 0.0;
        for i in 0..4 {
            for j in 0..4 {
                let pij = plan[(i, j)];
                want += pij * p.cost[(i, j)] + p.eps * pij * (pij.ln() - 1.0);
            }
        }
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // Log-domain representation of the same state: identical
        // objective and plan up to round-off.
        let mut lst = State::init(4, 1, Domain::Log);
        for i in 0..4 {
            lst.u[(i, 0)] = st.u[(i, 0)].ln();
            lst.v[(i, 0)] = st.v[(i, 0)].ln();
        }
        let lgot = objective(&p, &lst, 0);
        assert!((lgot - want).abs() < 1e-10, "{lgot} vs {want}");
        assert!(transport_plan(&p, &lst, 0).allclose(&plan, 1e-12));
    }

    #[test]
    fn plan_marginals_are_scaled_kernel() {
        let p = Problem::paper_4x4(1.0);
        let st = State::ones(4, 1);
        let plan = transport_plan(&p, &st, 0);
        assert!(plan.allclose(p.kernel(), 1e-15));
        // Identity log state reproduces the kernel too.
        let lst = State::init(4, 1, Domain::Log);
        let lplan = transport_plan(&p, &lst, 0);
        assert!(lplan.allclose(p.kernel(), 1e-15));
    }
}
