//! Whole-problem reductions: marginal errors, objective, plan.
//!
//! Cold-path operations (once per convergence check / at the end of a
//! run); the hot path lives in [`crate::runtime`]. Every reduction
//! branches on the state's [`Domain`]: log-domain states assemble plan
//! entries as `exp(log u + log K + log v)` — each exponent is the log of
//! a plan entry (≤ 0 near the fixed point), so nothing overflows even
//! when the duals are in the thousands.
//!
//! The marginal/objective reductions route their O(n²) work through the
//! cached kernel transposes and the blocked GEMV / logsumexp kernels
//! (`P·1 = u∘(Kv)`, `Pᵀ·1 = v∘(Kᵀu)`) instead of scalar accumulation —
//! at large n the scalar loops used to rival an iteration's cost.

use super::State;
use crate::linalg::{scale_rows_cols, Domain, Mat};
use crate::workload::Problem;

/// Plan marginals `(P·1, Pᵀ·1)` for histogram `h`, via two products on
/// the cached kernel + transpose (the GEMV fast path at `nh = 1`).
fn plan_marginals(p: &Problem, st: &State, h: usize) -> (Vec<f64>, Vec<f64>) {
    let n = p.n;
    let uh: Vec<f64> = (0..n).map(|i| st.u[(i, h)]).collect();
    let vh: Vec<f64> = (0..n).map(|i| st.v[(i, h)]).collect();
    match st.domain {
        Domain::Linear => {
            let kv = p.kernel().matmul(&Mat::col_from(&vh), 1);
            let ktu = p.kernel_t().matmul(&Mat::col_from(&uh), 1);
            let rows = uh.iter().zip(kv.as_slice()).map(|(&u, &q)| u * q).collect();
            let cols = vh.iter().zip(ktu.as_slice()).map(|(&v, &r)| v * r).collect();
            (rows, cols)
        }
        Domain::Log => {
            let kv = p.log_kernel().logsumexp(&Mat::col_from(&vh), 1);
            let ktu = p.log_kernel_t().logsumexp(&Mat::col_from(&uh), 1);
            // log u + log(Kv) is the log of a marginal entry — O(log a)
            // near the fixed point, so the exp cannot overflow there.
            let rows = uh.iter().zip(kv.as_slice()).map(|(&u, &q)| (u + q).exp()).collect();
            let cols = vh.iter().zip(ktu.as_slice()).map(|(&v, &r)| (v + r).exp()).collect();
            (rows, cols)
        }
    }
}

/// `(Σ|P·1 − a|, Σ|Pᵀ·1 − b_h|)` from precomputed plan marginals.
fn errors_from(p: &Problem, h: usize, rows: &[f64], cols: &[f64]) -> (f64, f64) {
    let err_a: f64 = rows.iter().zip(&p.a).map(|(&r, &a)| (r - a).abs()).sum();
    let err_b: f64 = (0..p.n).map(|j| (cols[j] - p.b[(j, h)]).abs()).sum();
    (err_a, err_b)
}

/// The entropic objective from precomputed plan marginals (see
/// [`objective`] for the factorization).
fn objective_from(p: &Problem, st: &State, h: usize, rows: &[f64], cols: &[f64]) -> f64 {
    let log_of = |x: f64| match st.domain {
        Domain::Linear => x.ln(),
        Domain::Log => x,
    };
    let mut total = 0.0;
    let mut mass = 0.0;
    for i in 0..p.n {
        // A zero marginal (fully underflowed row/column) carries zero
        // plan mass: skip it rather than accumulate ln(0)·0 = NaN.
        if rows[i] > 0.0 {
            total += log_of(st.u[(i, h)]) * rows[i];
            mass += rows[i];
        }
        if cols[i] > 0.0 {
            total += log_of(st.v[(i, h)]) * cols[i];
        }
    }
    p.eps * (total - mass)
}

/// L1 marginal errors `(Σ|P·1 − a|, Σ|Pᵀ·1 − b|)` for histogram `h`.
pub fn full_marginal_errors(p: &Problem, st: &State, h: usize) -> (f64, f64) {
    let (rows, cols) = plan_marginals(p, st, h);
    errors_from(p, h, &rows, &cols)
}

/// Entropic objective `⟨P,C⟩ + ε Σ P (log P − 1)` for histogram `h`,
/// computed in the stable form `ε Σ P (log u + log v − 1)` — which
/// factors over the plan marginals:
/// `Σ_i log u_i (P·1)_i + Σ_j log v_j (Pᵀ·1)_j − Σ P`. Log-domain
/// states already store `log u`, `log v` directly.
pub fn objective(p: &Problem, st: &State, h: usize) -> f64 {
    let (rows, cols) = plan_marginals(p, st, h);
    objective_from(p, st, h, &rows, &cols)
}

/// One traced-checkpoint sample `(err_a, err_b, objective)` from a
/// single pair of kernel products — the traced solver calls this once
/// per check instead of paying `full_marginal_errors` + [`objective`]
/// separately (two extra O(n²) products per checkpoint).
pub fn convergence_sample(p: &Problem, st: &State, h: usize) -> (f64, f64, f64) {
    let (rows, cols) = plan_marginals(p, st, h);
    let (err_a, err_b) = errors_from(p, h, &rows, &cols);
    (err_a, err_b, objective_from(p, st, h, &rows, &cols))
}

/// Transport plan `P = diag(u_h) K diag(v_h)`, assembled in whichever
/// representation the state carries (always returned linearly — plan
/// entries are probabilities and never overflow).
pub fn transport_plan(p: &Problem, st: &State, h: usize) -> Mat {
    let n = p.n;
    let uh: Vec<f64> = (0..n).map(|i| st.u[(i, h)]).collect();
    let vh: Vec<f64> = (0..n).map(|i| st.v[(i, h)]).collect();
    match st.domain {
        Domain::Linear => scale_rows_cols(p.kernel(), &uh, &vh),
        Domain::Log => {
            let lk = p.log_kernel();
            let mut out = Mat::zeros(n, n);
            for i in 0..n {
                let ui = uh[i];
                for ((o, &lkj), &vj) in out.row_mut(i).iter_mut().zip(lk.row(i)).zip(&vh) {
                    *o = (ui + lkj + vj).exp();
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Problem;

    #[test]
    fn errors_vanish_at_fixed_point() {
        // Construct an exact fixed point: P doubly stochastic by design.
        let p = Problem::paper_4x4(0.5);
        let k = p.kernel().clone();
        // Run enough plain iterations to reach the fixed point.
        let n = 4;
        let mut u = vec![1.0; n];
        let mut v = vec![1.0; n];
        for _ in 0..500 {
            for i in 0..n {
                let q: f64 = (0..n).map(|j| k[(i, j)] * v[j]).sum();
                u[i] = p.a[i] / q;
            }
            for j in 0..n {
                let r: f64 = (0..n).map(|i| k[(i, j)] * u[i]).sum();
                v[j] = p.b[(j, 0)] / r;
            }
        }
        let mut st = State::ones(n, 1);
        for i in 0..n {
            st.u[(i, 0)] = u[i];
            st.v[(i, 0)] = v[i];
        }
        let (ea, eb) = full_marginal_errors(&p, &st, 0);
        assert!(ea < 1e-12 && eb < 1e-14, "({ea}, {eb})");
        // The same fixed point expressed in log-scalings reads the same
        // marginal errors through the log-domain reduction.
        let mut lst = State::init(n, 1, Domain::Log);
        for i in 0..n {
            lst.u[(i, 0)] = u[i].ln();
            lst.v[(i, 0)] = v[i].ln();
        }
        let (lea, leb) = full_marginal_errors(&p, &lst, 0);
        assert!(lea < 1e-12 && leb < 1e-13, "({lea}, {leb})");
    }

    #[test]
    fn objective_matches_direct_formula() {
        let p = Problem::paper_4x4(0.7);
        let mut st = State::ones(4, 1);
        for i in 0..4 {
            st.u[(i, 0)] = 0.5 + 0.1 * i as f64;
            st.v[(i, 0)] = 1.5 - 0.2 * i as f64;
        }
        let got = objective(&p, &st, 0);
        let plan = transport_plan(&p, &st, 0);
        let mut want = 0.0;
        for i in 0..4 {
            for j in 0..4 {
                let pij = plan[(i, j)];
                want += pij * p.cost[(i, j)] + p.eps * pij * (pij.ln() - 1.0);
            }
        }
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // Log-domain representation of the same state: identical
        // objective and plan up to round-off.
        let mut lst = State::init(4, 1, Domain::Log);
        for i in 0..4 {
            lst.u[(i, 0)] = st.u[(i, 0)].ln();
            lst.v[(i, 0)] = st.v[(i, 0)].ln();
        }
        let lgot = objective(&p, &lst, 0);
        assert!((lgot - want).abs() < 1e-10, "{lgot} vs {want}");
        assert!(transport_plan(&p, &lst, 0).allclose(&plan, 1e-12));
    }

    #[test]
    fn plan_marginals_are_scaled_kernel() {
        let p = Problem::paper_4x4(1.0);
        let st = State::ones(4, 1);
        let plan = transport_plan(&p, &st, 0);
        assert!(plan.allclose(p.kernel(), 1e-15));
        // Identity log state reproduces the kernel too.
        let lst = State::init(4, 1, Domain::Log);
        let lplan = transport_plan(&p, &lst, 0);
        assert!(lplan.allclose(p.kernel(), 1e-15));
    }
}
