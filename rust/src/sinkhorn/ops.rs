//! Whole-problem reductions: marginal errors, objective, plan.
//!
//! Cold-path operations (once per convergence check / at the end of a
//! run); the hot path lives in [`crate::runtime`].

use super::State;
use crate::linalg::{scale_rows_cols, Mat};
use crate::workload::Problem;

/// L1 marginal errors `(Σ|P·1 − a|, Σ|Pᵀ·1 − b|)` for histogram `h`.
pub fn full_marginal_errors(p: &Problem, st: &State, h: usize) -> (f64, f64) {
    let n = p.n;
    let uh: Vec<f64> = (0..n).map(|i| st.u[(i, h)]).collect();
    let vh: Vec<f64> = (0..n).map(|i| st.v[(i, h)]).collect();
    let mut err_a = 0.0;
    let mut err_b = vec![0.0; n];
    for i in 0..n {
        let krow = p.k.row(i);
        let mut row_sum = 0.0;
        for j in 0..n {
            let pij = uh[i] * krow[j] * vh[j];
            row_sum += pij;
            err_b[j] += pij;
        }
        err_a += (row_sum - p.a[i]).abs();
    }
    let err_b: f64 = (0..n).map(|j| (err_b[j] - p.b[(j, h)]).abs()).sum();
    (err_a, err_b)
}

/// Entropic objective `⟨P,C⟩ + ε Σ P (log P − 1)` for histogram `h`,
/// computed in the stable form `ε Σ P (log u + log v − 1)`.
pub fn objective(p: &Problem, st: &State, h: usize) -> f64 {
    let n = p.n;
    let mut total = 0.0;
    for i in 0..n {
        let ui = st.u[(i, h)];
        let lu = ui.ln();
        let krow = p.k.row(i);
        for j in 0..n {
            let pij = ui * krow[j] * st.v[(j, h)];
            if pij > 0.0 {
                total += pij * (lu + st.v[(j, h)].ln() - 1.0);
            }
        }
    }
    p.eps * total
}

/// Transport plan `P = diag(u_h) K diag(v_h)`.
pub fn transport_plan(k: &Mat, st: &State, h: usize) -> Mat {
    let n = k.rows();
    let uh: Vec<f64> = (0..n).map(|i| st.u[(i, h)]).collect();
    let vh: Vec<f64> = (0..k.cols()).map(|i| st.v[(i, h)]).collect();
    scale_rows_cols(k, &uh, &vh)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Problem;

    #[test]
    fn errors_vanish_at_fixed_point() {
        // Construct an exact fixed point: P doubly stochastic by design.
        let p = Problem::paper_4x4(0.5);
        // Run enough plain iterations to reach the fixed point.
        let n = 4;
        let mut u = vec![1.0; n];
        let mut v = vec![1.0; n];
        for _ in 0..500 {
            for i in 0..n {
                let q: f64 = (0..n).map(|j| p.k[(i, j)] * v[j]).sum();
                u[i] = p.a[i] / q;
            }
            for j in 0..n {
                let r: f64 = (0..n).map(|i| p.k[(i, j)] * u[i]).sum();
                v[j] = p.b[(j, 0)] / r;
            }
        }
        let mut st = State::ones(n, 1);
        for i in 0..n {
            st.u[(i, 0)] = u[i];
            st.v[(i, 0)] = v[i];
        }
        let (ea, eb) = full_marginal_errors(&p, &st, 0);
        assert!(ea < 1e-12 && eb < 1e-14, "({ea}, {eb})");
    }

    #[test]
    fn objective_matches_direct_formula() {
        let p = Problem::paper_4x4(0.7);
        let mut st = State::ones(4, 1);
        for i in 0..4 {
            st.u[(i, 0)] = 0.5 + 0.1 * i as f64;
            st.v[(i, 0)] = 1.5 - 0.2 * i as f64;
        }
        let got = objective(&p, &st, 0);
        let plan = transport_plan(&p.k, &st, 0);
        let mut want = 0.0;
        for i in 0..4 {
            for j in 0..4 {
                let pij = plan[(i, j)];
                want += pij * p.cost[(i, j)] + p.eps * pij * (pij.ln() - 1.0);
            }
        }
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn plan_marginals_are_scaled_kernel() {
        let p = Problem::paper_4x4(1.0);
        let st = State::ones(4, 1);
        let plan = transport_plan(&p.k, &st, 0);
        assert!(plan.allclose(&p.k, 1e-15));
    }
}
