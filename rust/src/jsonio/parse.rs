//! Recursive-descent JSON parser.

use super::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Parse failure with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace only).
pub fn parse(src: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing content after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling for astral chars.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}
