//! Minimal JSON substrate (no `serde` in the offline image).
//!
//! A recursive-descent parser + pretty writer covering the JSON subset the
//! system exchanges: the AOT artifact manifest written by
//! `python/compile/aot.py` and the experiment result dumps consumed by the
//! plotting/table scripts. Numbers are kept as `f64` (plus an `i64` fast
//! path on write); strings support the standard escapes.

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::to_string_pretty;

use std::collections::BTreeMap;

/// A JSON value. `BTreeMap` keeps object key order deterministic, which
/// makes experiment output diffs stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helpers for experiment output.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like_document() {
        let src = r#"{
            "version": 1,
            "entries": [
                {"op": "client_update", "m": 256, "n": 512, "nhist": 1,
                 "dtype": "f64", "file": "x.hlo.txt", "w": 0},
                {"op": "server_matvec", "m": 64, "n": 64, "nhist": 64,
                 "dtype": "f32", "file": "y.hlo.txt", "w": 10}
            ],
            "src_hash": "abc123",
            "ok": true, "nothing": null, "pi": 3.5e-1
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].get("op").unwrap().as_str(), Some("client_update"));
        assert_eq!(entries[1].get("nhist").unwrap().as_usize(), Some(64));
        assert_eq!(v.get("pi").unwrap().as_f64(), Some(0.35));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("nothing"), Some(&Json::Null));

        // write → parse is the identity
        let text = to_string_pretty(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1F600}".to_string());
        let text = to_string_pretty(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{unquoted: 1}").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("").is_err());
        assert!(parse("{\"a\": 1} trailing").is_err());
    }

    #[test]
    fn parses_nested_and_negative() {
        let v = parse(r#"[[-1.5e3, 2], {"x": [null]}]"#).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr[0].as_arr().unwrap()[0].as_f64(), Some(-1500.0));
    }

    #[test]
    fn unicode_escape_sequences() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
