//! JSON pretty-printer (2-space indent, stable key order).

use super::Json;

/// Serialize with indentation; integers print without a trailing `.0`.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

fn write_value(v: &Json, indent: usize, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(indent + 1, out);
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            pad(indent, out);
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(indent + 1, out);
                write_str(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            pad(indent, out);
            out.push('}');
        }
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() && x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else if x.is_finite() {
        // Ryu-style shortest repr is what `{}` gives for f64 in Rust.
        out.push_str(&format!("{x}"));
    } else {
        // JSON has no Inf/NaN; encode as null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(to_string_pretty(&Json::Num(42.0)), "42");
        assert_eq!(to_string_pretty(&Json::Num(-3.0)), "-3");
        assert_eq!(to_string_pretty(&Json::Num(0.5)), "0.5");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(to_string_pretty(&Json::Num(f64::NAN)), "null");
        assert_eq!(to_string_pretty(&Json::Num(f64::INFINITY)), "null");
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(to_string_pretty(&Json::Str("\u{1}".into())), "\"\\u0001\"");
    }
}
