//! Deterministic, seedable PRNG substrate (no `rand` crate offline).
//!
//! `splitmix64` seeds a `xoshiro256++` core; on top we provide the
//! samplers the workload generator and network simulator need: uniform,
//! normal (Ziggurat-free polar method), exponential, lognormal, Dirichlet
//! (via gamma), and permutation shuffles. All experiment randomness flows
//! through [`Rng`] so every run is reproducible from a single `u64` seed.

mod xoshiro;

pub use xoshiro::Xoshiro256pp;

/// Convenience alias: the experiment-wide generator.
pub type Rng = Xoshiro256pp;

/// splitmix64 — used to expand a single seed into stream seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive a child seed for stream `idx` (e.g. one per client thread).
pub fn child_seed(seed: u64, idx: u64) -> u64 {
    let mut s = seed ^ idx.wrapping_mul(0xA24BAED4963EE407);
    splitmix64(&mut s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = 42;
        let mut b = 42;
        assert_eq!(splitmix64(&mut a), splitmix64(&mut b));
        assert_eq!(a, b);
    }

    #[test]
    fn child_seeds_differ_by_stream() {
        let s = 7;
        assert_ne!(child_seed(s, 0), child_seed(s, 1));
        assert_eq!(child_seed(s, 3), child_seed(s, 3));
    }

    #[test]
    fn uniform_is_in_unit_interval() {
        let mut r = Rng::seed_from(123);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::seed_from(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from(9);
        let p = r.dirichlet(17, 1.0);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}"); // rate 2 → mean .5
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from(31);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from(77);
        for _ in 0..10_000 {
            let k = r.uniform_range(3.0, 9.0);
            assert!((3.0..9.0).contains(&k));
            let i = r.below(13);
            assert!(i < 13);
        }
    }
}
