//! xoshiro256++ core generator + distribution samplers.
//!
//! Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (2019). Period 2^256 − 1; passes BigCrush. We implement it
//! directly because no `rand` crate resolves in this offline image.

use super::splitmix64;

/// xoshiro256++ PRNG with the distribution samplers the workloads need.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second normal from the polar method.
    spare_normal: Option<f64>,
}

impl Xoshiro256pp {
    /// Seed all four lanes from a single `u64` through splitmix64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        // 64-bit multiply-shift; bias negligible for experiment bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Standard normal via Marsaglia's polar method (caches the pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let x = 2.0 * self.uniform() - 1.0;
            let y = 2.0 * self.uniform() - 1.0;
            let r2 = x * x + y * y;
            if r2 > 0.0 && r2 < 1.0 {
                let f = (-2.0 * r2.ln() / r2).sqrt();
                self.spare_normal = Some(y * f);
                return x * f;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (mean `1/rate`).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        // 1 - uniform() is in (0, 1], so ln is finite.
        -(1.0 - self.uniform()).ln() / rate
    }

    /// Lognormal: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Gamma(shape, 1) — Marsaglia–Tsang for shape ≥ 1, boost for < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Johnk-boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha, …, alpha) sample of length `n` — a strictly
    /// positive probability vector, the paper's marginal distributions.
    pub fn dirichlet(&mut self, n: usize, alpha: f64) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n)
            .map(|_| self.gamma(alpha).max(1e-300))
            .collect();
        let s: f64 = g.iter().sum();
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}
