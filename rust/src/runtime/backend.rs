//! Backend trait: what the coordinators need from the compute layer.

use crate::linalg::Mat;

/// A client's target marginal slice: the u-update broadcasts one vector
/// (`a_j`) across histograms; the v-update in vectorized mode has one
/// column per histogram (`b_j ∈ R^{m×N}`).
#[derive(Clone, Copy, Debug)]
pub enum Target<'a> {
    Vec(&'a [f64]),
    Mat(&'a Mat),
}

impl Target<'_> {
    pub fn rows(&self) -> usize {
        match self {
            Target::Vec(v) => v.len(),
            Target::Mat(m) => m.rows(),
        }
    }
}

/// A stateful handle bound to one kernel block `A (m×n)` and one target
/// slice `t`. Holds the evolving scaling state `u (m×N)` internally so
/// backends can keep it device-resident; `update` performs
/// `u ← α·t/(A·x) + (1−α)·u` and returns a host view of the new state.
pub trait BlockOp: Send {
    fn m(&self) -> usize;
    fn n(&self) -> usize;
    fn hists(&self) -> usize;

    /// Damped Sinkhorn scaling update; returns the new state.
    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat;

    /// Plain product `A·x` (star-server step).
    fn matvec(&mut self, x: &Mat) -> &Mat;

    /// Per-histogram L1 marginal error `Σ_i |u∘(A·x) − t|_i`.
    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64>;

    /// Current state (host view).
    fn state(&self) -> &Mat;

    /// Overwrite the state (initialization / restart).
    fn set_state(&mut self, u: &Mat);
}

/// Backend factory: builds [`BlockOp`]s for client blocks.
pub trait ComputeBackend: Send + Sync {
    /// Bind a block operator. `u0` seeds the state (normally ones).
    fn block_op(
        &self,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>>;

    fn name(&self) -> &'static str;
}
