//! Backend trait: what the coordinators need from the compute layer.
//!
//! [`BlockOp`] is domain-polymorphic by construction: a linear-domain op
//! (from [`ComputeBackend::block_op`]) iterates `u ← α·t/(A·x) + (1−α)·u`
//! on linear scalings; a log-domain op (from
//! [`ComputeBackend::log_block_op`]) iterates
//! `log u ← α·(log t − LSE(A_log + log x)) + (1−α)·log u` on
//! log-scalings. Both report the *linear-domain* L1 marginal error from
//! `marginal`, so solvers and coordinators run the same protocol code
//! over either representation.

use crate::linalg::{AbsorbedLogCsr, Domain, LogCsr, Mat, Stabilization};
use std::sync::Arc;

/// A client's target marginal slice: the u-update broadcasts one vector
/// (`a_j`) across histograms; the v-update in vectorized mode has one
/// column per histogram (`b_j ∈ R^{m×N}`).
#[derive(Clone, Copy, Debug)]
pub enum Target<'a> {
    Vec(&'a [f64]),
    Mat(&'a Mat),
}

impl Target<'_> {
    pub fn rows(&self) -> usize {
        match self {
            Target::Vec(v) => v.len(),
            Target::Mat(m) => m.rows(),
        }
    }
}

/// Instrumentation of the absorption-hybrid schedule: how many scaling
/// updates an operator performed, how many of them forced a kernel
/// re-absorption (partial `O(nnz)` or full), and how many of those were
/// full `O(m·n)` re-truncations — the rest ran at sparse-GEMM cost. For
/// vectorized solves `absorb_triggers[h]` counts, per histogram, how
/// often it was hist `h`'s drift that tripped a re-absorption. The
/// acceptance bar for the hybrid is `linear_fraction() ≥ 0.7` over a
/// small-ε vectorized solve (≥ 0.8 single-histogram).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StabStats {
    pub updates: usize,
    /// Re-absorption events (partial + full).
    pub absorbs: usize,
    /// Full support re-truncations (the only dense-cost rebuilds).
    pub rebuilds: usize,
    /// Per-histogram re-absorption triggers (empty for non-hybrid ops).
    pub absorb_triggers: Vec<usize>,
    /// Coordinator-issued fleet absorb commands this operator obeyed
    /// (a subset of `absorbs`; 0 outside `--fleet-absorb` runs).
    pub fleet_commands: usize,
    /// Full re-truncations performed on a fleet command (a subset of
    /// `rebuilds`). `rebuilds − fleet_rebuilds` are local emergency
    /// rebuilds the coordinator did not anticipate.
    pub fleet_rebuilds: usize,
}

impl StabStats {
    /// Fraction of updates that ran purely on the linear GEMM path.
    pub fn linear_fraction(&self) -> f64 {
        if self.updates == 0 {
            1.0
        } else {
            1.0 - self.absorbs as f64 / self.updates as f64
        }
    }

    /// Merge two optional per-operator counters (u-op + v-op, or
    /// per-node counters across a federated run). Per-histogram trigger
    /// vectors add elementwise (padded to the longer length).
    pub fn merged(a: Option<StabStats>, b: Option<StabStats>) -> Option<StabStats> {
        match (a, b) {
            (None, None) => None,
            (x, y) => {
                let (x, y) = (x.unwrap_or_default(), y.unwrap_or_default());
                let mut triggers = if x.absorb_triggers.len() >= y.absorb_triggers.len() {
                    x.absorb_triggers.clone()
                } else {
                    y.absorb_triggers.clone()
                };
                let shorter = if x.absorb_triggers.len() >= y.absorb_triggers.len() {
                    &y.absorb_triggers
                } else {
                    &x.absorb_triggers
                };
                for (t, &s) in triggers.iter_mut().zip(shorter) {
                    *t += s;
                }
                Some(StabStats {
                    updates: x.updates + y.updates,
                    absorbs: x.absorbs + y.absorbs,
                    rebuilds: x.rebuilds + y.rebuilds,
                    absorb_triggers: triggers,
                    fleet_commands: x.fleet_commands + y.fleet_commands,
                    fleet_rebuilds: x.fleet_rebuilds + y.fleet_rebuilds,
                })
            }
        }
    }
}

/// One node's slice-local view of the fleet-absorption decision inputs,
/// all computed over rows `[col0, col0 + m)` of a candidate input `x` —
/// exactly the slice that node already owns in the scaling exchange, so
/// probes cost `O(m·N)` instead of a redundant `O(n·N)` scan per node.
#[derive(Clone, Debug)]
pub struct FleetProbe {
    /// Per-histogram drift `max_j |x[j,h] − ḡ[j]|` of the slice against
    /// the operator's currently absorbed reference.
    pub drift: Vec<f64>,
    /// Max inter-histogram spread `|x[j,h] − mean_h x[j,·]|` over the
    /// slice — merged across nodes it is exactly the full-input spread
    /// (the column mean is a per-row quantity).
    pub spread: f64,
    /// Column-mean candidate reference for the slice rows; the
    /// coordinator concatenates these into the broadcast dual `ḡ`.
    pub gref_slice: Vec<f64>,
    /// Current covered drift capacity of the operator's kernel.
    pub covered: f64,
}

/// Top-k selection policy of the greedy schedule (`--greedy-topk`): how
/// many of a block's rows get updated (and exchanged) per
/// half-iteration. An integer literal selects a fixed row count; a
/// float in (0, 1) selects the smallest prefix of the violation-ranked
/// rows covering that fraction of the total violation mass — the
/// adaptive variant spends its budget where the marginals are worst.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GreedySpec {
    /// Fixed number of rows per greedy update (clamped to the block).
    Count(usize),
    /// Smallest violation-ranked prefix covering this mass fraction.
    MassFraction(f64),
}

impl GreedySpec {
    /// Parse a `--greedy-topk` value: `"64"` → `Count(64)`, `"0.25"` →
    /// `MassFraction(0.25)`.
    pub fn parse(s: &str) -> anyhow::Result<GreedySpec> {
        if let Ok(k) = s.parse::<usize>() {
            anyhow::ensure!(k >= 1, "--greedy-topk count must be ≥ 1");
            return Ok(GreedySpec::Count(k));
        }
        let f: f64 = s.parse().map_err(|_| {
            anyhow::anyhow!(
                "--greedy-topk expects an integer count or a fraction in (0, 1), got '{s}'"
            )
        })?;
        anyhow::ensure!(
            f > 0.0 && f < 1.0,
            "--greedy-topk fraction must lie in (0, 1), got {f}"
        );
        Ok(GreedySpec::MassFraction(f))
    }

    /// Rank rows by violation and select per the policy: the selected
    /// indices come back sorted ascending together with the selected
    /// and total violation mass. At least one row is always selected;
    /// ties break toward the lower index so selection is deterministic.
    pub fn select(&self, viol: &[f64]) -> GreedyOutcome {
        let total: f64 = viol.iter().sum();
        let mut order: Vec<u32> = (0..viol.len() as u32).collect();
        order.sort_by(|&a, &b| viol[b as usize].total_cmp(&viol[a as usize]).then(a.cmp(&b)));
        let take = match *self {
            GreedySpec::Count(k) => k.clamp(1, viol.len().max(1)).min(viol.len()),
            GreedySpec::MassFraction(f) => {
                let goal = f * total;
                let mut acc = 0.0;
                let mut take = 0usize;
                for &i in &order {
                    if take > 0 && (acc >= goal || viol[i as usize] == 0.0) {
                        break;
                    }
                    acc += viol[i as usize];
                    take += 1;
                }
                take
            }
        };
        let mut rows = order[..take].to_vec();
        rows.sort_unstable();
        let selected_mass = rows.iter().map(|&i| viol[i as usize]).sum();
        GreedyOutcome { rows, selected_mass, total_mass: total }
    }
}

/// What a greedy update touched: the updated row indices (sorted,
/// block-local) and the violation mass they covered. The exchange
/// layer ships exactly these coordinates; the stats surface the
/// selected-over-total mass ratio.
#[derive(Clone, Debug, Default)]
pub struct GreedyOutcome {
    pub rows: Vec<u32>,
    pub selected_mass: f64,
    pub total_mass: f64,
}

/// Aggregated greedy-schedule instrumentation: how many top-k updates
/// ran, how many rows they selected out of how many candidates, and the
/// violation mass the selections covered. The row ratio is the comm
/// saving (`1 − rows_selected/rows_candidate` of the slice bytes never
/// move); the mass ratio is the quality of the selection policy.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GreedyStats {
    /// Greedy update calls (two per iteration on a full solve: u and v).
    pub calls: u64,
    /// Rows selected across all calls.
    pub rows_selected: u64,
    /// Candidate rows across all calls (`m` per call).
    pub rows_candidate: u64,
    /// Violation mass covered by the selections.
    pub selected_mass: f64,
    /// Total violation mass seen by the selections.
    pub total_mass: f64,
}

impl GreedyStats {
    /// Fold one greedy outcome over an `m`-row block into the counters.
    pub fn record(&mut self, o: &GreedyOutcome, m: usize) {
        self.calls += 1;
        self.rows_selected += o.rows.len() as u64;
        self.rows_candidate += m as u64;
        self.selected_mass += o.selected_mass;
        self.total_mass += o.total_mass;
    }

    /// Mean fraction of rows selected per call (1.0 when nothing ran).
    pub fn row_fraction(&self) -> f64 {
        if self.rows_candidate == 0 {
            1.0
        } else {
            self.rows_selected as f64 / self.rows_candidate as f64
        }
    }

    /// Fraction of the violation mass the selections covered.
    pub fn mass_fraction(&self) -> f64 {
        if self.total_mass == 0.0 {
            1.0
        } else {
            self.selected_mass / self.total_mass
        }
    }

    /// Merge two optional counters (u-op + v-op, or per-node counters
    /// across a federated run), mirroring [`StabStats::merged`].
    pub fn merged(a: Option<GreedyStats>, b: Option<GreedyStats>) -> Option<GreedyStats> {
        match (a, b) {
            (None, None) => None,
            (x, y) => {
                let (x, y) = (x.unwrap_or_default(), y.unwrap_or_default());
                Some(GreedyStats {
                    calls: x.calls + y.calls,
                    rows_selected: x.rows_selected + y.rows_selected,
                    rows_candidate: x.rows_candidate + y.rows_candidate,
                    selected_mass: x.selected_mass + y.selected_mass,
                    total_mass: x.total_mass + y.total_mass,
                })
            }
        }
    }
}

/// A stateful handle bound to one kernel block `A (m×n)` and one target
/// slice `t`. Holds the evolving scaling state `u (m×N)` internally so
/// backends can keep it device-resident; `update` performs
/// `u ← α·t/(A·x) + (1−α)·u` and returns a host view of the new state.
pub trait BlockOp: Send {
    fn m(&self) -> usize;
    fn n(&self) -> usize;
    fn hists(&self) -> usize;

    /// Damped Sinkhorn scaling update; returns the new state.
    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat;

    /// Plain product `A·x` (star-server step).
    fn matvec(&mut self, x: &Mat) -> &Mat;

    /// Per-histogram L1 marginal error `Σ_i |u∘(A·x) − t|_i`.
    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64>;

    /// Current state (host view).
    fn state(&self) -> &Mat;

    /// Overwrite the state (initialization / restart).
    fn set_state(&mut self, u: &Mat);

    /// Absorption-hybrid counters; `None` for operators without a
    /// stabilized schedule (linear, dense/sparse logsumexp).
    fn stab_stats(&self) -> Option<StabStats> {
        None
    }

    /// Fleet-absorption drift probe over rows `[col0, col0 + rows)` of
    /// the candidate input `x` — `None` for operators without a live
    /// absorbed kernel (non-hybrid schedules, or a hybrid that degraded
    /// to its dense fallback).
    fn fleet_probe(&self, x: &Mat, col0: usize, rows: usize) -> Option<FleetProbe> {
        let _ = (x, col0, rows);
        None
    }

    /// Obey a coordinator-broadcast absorb command: move the absorbed
    /// reference to `gref` with drift capacity `covered` (a cheap
    /// partial reference move when the support allows it, a full
    /// re-truncation otherwise). Returns whether a full rebuild was
    /// paid; no-op (false) for operators without an absorbed kernel.
    fn fleet_absorb(&mut self, gref: &[f64], covered: f64) -> bool {
        let _ = (gref, covered);
        false
    }

    /// Per-column stopping support: irreversibly drop every histogram
    /// column except the selected ones (strictly increasing indices into
    /// the current batch) — state, per-column targets, counters, and
    /// scratch are packed left so subsequent products cost
    /// O(nnz·|active|). The kernel itself is column-count independent
    /// and survives untouched (no rebuild: an absorbed reference keeps
    /// its support and anchor). Returns `false` — and changes nothing —
    /// for operators without compaction support or while a streamed
    /// accumulation is pending; callers then fall back to rebuilding
    /// the operator around a packed state. Per-histogram
    /// `absorb_triggers` of dropped columns are dropped with them; the
    /// scalar counters keep running across the compaction.
    fn compact_columns(&mut self, active: &[usize]) -> bool {
        let _ = active;
        false
    }

    // --- Streamed partial accumulation (`--stream-exchange`) ---------
    //
    // The slice-streaming exchange replaces the all-or-nothing gather
    // barrier: as peer `j`'s frame becomes deliverable, the coordinator
    // folds `A[:, slice_j]·x_j` into a pending product via these hooks,
    // hiding decode + partial compute behind the transfers still in
    // flight. Protocol: `accum_begin`, then one `accum_fold` per slice
    // of a column partition (any order), then exactly one of
    // `accum_update` / `accum_matvec`. A `false` from `accum_fold`
    // means the operator abandoned streaming (e.g. a hybrid drift trip
    // that needs a re-absorption first): the caller must finish
    // assembling the full input and run the ordinary `update`/`matvec`
    // on it instead. The finished streamed product equals the barrier
    // product up to summation-order round-off (≤ 1e-12 in the
    // coordinator pins).

    /// Whether this operator implements the streamed accumulation
    /// protocol. Backends without it (XLA artifact dispatch) keep the
    /// barrier path.
    fn supports_streaming(&self) -> bool {
        false
    }

    /// Reset the pending streamed product.
    fn accum_begin(&mut self) {}

    /// Fold rows `[col0, col0+rows)` of the (conceptual) full input into
    /// the pending product; returns whether streaming is still live.
    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        let _ = (col0, rows, x_slice);
        false
    }

    /// Finish the pending product and apply the damped scaling update —
    /// the streamed equivalent of [`BlockOp::update`] on the assembled
    /// input.
    fn accum_update(&mut self, alpha: f64) -> &Mat {
        let _ = alpha;
        unreachable!("operator does not support streamed accumulation")
    }

    /// Finish the pending product and return it — the streamed
    /// equivalent of [`BlockOp::matvec`] (star-server step).
    fn accum_matvec(&mut self) -> &Mat {
        unreachable!("operator does not support streamed accumulation")
    }

    // --- Greedy top-k updates (`--exchange greedy`) ------------------
    //
    // The greedy schedule updates only the rows whose marginal
    // violation `Σ_h |u∘(A·x) − t|_i` currently ranks in the top-k and
    // leaves every other scaling untouched — the federated Greenkhorn
    // step. Operators maintain the product `A·x` incrementally: the
    // caller passes the x-coordinates that changed since the previous
    // greedy call (its own selection plus every peer coordinate it
    // received), and the operator folds `A[:, changed]·dx` into a
    // cached product at O(k·nnz_col) instead of recomputing the full
    // GEMM. `changed = None` — or any interleaved non-greedy mutation
    // — invalidates the cache and pays one full refresh.

    /// Whether this operator implements greedy top-k updates.
    fn supports_greedy(&self) -> bool {
        false
    }

    /// Refresh per-row violations against `x`, select rows per `spec`,
    /// and apply the damped update on the selected rows only. The new
    /// scalings are read back through [`BlockOp::state`].
    fn greedy_update(
        &mut self,
        x: &Mat,
        alpha: f64,
        spec: GreedySpec,
        changed: Option<&[u32]>,
    ) -> GreedyOutcome {
        let _ = (x, alpha, spec, changed);
        unreachable!("operator does not support greedy updates")
    }
}

/// Backend factory: builds [`BlockOp`]s for client blocks.
pub trait ComputeBackend: Send + Sync {
    /// Bind a linear-domain block operator. `u0` seeds the state
    /// (normally ones).
    fn block_op(
        &self,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>>;

    /// Bind a log-domain block operator: `a_log` is a `log K` block, the
    /// state seed `u0_log` holds log-scalings (normally zeros), and the
    /// target stays a linear-domain marginal slice (its log is taken
    /// internally). Backends without a log path inherit this default and
    /// fail fast with a descriptive error instead of panicking deep in a
    /// solve.
    fn log_block_op(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        let _ = (a_log, t, u0_log);
        anyhow::bail!(
            "backend '{}' does not support the log domain; \
             use --backend native or --domain linear",
            self.name()
        )
    }

    /// Bind a *sparse* log-domain block operator over a truncated
    /// [`LogCsr`] block: the product is a sparse row-wise logsumexp that
    /// touches `nnz` entries instead of `m×n`. Backends without a sparse
    /// log path fail fast with a descriptive error, mirroring
    /// [`ComputeBackend::log_block_op`].
    fn sparse_log_block_op(
        &self,
        a_log: &LogCsr,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        let _ = (a_log, t, u0_log);
        anyhow::bail!(
            "backend '{}' does not support the sparse log domain; \
             use --backend native or --domain linear",
            self.name()
        )
    }

    /// Bind a *stabilized* log-domain operator: the backend is free to
    /// pick the absorption-hybrid schedule (single histogram), the
    /// truncated sparse logsumexp (density below
    /// `stab.sparse_density_cutoff`), or the dense logsumexp — all
    /// numerically equivalent to [`ComputeBackend::log_block_op`] up to
    /// the `θ` truncation. The default ignores `stab` and runs dense.
    fn log_block_op_stabilized(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        let _ = stab;
        self.log_block_op(a_log, t, u0_log)
    }

    /// Dispatch on the numerics domain. `a` must already be in the
    /// matching representation (`Problem::kernel_for` /
    /// `Partition::new_in` take care of that).
    fn block_op_in(
        &self,
        domain: Domain,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        match domain {
            Domain::Linear => self.block_op(a, t, u0),
            Domain::Log => self.log_block_op(a, t, u0),
        }
    }

    /// Stabilized log-domain operator seeded with a pre-built absorbed
    /// kernel (normally [`crate::workload::Problem`]'s per-(θ, τ) cache
    /// entry at the zero reference). Backends with a hybrid schedule
    /// start from the shared support and copy-on-write at the first
    /// re-absorption; the default ignores the seed and falls back to
    /// [`ComputeBackend::log_block_op_stabilized`].
    fn log_block_op_stabilized_seeded(
        &self,
        a_log: &Mat,
        seed: Option<Arc<AbsorbedLogCsr>>,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        let _ = seed;
        self.log_block_op_stabilized(a_log, t, u0_log, stab)
    }

    /// Domain dispatch with the stabilized log path: what the solver and
    /// every coordinator use on the hot path.
    fn block_op_in_stabilized(
        &self,
        domain: Domain,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        match domain {
            Domain::Linear => self.block_op(a, t, u0),
            Domain::Log => self.log_block_op_stabilized(a, t, u0, stab),
        }
    }

    /// Whether [`ComputeBackend::log_block_op`] is implemented natively.
    /// Lets callers resolve `--domain auto` without trial construction.
    fn supports_log(&self) -> bool {
        false
    }

    /// Whether [`ComputeBackend::sparse_log_block_op`] is implemented.
    fn supports_sparse_log(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_spec_parses_counts_and_fractions() {
        assert_eq!(GreedySpec::parse("64").unwrap(), GreedySpec::Count(64));
        assert_eq!(GreedySpec::parse("0.25").unwrap(), GreedySpec::MassFraction(0.25));
        assert!(GreedySpec::parse("0").is_err());
        assert!(GreedySpec::parse("1.0").is_err());
        assert!(GreedySpec::parse("-0.5").is_err());
        assert!(GreedySpec::parse("abc").is_err());
    }

    #[test]
    fn greedy_selection_ranks_by_violation_mass() {
        let viol = [0.1, 4.0, 0.2, 3.0, 0.0, 0.7];
        let top2 = GreedySpec::Count(2).select(&viol);
        assert_eq!(top2.rows, vec![1, 3]);
        assert!((top2.selected_mass - 7.0).abs() < 1e-15);
        assert!((top2.total_mass - 8.0).abs() < 1e-15);
        // The smallest prefix covering 60% of the mass (4.8): {1, 3}.
        let frac = GreedySpec::MassFraction(0.6).select(&viol);
        assert_eq!(frac.rows, vec![1, 3]);
        // Oversized counts clamp to the block; at least one row always.
        assert_eq!(GreedySpec::Count(99).select(&viol).rows.len(), 6);
        assert_eq!(GreedySpec::MassFraction(0.5).select(&[0.0; 4]).rows.len(), 1);
        // Ties break toward the lower index deterministically.
        assert_eq!(GreedySpec::Count(2).select(&[1.0, 1.0, 1.0]).rows, vec![0, 1]);
    }
}
