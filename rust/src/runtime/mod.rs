//! Compute runtime: AOT HLO artifacts through PJRT, plus the native
//! fallback backend.
//!
//! The Rust hot path never runs Python: `make artifacts` (build time)
//! lowers the L2 JAX model to HLO text; here we parse the manifest,
//! compile executables once per shape on the PJRT CPU client, and
//! dispatch block operations through them.
//!
//! Key hot-path design (see EXPERIMENTS.md §Perf): each client's kernel
//! block `A` and target slice `t` are uploaded to the device **once**
//! ([`BlockOp`] construction); per iteration only the gathered scaling
//! state `x` crosses the host↔device boundary, and the evolving state
//! `u` stays device-resident (`execute_b` output buffers are fed back as
//! the next call's inputs).

mod backend;
mod manifest;
mod native;
#[cfg(feature = "xla-backend")]
mod pjrt;
pub mod pool;

pub use backend::{
    BlockOp, ComputeBackend, FleetProbe, GreedyOutcome, GreedySpec, GreedyStats, StabStats, Target,
};
pub use manifest::{Manifest, ManifestEntry};
pub use native::{NativeBackend, HYBRID_MAX_CAPACITY};
pub use pool::Pool;
#[cfg(feature = "xla-backend")]
pub use pjrt::{PjrtRuntime, XlaBackend};

use crate::config::BackendKind;
use std::sync::Arc;

/// Instantiate the configured backend. The XLA backend needs the
/// artifact directory; construction fails fast if the manifest is
/// missing rather than silently degrading. Builds without the
/// `xla-backend` feature (the offline default — the `xla` crate needs
/// the PJRT C library) reject the XLA kind with a clear error.
pub fn make_backend(
    kind: BackendKind,
    artifacts_dir: &str,
    compute_threads: usize,
) -> anyhow::Result<Arc<dyn ComputeBackend>> {
    match kind {
        BackendKind::Native => {
            let _ = artifacts_dir;
            Ok(Arc::new(NativeBackend::new(compute_threads)))
        }
        #[cfg(feature = "xla-backend")]
        BackendKind::Xla => {
            let rt = PjrtRuntime::shared(artifacts_dir)?;
            Ok(Arc::new(XlaBackend::new(rt, compute_threads)))
        }
        #[cfg(not(feature = "xla-backend"))]
        BackendKind::Xla => anyhow::bail!(
            "this build has no xla backend (compile with --features xla-backend); \
             use --backend native"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn sample(m: usize, n: usize, nh: usize, seed: u64) -> (Mat, Mat, Vec<f64>, Mat) {
        let mut rng = Rng::seed_from(seed);
        let a = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let u = Mat::rand_uniform(m, nh, 0.1, 1.0, &mut rng);
        (a, x, t, u)
    }

    #[test]
    fn native_block_op_matches_formula() {
        let (a, x, t, u) = sample(6, 9, 2, 1);
        let be = NativeBackend::new(1);
        let mut op = be
            .block_op(&a, Target::Vec(&t), u.clone())
            .expect("native op");
        let alpha = 0.7;
        let got = op.update(&x, alpha).clone();
        let q = a.matmul(&x, 1);
        for i in 0..6 {
            for j in 0..2 {
                let want = alpha * t[i] / q[(i, j)] + (1.0 - alpha) * u[(i, j)];
                assert!((got[(i, j)] - want).abs() < 1e-12);
            }
        }
        // State advances: a second update must use `got` as u_old.
        let got2 = op.update(&x, alpha).clone();
        for i in 0..6 {
            for j in 0..2 {
                let want = alpha * t[i] / q[(i, j)] + (1.0 - alpha) * got[(i, j)];
                assert!((got2[(i, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn native_matvec_and_marginal() {
        let (a, x, t, u) = sample(4, 5, 3, 2);
        let be = NativeBackend::new(1);
        let mut op = be.block_op(&a, Target::Vec(&t), u.clone()).unwrap();
        let q = op.matvec(&x).clone();
        assert!(q.allclose(&a.matmul(&x, 1), 1e-13));
        let err = op.marginal(&x, &u);
        for h in 0..3 {
            let mut want = 0.0;
            for i in 0..4 {
                want += (u[(i, h)] * q[(i, h)] - t[i]).abs();
            }
            assert!((err[h] - want).abs() < 1e-12, "hist {h}");
        }
    }

    #[test]
    fn native_mat_target() {
        let (a, x, _, u) = sample(5, 7, 2, 3);
        let mut rng = Rng::seed_from(9);
        let tm = Mat::rand_uniform(5, 2, 0.1, 1.0, &mut rng);
        let be = NativeBackend::new(1);
        let mut op = be.block_op(&a, Target::Mat(&tm), u.clone()).unwrap();
        let got = op.update(&x, 1.0).clone();
        let q = a.matmul(&x, 1);
        for i in 0..5 {
            for j in 0..2 {
                assert!((got[(i, j)] - tm[(i, j)] / q[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn native_log_block_op_matches_linear_formula() {
        // On a moderate-range block, the log op must agree with the
        // linear op mapped through exp/ln at α = 1.
        let (a, x, t, _) = sample(6, 9, 2, 21);
        let be = NativeBackend::new(1);
        let a_log = a.map(f64::ln);
        let x_log = x.map(f64::ln);
        let mut lin = be.block_op(&a, Target::Vec(&t), Mat::ones(6, 2)).unwrap();
        let mut log = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(6, 2))
            .unwrap();
        let want = lin.update(&x, 1.0).clone();
        let got = log.update(&x_log, 1.0).clone();
        for i in 0..6 {
            for j in 0..2 {
                assert!(
                    (got[(i, j)].exp() - want[(i, j)]).abs()
                        < 1e-12 * want[(i, j)].abs().max(1.0),
                    "({i},{j}): {} vs {}",
                    got[(i, j)].exp(),
                    want[(i, j)]
                );
            }
        }
        // Marginal errors agree in the linear domain.
        let u_lin = lin.state().clone();
        let u_log = log.state().clone();
        let e_lin = lin.marginal(&x, &u_lin);
        let e_log = log.marginal(&x_log, &u_log);
        for h in 0..2 {
            assert!((e_lin[h] - e_log[h]).abs() < 1e-10, "hist {h}");
        }
    }

    #[test]
    fn native_log_block_op_survives_underflow_range() {
        // Kernel entries around exp(−2000): the linear op would read
        // q = 0 and blow up; the log op stays finite and exact.
        let a_log = Mat::from_vec(2, 2, vec![-2000.0, -2100.0, -2050.0, -2000.0]);
        let t = vec![0.25, 0.75];
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(2, 1))
            .unwrap();
        let got = op.update(&Mat::zeros(2, 1), 1.0).clone();
        assert!(got.as_slice().iter().all(|v| v.is_finite()), "{got:?}");
        // log u ≈ ln t − max-absorbed lse of the row.
        let lse0 = crate::linalg::logsumexp_slice(&[-2000.0, -2100.0]);
        assert!((got[(0, 0)] - (0.25f64.ln() - lse0)).abs() < 1e-9);
    }

    #[test]
    fn sparse_log_block_op_matches_dense_log_op() {
        use crate::linalg::LogCsr;
        // A log block with hard-masked entries (−∞) and a fully masked
        // row: sparse and dense log operators must agree exactly on
        // updates and marginals (the sparse op skips the masked mass the
        // dense op multiplies by zero).
        let mut rng = Rng::seed_from(31);
        let (m, n, nh) = (7, 9, 2);
        let mut a_log = Mat::rand_uniform(m, n, -4.0, 0.0, &mut rng);
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.5 {
                    a_log[(i, j)] = f64::NEG_INFINITY;
                }
            }
        }
        for j in 0..n {
            a_log[(3, j)] = f64::NEG_INFINITY; // fully masked row
        }
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let x_log = Mat::rand_uniform(n, nh, -1.0, 1.0, &mut rng);
        let be = NativeBackend::new(2);
        let lc = LogCsr::from_dense_log(&a_log, f64::NEG_INFINITY);
        let mut dense = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(m, nh))
            .unwrap();
        let mut sparse = be
            .sparse_log_block_op(&lc, Target::Vec(&t), Mat::zeros(m, nh))
            .unwrap();
        let want = dense.update(&x_log, 1.0).clone();
        let got = sparse.update(&x_log, 1.0).clone();
        for i in 0..m {
            for h in 0..nh {
                let (w, g) = (want[(i, h)], got[(i, h)]);
                assert!(
                    (w - g).abs() < 1e-12 || (w.is_infinite() && g == w),
                    "({i},{h}): {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn hybrid_log_op_matches_dense_log_op() {
        use crate::linalg::Stabilization;
        // Single-histogram log block: the stabilized dispatch picks the
        // absorption-hybrid, whose GEMV-on-absorbed-kernel products must
        // reproduce the dense logsumexp to round-off — including across
        // a forced re-absorption (large scaling drift).
        let mut rng = Rng::seed_from(33);
        let (m, n) = (8, 11);
        let a_log = Mat::rand_uniform(m, n, -30.0, 0.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let be = NativeBackend::new(1);
        let stab = Stabilization::default();
        let mut dense = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(m, 1))
            .unwrap();
        let mut hybrid = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), Mat::zeros(m, 1), &stab)
            .unwrap();
        assert!(hybrid.stab_stats().is_some(), "nh=1 must dispatch the hybrid");
        // Drift well past τ = 15 to force at least one re-absorption.
        for shift in [0.0, 0.5, -40.0, -40.2] {
            let x_log = Mat::full(n, 1, shift);
            let want = dense.update(&x_log, 1.0).clone();
            let got = hybrid.update(&x_log, 1.0).clone();
            for i in 0..m {
                assert!(
                    (want[(i, 0)] - got[(i, 0)]).abs() < 1e-10,
                    "shift {shift} row {i}: {} vs {}",
                    got[(i, 0)],
                    want[(i, 0)]
                );
            }
            let u = hybrid.state().clone();
            let e_d = dense.marginal(&x_log, &u);
            let e_h = hybrid.marginal(&x_log, &u);
            assert!((e_d[0] - e_h[0]).abs() < 1e-10);
        }
        let stats = hybrid.stab_stats().unwrap();
        assert!(stats.absorbs >= 1, "the −40 shift must trigger a re-absorption");
        assert_eq!(stats.updates, 4);
        assert!(stats.linear_fraction() < 1.0);
    }

    #[test]
    fn multi_histogram_stabilized_dispatch_stays_exact() {
        use crate::linalg::Stabilization;
        // nh > 1 now routes to the shared-support absorption-hybrid; on
        // an untruncatable moderate-range block its batched GEMM must
        // reproduce the dense logsumexp op to round-off.
        let (a, x, t, _) = sample(6, 9, 3, 41);
        let a_log = a.map(f64::ln);
        let x_log = x.map(f64::ln);
        let be = NativeBackend::new(1);
        let mut plain = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(6, 3))
            .unwrap();
        let mut stab = be
            .log_block_op_stabilized(
                &a_log,
                Target::Vec(&t),
                Mat::zeros(6, 3),
                &Stabilization::default(),
            )
            .unwrap();
        let want = plain.update(&x_log, 1.0).clone();
        let got = stab.update(&x_log, 1.0).clone();
        assert!(got.allclose(&want, 1e-12));
        let stats = stab.stab_stats().expect("nh>1 must dispatch the hybrid now");
        assert_eq!(stats.absorb_triggers.len(), 3, "per-histogram trigger slots");
    }

    #[test]
    fn multi_histogram_hybrid_matches_dense_across_reabsorptions() {
        use crate::linalg::Stabilization;
        // Vectorized hybrid vs. the dense logsumexp op on a wide-range
        // block, driving the scalings through drifts that force both
        // re-absorption tiers (reference moves within and beyond σ).
        let mut rng = Rng::seed_from(47);
        let (m, n, nh) = (9, 12, 4);
        let a_log = Mat::rand_uniform(m, n, -300.0, 0.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let be = NativeBackend::new(1);
        let stab = Stabilization { absorb_threshold: 5.0, ..Stabilization::default() };
        let mut dense = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(m, nh))
            .unwrap();
        let mut hybrid = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), Mat::zeros(m, nh), &stab)
            .unwrap();
        let mut base = 0.0;
        for step in 0..6 {
            // Common drift `base` (exercises the reference move) plus a
            // per-histogram spread (exercises the shared support).
            base -= 4.0 * step as f64;
            let mut x_log = Mat::zeros(n, nh);
            for j in 0..n {
                for h in 0..nh {
                    x_log[(j, h)] = base + rng.uniform_range(-2.0, 2.0) + h as f64;
                }
            }
            let want = dense.update(&x_log, 1.0).clone();
            let got = hybrid.update(&x_log, 1.0).clone();
            for i in 0..m {
                for h in 0..nh {
                    assert!(
                        (want[(i, h)] - got[(i, h)]).abs() < 1e-10,
                        "step {step} ({i},{h}): {} vs {}",
                        got[(i, h)],
                        want[(i, h)]
                    );
                }
            }
        }
        let stats = hybrid.stab_stats().unwrap();
        assert!(stats.absorbs >= 1, "the drifting scalings must re-absorb");
        assert!(
            stats.absorb_triggers.iter().sum::<usize>() >= stats.absorbs,
            "each absorb must record at least one triggering histogram"
        );
    }

    #[test]
    fn fleet_probe_and_absorb_drive_the_hybrid_externally() {
        use crate::linalg::Stabilization;
        // The coordinator-driven surface of the hybrid: slice probes
        // report drift against the absorbed reference (and merge into
        // exactly the full-input decision), and an external absorb
        // command moves the reference like the internal schedule would
        // — products stay equal to the dense logsumexp throughout.
        let mut rng = Rng::seed_from(61);
        let (m, n, nh) = (7, 10, 2);
        let a_log = Mat::rand_uniform(m, n, -30.0, 0.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let be = NativeBackend::new(1);
        let stab = Stabilization { absorb_threshold: 5.0, ..Stabilization::default() };
        let mut dense = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(m, nh))
            .unwrap();
        let mut hybrid = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), Mat::zeros(m, nh), &stab)
            .unwrap();
        // Zero drift at the zero reference: nothing to report.
        let x0 = Mat::zeros(n, nh);
        let p0 = hybrid.fleet_probe(&x0, 0, n).expect("live hybrid probes");
        assert_eq!(p0.drift.len(), nh);
        assert!(p0.drift.iter().all(|&d| d == 0.0));
        assert_eq!(p0.covered, 5.0);
        // Drifted input: two disjoint slice probes must merge into the
        // full-range probe exactly (drift/spread maxima, concatenated
        // reference candidate).
        let mut x = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x[(j, h)] = -12.0 + (j as f64) * 0.1 + h as f64;
            }
        }
        let full = hybrid.fleet_probe(&x, 0, n).unwrap();
        let lo = hybrid.fleet_probe(&x, 0, 5).unwrap();
        let hi = hybrid.fleet_probe(&x, 5, n - 5).unwrap();
        for h in 0..nh {
            assert_eq!(full.drift[h], lo.drift[h].max(hi.drift[h]));
        }
        assert_eq!(full.spread, lo.spread.max(hi.spread));
        let mut gref = lo.gref_slice.clone();
        gref.extend_from_slice(&hi.gref_slice);
        assert_eq!(gref, full.gref_slice);
        assert!(full.drift.iter().any(|&d| d > full.covered));
        // Obey the command; the next update must match dense exactly
        // without re-triggering the internal schedule.
        let rebuilt = hybrid.fleet_absorb(&gref, full.spread + 5.0);
        assert!(rebuilt, "first command moves past the zero anchor");
        let want = dense.update(&x, 1.0).clone();
        let got = hybrid.update(&x, 1.0).clone();
        assert!(got.allclose(&want, 1e-11));
        let stats = hybrid.stab_stats().unwrap();
        assert_eq!(stats.fleet_commands, 1);
        assert_eq!(stats.fleet_rebuilds, 1);
        assert_eq!(stats.absorbs, 1, "the command pre-empted the update's own trigger");
        // Non-hybrid operators expose no fleet surface.
        assert!(dense.fleet_probe(&x, 0, n).is_none());
        assert!(!dense.fleet_absorb(&gref, 10.0));
    }

    #[test]
    fn hybrid_capacity_overflow_falls_back_to_dense() {
        use crate::linalg::Stabilization;
        // τ beyond the representable drift capacity: the hybrid must
        // degrade to the dense logsumexp (identical results, every
        // update counted as non-linear) instead of producing inf/NaN.
        let mut rng = Rng::seed_from(59);
        let (m, n) = (6, 9);
        let a_log = Mat::rand_uniform(m, n, -30.0, 0.0, &mut rng);
        let t: Vec<f64> = (0..m).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let be = NativeBackend::new(1);
        let stab = Stabilization { absorb_threshold: 800.0, ..Stabilization::default() };
        let mut dense = be
            .log_block_op(&a_log, Target::Vec(&t), Mat::zeros(m, 1))
            .unwrap();
        let mut hybrid = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), Mat::zeros(m, 1), &stab)
            .unwrap();
        let x_log = Mat::full(n, 1, -400.0);
        let want = dense.update(&x_log, 1.0).clone();
        let got = hybrid.update(&x_log, 1.0).clone();
        assert!(got.allclose(&want, 1e-12));
        assert!(got.as_slice().iter().all(|v| v.is_finite()));
        let stats = hybrid.stab_stats().unwrap();
        assert_eq!(stats.updates, 1);
        assert_eq!(stats.absorbs, 1, "fallback products count as non-linear");
    }

    #[test]
    fn xla_kind_without_feature_or_artifacts_errors_cleanly() {
        // Whichever is missing (the compiled-out backend or the artifact
        // manifest), asking for XLA from a bogus dir must not panic.
        let r = make_backend(crate::config::BackendKind::Xla, "/nonexistent-artifacts", 1);
        assert!(r.is_err());
    }

    #[test]
    fn set_state_overrides_u() {
        let (a, x, t, u) = sample(3, 4, 1, 5);
        let be = NativeBackend::new(1);
        let mut op = be.block_op(&a, Target::Vec(&t), u).unwrap();
        let fresh = Mat::ones(3, 1);
        op.set_state(&fresh);
        let got = op.update(&x, 0.0).clone(); // alpha 0 → returns state
        assert!(got.allclose(&fresh, 1e-15));
    }
}
