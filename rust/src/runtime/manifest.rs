//! Artifact manifest: what `python -m compile.aot` produced.

use crate::jsonio::{parse, Json};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One AOT-lowered module.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub op: String,
    pub impl_: String,
    pub dtype: String,
    pub m: usize,
    pub n: usize,
    pub nhist: usize,
    pub w: usize,
    pub file: String,
    pub outputs: usize,
}

/// Parsed manifest with shape-keyed lookup.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub grid: String,
    pub entries: Vec<ManifestEntry>,
    /// (op, dtype, m, n, nhist, w) → index, preferring `impl` order
    /// given at insert (xla first — the faster path on this image).
    index: HashMap<(String, String, usize, usize, usize, usize), usize>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts`", path.display()))?;
        let root = parse(&text).context("parsing manifest.json")?;
        let version = root
            .get("version")
            .and_then(Json::as_usize)
            .context("manifest missing version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let grid = root
            .get("grid")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let raw = root
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing entries")?;
        let mut entries = Vec::with_capacity(raw.len());
        for e in raw {
            let gets = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .with_context(|| format!("entry missing {k}"))?
                    .to_string())
            };
            let getn = |k: &str| -> Result<usize> {
                e.get(k)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("entry missing {k}"))
            };
            entries.push(ManifestEntry {
                op: gets("op")?,
                impl_: gets("impl")?,
                dtype: gets("dtype")?,
                m: getn("m")?,
                n: getn("n")?,
                nhist: getn("nhist")?,
                w: getn("w")?,
                file: gets("file")?,
                outputs: getn("outputs")?,
            });
        }
        let mut index = HashMap::new();
        // "xla" impl wins ties (measured faster on CPU PJRT; the pallas
        // artifacts remain addressable via find_impl for the ablation).
        let mut order: Vec<usize> = (0..entries.len()).collect();
        order.sort_by_key(|&i| (entries[i].impl_ != "xla") as u8);
        for i in order {
            let e = &entries[i];
            index
                .entry((e.op.clone(), e.dtype.clone(), e.m, e.n, e.nhist, e.w))
                .or_insert(i);
        }
        Ok(Manifest { dir, grid, entries, index })
    }

    /// Preferred entry for an op at a shape (f64, w=0 unless given).
    pub fn find(&self, op: &str, m: usize, n: usize, nhist: usize) -> Option<&ManifestEntry> {
        self.find_w(op, m, n, nhist, 0)
    }

    pub fn find_w(
        &self,
        op: &str,
        m: usize,
        n: usize,
        nhist: usize,
        w: usize,
    ) -> Option<&ManifestEntry> {
        self.index
            .get(&(op.to_string(), "f64".to_string(), m, n, nhist, w))
            .map(|&i| &self.entries[i])
    }

    /// Entry with a specific impl (ablation benches).
    pub fn find_impl(
        &self,
        op: &str,
        impl_: &str,
        m: usize,
        n: usize,
        nhist: usize,
        w: usize,
    ) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| {
            e.op == op
                && e.impl_ == impl_
                && e.dtype == "f64"
                && e.m == m
                && e.n == n
                && e.nhist == nhist
                && e.w == w
        })
    }

    pub fn path_of(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fedsink-manifest-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn loads_and_indexes() {
        let d = tmpdir("ok");
        write_manifest(
            &d,
            r#"{"version": 1, "grid": "quick", "entries": [
                {"op":"client_update","impl":"pallas","dtype":"f64","m":4,"n":8,"nhist":1,"w":0,"file":"p.hlo.txt","outputs":1},
                {"op":"client_update","impl":"xla","dtype":"f64","m":4,"n":8,"nhist":1,"w":0,"file":"x.hlo.txt","outputs":1}
            ]}"#,
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.entries.len(), 2);
        // xla preferred on ties
        assert_eq!(m.find("client_update", 4, 8, 1).unwrap().impl_, "xla");
        assert_eq!(
            m.find_impl("client_update", "pallas", 4, 8, 1, 0).unwrap().file,
            "p.hlo.txt"
        );
        assert!(m.find("client_update", 4, 8, 2).is_none());
    }

    #[test]
    fn missing_manifest_is_error() {
        let d = tmpdir("missing-sub");
        assert!(Manifest::load(d.join("nope")).is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let d = tmpdir("ver");
        write_manifest(&d, r#"{"version": 9, "entries": []}"#);
        assert!(Manifest::load(&d).is_err());
    }
}
