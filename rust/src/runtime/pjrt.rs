//! PJRT runtime: load HLO text artifacts, compile once, execute from the
//! coordinator hot path.
//!
//! The published `xla` crate (0.1.6) does not mark its PJRT handles
//! `Send`/`Sync` even though the underlying PJRT C API is thread-safe
//! (clients, loaded executables and buffers may be used concurrently —
//! the CPU plugin serializes internally where needed). The coordinator
//! runs one OS thread per federated client, so we wrap the handles and
//! assert thread-safety once, here, with the justification attached.

use super::backend::{BlockOp, ComputeBackend, Target};
use super::manifest::{Manifest, ManifestEntry};
use super::native::NativeBackend;
use crate::linalg::Mat;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// SAFETY: PJRT client/executable/buffer operations are thread-safe per
/// the PJRT C API contract; xla_extension's CPU client takes internal
/// locks. We never share a buffer mutably across threads — each BlockOp
/// owns its buffers and lives on one coordinator thread at a time.
struct SharedClient(xla::PjRtClient);
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}

struct SharedExe(xla::PjRtLoadedExecutable);
unsafe impl Send for SharedExe {}
unsafe impl Sync for SharedExe {}

struct SharedBuf(xla::PjRtBuffer);
unsafe impl Send for SharedBuf {}

/// Shared PJRT state: one CPU client + the artifact manifest + a compile
/// cache (each HLO module is compiled exactly once per process).
pub struct PjrtRuntime {
    client: SharedClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<SharedExe>>>,
}

impl PjrtRuntime {
    pub fn shared(artifacts_dir: &str) -> Result<Arc<Self>> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Self {
            client: SharedClient(client),
            manifest,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&self, entry: &ManifestEntry) -> Result<Arc<SharedExe>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&entry.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .with_context(|| format!("compiling {}", entry.file))?;
        let exe = Arc::new(SharedExe(exe));
        self.cache
            .lock()
            .unwrap()
            .insert(entry.file.clone(), exe.clone());
        Ok(exe)
    }

    fn upload(&self, data: &[f64], dims: &[usize]) -> Result<SharedBuf> {
        Ok(SharedBuf(
            self.client
                .0
                .buffer_from_host_buffer(data, dims, None)
                .context("host→device transfer")?,
        ))
    }

    /// Generic artifact executor over host literals — integration tests
    /// and cold-path ops (objective/plan/sweep). Returns flat f64 vecs.
    pub fn run_entry(&self, entry: &ManifestEntry, inputs: &[xla::Literal]) -> Result<Vec<Vec<f64>>> {
        let exe = self.executable(entry)?;
        let bufs = exe.0.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        let parts = if entry.outputs == 1 {
            vec![lit]
        } else {
            let mut lit = lit;
            lit.decompose_tuple()?
        };
        parts
            .iter()
            .map(|l| l.to_vec::<f64>().map_err(Into::into))
            .collect()
    }
}

/// XLA-executing backend — the "accelerator" of the reproduction.
pub struct XlaBackend {
    rt: Arc<PjrtRuntime>,
    fallback: NativeBackend,
    fallback_threads: usize,
}

impl XlaBackend {
    pub fn new(rt: Arc<PjrtRuntime>, fallback_threads: usize) -> Self {
        Self {
            rt,
            fallback: NativeBackend::new(fallback_threads),
            fallback_threads,
        }
    }

    pub fn runtime(&self) -> &Arc<PjrtRuntime> {
        &self.rt
    }
}

impl ComputeBackend for XlaBackend {
    /// Explicit non-fallback: the AOT artifact grid only lowers the
    /// linear-domain update, and silently routing log-domain solves to
    /// the native CPU kernels would misreport the "accelerator" timing
    /// the paper's §IV-E comparison depends on. Callers must pick
    /// `--backend native` (or `--domain linear`) instead.
    fn log_block_op(
        &self,
        _a_log: &Mat,
        _t: Target<'_>,
        _u0_log: Mat,
    ) -> Result<Box<dyn BlockOp>> {
        anyhow::bail!(
            "the xla backend has no log-domain artifacts (the AOT grid lowers \
             linear-domain updates only); rerun with --backend native, or use \
             --domain linear / --domain auto"
        )
    }

    fn block_op(&self, a: &Mat, t: Target<'_>, u0: Mat) -> Result<Box<dyn BlockOp>> {
        let (m, n, nh) = (a.rows(), a.cols(), u0.cols());
        let (update_op, marginal_op) = match t {
            Target::Vec(_) => ("client_update", "block_marginal"),
            Target::Mat(_) => ("client_update_mat", "block_marginal_mat"),
        };
        let Some(update_entry) = self.rt.manifest().find(update_op, m, n, nh) else {
            // Shape not in the AOT grid: fall back to the native kernels
            // rather than failing the run (logged once per shape).
            eprintln!(
                "warning: no {update_op} artifact for (m={m}, n={n}, N={nh}); native fallback"
            );
            return self.fallback.block_op(a, t, u0);
        };
        let exe_update = self.rt.executable(update_entry)?;
        let exe_matvec = match self.rt.manifest().find("server_matvec", m, n, nh) {
            Some(e) => Some(self.rt.executable(e)?),
            None => None,
        };
        let exe_marginal = match self.rt.manifest().find(marginal_op, m, n, nh) {
            Some(e) => Some(self.rt.executable(e)?),
            None => None,
        };

        let a_buf = self.rt.upload(a.as_slice(), &[m, n])?;
        let (t_buf, t_host, t_stride) = match t {
            Target::Vec(v) => (self.rt.upload(v, &[m])?, v.to_vec(), 0),
            Target::Mat(tm) => (
                self.rt.upload(tm.as_slice(), &[m, nh])?,
                tm.as_slice().to_vec(),
                nh,
            ),
        };
        let u_buf = self.rt.upload(u0.as_slice(), &[m, nh])?;
        Ok(Box::new(XlaBlockOp {
            rt: self.rt.clone(),
            a_host: a.clone(),
            t_host,
            t_stride,
            exe_update,
            exe_matvec,
            exe_marginal,
            a_buf,
            t_buf,
            u_buf,
            u_host: u0,
            q_host: Mat::zeros(m, nh),
            alpha_cache: HashMap::new(),
            threads: self.fallback_threads,
        }))
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

struct XlaBlockOp {
    rt: Arc<PjrtRuntime>,
    /// Host copies for fallback paths (matvec/marginal without artifacts).
    a_host: Mat,
    t_host: Vec<f64>,
    t_stride: usize,
    exe_update: Arc<SharedExe>,
    exe_matvec: Option<Arc<SharedExe>>,
    exe_marginal: Option<Arc<SharedExe>>,
    a_buf: SharedBuf,
    t_buf: SharedBuf,
    /// Device-resident evolving state; replaced by each update's output
    /// buffer, so `u` never round-trips through the host on the hot path
    /// (the host mirror is refreshed for the return value / comms).
    u_buf: SharedBuf,
    u_host: Mat,
    q_host: Mat,
    /// Device scalars for each distinct damping factor seen.
    alpha_cache: HashMap<u64, SharedBuf>,
    threads: usize,
}

impl XlaBlockOp {
    fn read_into(buf: &SharedBuf, out: &mut Mat) -> Result<()> {
        // §Perf note: `copy_raw_to_host_sync` (a direct device→host
        // copy) would skip the intermediate Literal, but the TFRT CPU
        // plugin reports `CopyRawToHost not implemented`, so the
        // readback goes through a Literal into the preallocated mirror.
        let lit = buf.0.to_literal_sync()?;
        lit.copy_raw_to::<f64>(out.as_mut_slice())?;
        Ok(())
    }
}

impl BlockOp for XlaBlockOp {
    fn m(&self) -> usize {
        self.a_host.rows()
    }

    fn n(&self) -> usize {
        self.a_host.cols()
    }

    fn hists(&self) -> usize {
        self.u_host.cols()
    }

    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat {
        let n = self.n();
        let nh = self.hists();
        assert_eq!(x.rows(), n);
        assert_eq!(x.cols(), nh);
        let mut go = || -> Result<SharedBuf> {
            let x_buf = self.rt.upload(x.as_slice(), &[n, nh])?;
            let alpha_key = alpha.to_bits();
            if !self.alpha_cache.contains_key(&alpha_key) {
                let buf = self.rt.upload(&[alpha], &[1])?;
                self.alpha_cache.insert(alpha_key, buf);
            }
            let alpha_buf = &self.alpha_cache[&alpha_key];
            let outs = self.exe_update.0.execute_b(&[
                &self.a_buf.0,
                &x_buf.0,
                &self.t_buf.0,
                &self.u_buf.0,
                &alpha_buf.0,
            ])?;
            let out = outs.into_iter().next().unwrap().into_iter().next().unwrap();
            Ok(SharedBuf(out))
        };
        let out = go().expect("xla update failed");
        self.u_buf = out;
        Self::read_into(&self.u_buf, &mut self.u_host).expect("device→host read");
        &self.u_host
    }

    fn matvec(&mut self, x: &Mat) -> &Mat {
        let n = self.n();
        let nh = self.hists();
        if let Some(exe) = self.exe_matvec.clone() {
            let x_buf = self.rt.upload(x.as_slice(), &[n, nh]).expect("x upload");
            let outs = exe.0.execute_b(&[&self.a_buf.0, &x_buf.0]).expect("xla matvec");
            let out = SharedBuf(outs.into_iter().next().unwrap().into_iter().next().unwrap());
            Self::read_into(&out, &mut self.q_host).expect("device→host read");
        } else {
            let mut q = std::mem::replace(&mut self.q_host, Mat::zeros(0, 0));
            self.a_host.matmul_into(x, &mut q, self.threads);
            self.q_host = q;
        }
        &self.q_host
    }

    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64> {
        let n = self.n();
        let nh = self.hists();
        if let Some(exe) = &self.exe_marginal {
            let go = || -> Result<Vec<f64>> {
                let x_buf = self.rt.upload(x.as_slice(), &[n, nh])?;
                let u_buf = self.rt.upload(u.as_slice(), &[self.m(), nh])?;
                let outs = exe.0.execute_b(&[&self.a_buf.0, &x_buf.0, &u_buf.0, &self.t_buf.0])?;
                let lit = outs[0][0].to_literal_sync()?;
                Ok(lit.to_vec::<f64>()?)
            };
            go().expect("xla marginal failed")
        } else {
            // Native reduction over A·x.
            let mut q = std::mem::replace(&mut self.q_host, Mat::zeros(0, 0));
            self.a_host.matmul_into(x, &mut q, self.threads);
            let mut err = vec![0.0; nh];
            for i in 0..self.m() {
                let qrow = q.row(i);
                let urow = u.row(i);
                if self.t_stride == 0 {
                    let ti = self.t_host[i];
                    for h in 0..nh {
                        err[h] += (urow[h] * qrow[h] - ti).abs();
                    }
                } else {
                    let trow = &self.t_host[i * self.t_stride..(i + 1) * self.t_stride];
                    for h in 0..nh {
                        err[h] += (urow[h] * qrow[h] - trow[h]).abs();
                    }
                }
            }
            self.q_host = q;
            err
        }
    }

    fn state(&self) -> &Mat {
        &self.u_host
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u_host.rows());
        assert_eq!(u.cols(), self.u_host.cols());
        self.u_host = u.clone();
        self.u_buf = self
            .rt
            .upload(u.as_slice(), &[u.rows(), u.cols()])
            .expect("state upload");
    }
}
