//! Pure-Rust compute backend.
//!
//! Reference semantics for the XLA path, the arbitrary-shape fallback,
//! and the deliberately CPU-speed substrate for the paper's §IV-E study
//! (where slower compute flips the comm/comp balance). Uses the blocked
//! GEMM/CSR kernels from [`crate::linalg`]; switches to CSR automatically
//! when the block is sparse enough to win. This is also the only backend
//! with a native log-domain operator (row-wise max-absorbed logsumexp) —
//! the small-ε path the AOT artifact grid does not cover.

use super::backend::{BlockOp, ComputeBackend, Target};
use crate::linalg::{Csr, Mat};

/// In-place damped update: `u = α·t/q + (1−α)·u`.
fn scale_divide_inplace(t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let (m, nh) = (q.rows(), q.cols());
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let ti = t[i];
            for j in 0..nh {
                urow[j] = alpha * (ti / qrow[j]) + beta * urow[j];
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (trow[j] / qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Density below which CSR dispatch beats dense GEMM for this shape.
/// Measured in bench_kernels (n=1024): dense wins at density 0.31
/// (s=0.9), CSR wins at 0.25 (s=1.0) — cutoff set between them.
const CSR_DENSITY_CUTOFF: f64 = 0.27;

pub struct NativeBackend {
    threads: usize,
}

impl NativeBackend {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }
}

impl ComputeBackend for NativeBackend {
    fn log_block_op(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(t.rows() == a_log.rows(), "target rows != block rows");
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, t_stride) = match t {
            Target::Vec(v) => (v.to_vec(), 0),
            Target::Mat(m) => {
                anyhow::ensure!(m.cols() == u0_log.cols(), "target hists != state hists");
                (m.as_slice().to_vec(), m.cols())
            }
        };
        let log_t: Vec<f64> = t_lin.iter().map(|&x| x.ln()).collect();
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            threads: self.threads,
        }))
    }

    fn supports_log(&self) -> bool {
        true
    }

    fn block_op(
        &self,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(t.rows() == a.rows(), "target rows != block rows");
        anyhow::ensure!(u0.rows() == a.rows(), "state rows != block rows");
        let csr = Csr::from_dense(a, 0.0);
        let csr = (csr.density() < CSR_DENSITY_CUTOFF).then_some(csr);
        let (t_data, t_stride) = match t {
            Target::Vec(v) => (v.to_vec(), 0),
            Target::Mat(m) => {
                anyhow::ensure!(m.cols() == u0.cols(), "target hists != state hists");
                (m.as_slice().to_vec(), m.cols())
            }
        };
        let q = Mat::zeros(a.rows(), u0.cols());
        Ok(Box::new(NativeBlockOp {
            a: a.clone(),
            csr,
            t: t_data,
            t_stride,
            u: u0,
            q,
            threads: self.threads,
        }))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

struct NativeBlockOp {
    a: Mat,
    csr: Option<Csr>,
    t: Vec<f64>,
    t_stride: usize,
    u: Mat,
    /// Preallocated product buffer — the hot loop never allocates.
    q: Mat,
    threads: usize,
}

impl NativeBlockOp {
    fn product(&mut self, x: &Mat) {
        match &self.csr {
            Some(csr) => csr.matmul_into(x, &mut self.q, self.threads),
            None => self.a.matmul_into(x, &mut self.q, self.threads),
        }
    }
}

impl BlockOp for NativeBlockOp {
    fn m(&self) -> usize {
        self.a.rows()
    }

    fn n(&self) -> usize {
        self.a.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat {
        self.product(x);
        // u = α t/q + (1−α) u, in place over the state buffer (element-
        // wise, so aliasing u_old with u_out is safe — no allocation).
        scale_divide_inplace(&self.t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn matvec(&mut self, x: &Mat) -> &Mat {
        self.product(x);
        &self.q
    }

    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64> {
        self.product(x);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u.row(i);
            if self.t_stride == 0 {
                let ti = self.t[i];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - ti).abs();
                }
            } else {
                let trow = &self.t[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}

/// Log-domain twin of [`NativeBlockOp`]: the block is `log K`, the state
/// holds log-scalings, and the product is the row-wise max-absorbed
/// logsumexp (Schmitzer's stabilized scaling — the running maximum of
/// `log K + log x` is absorbed into the exponent so every `exp` argument
/// is ≤ 0; no kernel entry ever underflows).
struct NativeLogBlockOp {
    a_log: Mat,
    /// Linear-domain target (for the marginal error) …
    t_lin: Vec<f64>,
    /// … and its log (for the update).
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    threads: usize,
}

impl NativeLogBlockOp {
    fn product(&mut self, x_log: &Mat) {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.threads);
    }
}

impl BlockOp for NativeLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log);
        // log u = α (log t − q) + (1−α) log u, in place (element-wise, so
        // aliasing old and new state is safe). Note α < 1 damps the
        // *duals* — geometrically in the linear domain — which coincides
        // with linear damping at α = 1 (the Prop.-1 regime).
        let (m, nh) = (self.q.rows(), self.q.cols());
        let beta = 1.0 - alpha;
        for i in 0..m {
            let qrow = self.q.row(i);
            let urow = self.u.row_mut(i);
            if self.t_stride == 0 {
                let lti = self.log_t[i];
                for j in 0..nh {
                    urow[j] = alpha * (lti - qrow[j]) + beta * urow[j];
                }
            } else {
                let ltrow = &self.log_t[i * self.t_stride..(i + 1) * self.t_stride];
                for j in 0..nh {
                    urow[j] = alpha * (ltrow[j] - qrow[j]) + beta * urow[j];
                }
            }
        }
        &self.u
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log);
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log);
        // Linear-domain L1 error: |exp(log u + q) − t| per entry. The
        // exponent log u + q is the log of a marginal entry — O(log t)
        // near the fixed point — so the exp cannot overflow there.
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}
