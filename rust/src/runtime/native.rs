//! Pure-Rust compute backend.
//!
//! Reference semantics for the XLA path, the arbitrary-shape fallback,
//! and the deliberately CPU-speed substrate for the paper's §IV-E study
//! (where slower compute flips the comm/comp balance). Uses the blocked
//! GEMM/CSR kernels from [`crate::linalg`]; switches to CSR automatically
//! when the block is sparse enough to win. This is also the only backend
//! with a native log-domain operator (row-wise max-absorbed logsumexp) —
//! the small-ε path the AOT artifact grid does not cover.

use super::backend::{
    BlockOp, ComputeBackend, FleetProbe, GreedyOutcome, GreedySpec, StabStats, Target,
};
use super::pool::Pool;
use crate::linalg::{AbsorbedLogCsr, Csr, LogCsr, Mat, Stabilization};
use std::sync::Arc;

/// In-place damped update: `u = α·t/q + (1−α)·u`.
fn scale_divide_inplace(t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let (m, nh) = (q.rows(), q.cols());
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let ti = t[i];
            for j in 0..nh {
                urow[j] = alpha * (ti / qrow[j]) + beta * urow[j];
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (trow[j] / qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// In-place damped log-domain update: `log u = α·(log t − q) + (1−α)·
/// log u` (element-wise, so aliasing old and new state is safe). The
/// one implementation behind every log operator's `update` — barrier
/// and streamed paths must apply byte-identical arithmetic.
fn damped_log_subtract_inplace(log_t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let (m, nh) = (q.rows(), q.cols());
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let lti = log_t[i];
            for j in 0..nh {
                urow[j] = alpha * (lti - qrow[j]) + beta * urow[j];
            }
        } else {
            let ltrow = &log_t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (ltrow[j] - qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Resolve online-logsumexp accumulators into the product buffer:
/// `q = mx + ln sum` (−∞ where no mass was folded).
fn finish_lse_accum(mx: &[f64], sum: &[f64], q: &mut Mat) {
    for (o, (m, s)) in q.as_mut_slice().iter_mut().zip(mx.iter().zip(sum)) {
        *o = if *s > 0.0 { m + s.ln() } else { f64::NEG_INFINITY };
    }
}

/// Per-row linear-domain marginal violation `Σ_h |u∘q − t|_i` — the
/// ranking the greedy top-k schedule selects on. One entry per block
/// row, matching `Σ_i viol[i] = Σ_h marginal(x, u)[h]`.
fn row_violations_linear(t: &[f64], t_stride: usize, q: &Mat, u: &Mat, viol: &mut Vec<f64>) {
    let (m, nh) = (q.rows(), q.cols());
    viol.resize(m, 0.0);
    for (i, slot) in viol.iter_mut().enumerate() {
        let qrow = q.row(i);
        let urow = u.row(i);
        let mut v = 0.0;
        if t_stride == 0 {
            let ti = t[i];
            for h in 0..nh {
                v += (urow[h] * qrow[h] - ti).abs();
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for h in 0..nh {
                v += (urow[h] * qrow[h] - trow[h]).abs();
            }
        }
        *slot = v;
    }
}

/// Log-domain twin of [`row_violations_linear`]:
/// `Σ_h |exp(log u + q) − t|_i` per row.
fn row_violations_log(t_lin: &[f64], t_stride: usize, q: &Mat, u: &Mat, viol: &mut Vec<f64>) {
    let (m, nh) = (q.rows(), q.cols());
    viol.resize(m, 0.0);
    for (i, slot) in viol.iter_mut().enumerate() {
        let qrow = q.row(i);
        let urow = u.row(i);
        let mut v = 0.0;
        if t_stride == 0 {
            let ti = t_lin[i];
            for h in 0..nh {
                v += ((urow[h] + qrow[h]).exp() - ti).abs();
            }
        } else {
            let trow = &t_lin[i * t_stride..(i + 1) * t_stride];
            for h in 0..nh {
                v += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
            }
        }
        *slot = v;
    }
}

/// Damped update restricted to `rows`: the selected rows move exactly
/// as [`scale_divide_inplace`] would move them; every other scaling
/// stays untouched — the greedy (Greenkhorn-style) half-step.
fn scale_divide_rows(rows: &[u32], t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let nh = q.cols();
    let beta = 1.0 - alpha;
    for &ri in rows {
        let i = ri as usize;
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let ti = t[i];
            for j in 0..nh {
                urow[j] = alpha * (ti / qrow[j]) + beta * urow[j];
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (trow[j] / qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Log-domain twin of [`scale_divide_rows`]: selected rows move exactly
/// as [`damped_log_subtract_inplace`] would move them.
fn damped_log_subtract_rows(
    rows: &[u32],
    log_t: &[f64],
    t_stride: usize,
    q: &Mat,
    alpha: f64,
    u: &mut Mat,
) {
    let nh = q.cols();
    let beta = 1.0 - alpha;
    for &ri in rows {
        let i = ri as usize;
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let lti = log_t[i];
            for j in 0..nh {
                urow[j] = alpha * (lti - qrow[j]) + beta * urow[j];
            }
        } else {
            let ltrow = &log_t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (ltrow[j] - qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Debug-only contract check of the greedy incremental protocol: every
/// coordinate of `x` outside `changed` must still equal the cached
/// snapshot `gx` — a caller that moved a coordinate without declaring
/// it would silently corrupt the maintained product.
#[cfg(debug_assertions)]
fn debug_assert_changed_covers(x: &Mat, gx: &Mat, changed: &[u32]) {
    let mut it = changed.iter().peekable();
    for j in 0..x.rows() {
        if it.peek() == Some(&&(j as u32)) {
            it.next();
            continue;
        }
        debug_assert_eq!(
            x.row(j),
            gx.row(j),
            "coordinate {j} moved outside the declared `changed` set"
        );
    }
}
#[cfg(not(debug_assertions))]
fn debug_assert_changed_covers(_x: &Mat, _gx: &Mat, _changed: &[u32]) {}

/// Density below which CSR dispatch beats dense GEMM for this shape.
/// Measured in bench_kernels (n=1024): dense wins at density 0.31
/// (s=0.9), CSR wins at 0.25 (s=1.0) — cutoff set between them.
const CSR_DENSITY_CUTOFF: f64 = 0.27;

// Threaded absorbed-GEMM autotuning: the banded SpMM only amortizes
// its dispatch overhead above the pool-calibrated crossover in
// stored-entry FMAs (`nnz·N`) — see [`Pool::threads_for_work`], which
// measures the hand-off cost once at pool construction and can be
// pinned via `FEDSINK_PAR_MIN_WORK`. The hybrid dispatch picks threads
// per shape from it, the way the CSR path picks its representation
// from the measured [`CSR_DENSITY_CUTOFF`].

/// Drift-capacity ceiling for the shared-support hybrid: the
/// per-histogram corrections `exp(x − ḡ)` and the row sums they feed
/// must stay inside f64's normal range (|exponent| ≲ 709, with headroom
/// for the n-term sum and the support slack). A tuning or an
/// inter-histogram dual spread that needs more capacity has no
/// numerically safe shared support — the operator then falls back to
/// the dense logsumexp permanently instead of silently producing
/// inf/NaN iterates.
pub const HYBRID_MAX_CAPACITY: f64 = 300.0;

/// Whether a shared support with anchor budget `sigma` can represent
/// drift capacity `needed`: the per-histogram corrections must stay
/// inside f64's exponent range ([`HYBRID_MAX_CAPACITY`]) *and* the
/// truncation slack `θ − 2(σ + needed)` must stay above
/// [`crate::linalg::THETA_SUPPORT_FLOOR`] so no stored absorbed entry
/// underflows into a degenerate (structurally kept, numerically zero)
/// support. A tuning that fails either bound has no numerically safe
/// shared support and the operator degrades to the dense logsumexp.
fn fits_support(theta: f64, sigma: f64, needed: f64) -> bool {
    needed.is_finite()
        && needed <= HYBRID_MAX_CAPACITY
        && needed <= AbsorbedLogCsr::max_covered(theta, sigma)
}

/// Column-mean reference candidate and inter-histogram spread over rows
/// `[r0, r0 + rows)` of the log-scalings `x`, written into
/// `gref[..rows]`; returns the spread. The ONE implementation shared by
/// the hybrid's internal schedule (full range, scratch buffer) and the
/// slice-local fleet probe — slice results merge into exactly the
/// full-range result only while both sides compute identically, so
/// there must be a single copy of this arithmetic.
fn reference_candidate(x: &Mat, r0: usize, rows: usize, gref: &mut [f64]) -> f64 {
    let nh = x.cols();
    debug_assert_eq!(gref.len(), rows);
    let xs = x.as_slice();
    let inv = 1.0 / nh as f64;
    let mut spread: f64 = 0.0;
    for (slot, j) in gref.iter_mut().zip(r0..r0 + rows) {
        let xrow = &xs[j * nh..(j + 1) * nh];
        let mean = xrow.iter().sum::<f64>() * inv;
        *slot = mean;
        for &xv in xrow {
            let s = (xv - mean).abs();
            if s > spread {
                spread = s;
            }
        }
    }
    spread
}

pub struct NativeBackend {
    /// Handle onto the process-wide persistent worker pool, scoped to
    /// this backend's share of the cores (the per-node share under a
    /// federated simulation). Every op clones it — kernels dispatch
    /// bands onto resident workers instead of spawning per call.
    pool: Pool,
}

impl NativeBackend {
    pub fn new(threads: usize) -> Self {
        Self { pool: Pool::global().with_share(threads.max(1)) }
    }
}

/// Extract the linear target, its log, and the broadcast stride from a
/// [`Target`] — shared by every log-domain operator.
fn log_targets(
    t: Target<'_>,
    m: usize,
    nh: usize,
) -> anyhow::Result<(Vec<f64>, Vec<f64>, usize)> {
    anyhow::ensure!(t.rows() == m, "target rows != block rows");
    let (t_lin, t_stride) = match t {
        Target::Vec(v) => (v.to_vec(), 0),
        Target::Mat(mat) => {
            anyhow::ensure!(mat.cols() == nh, "target hists != state hists");
            (mat.as_slice().to_vec(), mat.cols())
        }
    };
    let log_t: Vec<f64> = t_lin.iter().map(|&x| x.ln()).collect();
    Ok((t_lin, log_t, t_stride))
}

impl ComputeBackend for NativeBackend {
    fn log_block_op(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            acc_mx: Vec::new(),
            acc_sum: Vec::new(),
            gviol: Vec::new(),
            pool: self.pool.clone(),
        }))
    }

    fn supports_log(&self) -> bool {
        true
    }

    fn supports_sparse_log(&self) -> bool {
        true
    }

    fn sparse_log_block_op(
        &self,
        a_log: &LogCsr,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeSparseLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            acc_mx: Vec::new(),
            acc_sum: Vec::new(),
            gq: Mat::zeros(0, 0),
            gq_rows: Vec::new(),
            gviol: Vec::new(),
            since_refresh: 0,
            greedy_live: false,
            pool: self.pool.clone(),
        }))
    }

    /// Stabilized log-domain dispatch: the absorption-hybrid schedule
    /// for any histogram count when enabled, the truncated sparse
    /// logsumexp when the hybrid is off and the block is sparse enough,
    /// dense logsumexp otherwise.
    fn log_block_op_stabilized(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        self.log_block_op_stabilized_seeded(a_log, None, t, u0_log, stab)
    }

    /// Seeded stabilized dispatch: a matching pre-built absorbed kernel
    /// (the problem's per-(θ, τ) zero-reference cache entry) is shared
    /// copy-on-write until the first re-absorption, so multi-solve
    /// experiments truncate each kernel exactly once.
    fn log_block_op_stabilized_seeded(
        &self,
        a_log: &Mat,
        seed: Option<Arc<AbsorbedLogCsr>>,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        if stab.hybrid_enabled() {
            anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
            let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
            return Ok(Box::new(HybridLogBlockOp::new(
                a_log.clone(),
                t_lin,
                log_t,
                t_stride,
                u0_log,
                seed,
                stab,
                self.pool.clone(),
            )));
        }
        // Cheap non-allocating probe first; only build the CSR when the
        // sparse path actually wins.
        if stab.sparse_density_cutoff > 0.0
            && LogCsr::density_of(a_log, stab.truncation_theta) < stab.sparse_density_cutoff
        {
            let truncated = LogCsr::from_dense_log(a_log, stab.truncation_theta);
            return self.sparse_log_block_op(&truncated, t, u0_log);
        }
        self.log_block_op(a_log, t, u0_log)
    }

    fn block_op(
        &self,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(t.rows() == a.rows(), "target rows != block rows");
        anyhow::ensure!(u0.rows() == a.rows(), "state rows != block rows");
        let csr = Csr::from_dense(a, 0.0);
        let csr = (csr.density() < CSR_DENSITY_CUTOFF).then_some(csr);
        let (t_data, t_stride) = match t {
            Target::Vec(v) => (v.to_vec(), 0),
            Target::Mat(m) => {
                anyhow::ensure!(m.cols() == u0.cols(), "target hists != state hists");
                (m.as_slice().to_vec(), m.cols())
            }
        };
        let q = Mat::zeros(a.rows(), u0.cols());
        Ok(Box::new(NativeBlockOp {
            a: a.clone(),
            csr,
            t: t_data,
            t_stride,
            u: u0,
            q,
            acc: Mat::zeros(0, 0),
            gq: Mat::zeros(0, 0),
            gx: Mat::zeros(0, 0),
            gviol: Vec::new(),
            greedy_live: false,
            pool: self.pool.clone(),
        }))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

struct NativeBlockOp {
    a: Mat,
    csr: Option<Csr>,
    t: Vec<f64>,
    t_stride: usize,
    u: Mat,
    /// Preallocated product buffer — the hot loop never allocates.
    q: Mat,
    /// Streamed-exchange accumulator, distinct from `q` so a marginal
    /// check between folds (its product writes `q`) cannot clobber a
    /// pending accumulation. Allocated lazily — only streamed runs pay.
    acc: Mat,
    /// Greedy-schedule cache (lazy — only `--exchange greedy` pays):
    /// the maintained product `A·gx`, its input snapshot, and the
    /// per-row violation scratch. `gq` is kept coherent against `gx` by
    /// folding `A[:, changed]·dx` per greedy call; it is distinct from
    /// `q` so interleaved marginal checks cannot clobber it.
    gq: Mat,
    gx: Mat,
    gviol: Vec<f64>,
    greedy_live: bool,
    pool: Pool,
}

impl NativeBlockOp {
    fn product(&mut self, x: &Mat) {
        let threads = self.pool.share();
        match &self.csr {
            Some(csr) => csr.matmul_into(x, &mut self.q, threads),
            None => self.a.matmul_into(x, &mut self.q, threads),
        }
    }
}

impl BlockOp for NativeBlockOp {
    fn m(&self) -> usize {
        self.a.rows()
    }

    fn n(&self) -> usize {
        self.a.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat {
        self.product(x);
        // u = α t/q + (1−α) u, in place over the state buffer (element-
        // wise, so aliasing u_old with u_out is safe — no allocation).
        scale_divide_inplace(&self.t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn matvec(&mut self, x: &Mat) -> &Mat {
        self.product(x);
        &self.q
    }

    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64> {
        self.product(x);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u.row(i);
            if self.t_stride == 0 {
                let ti = self.t[i];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - ti).abs();
                }
            } else {
                let trow = &self.t[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        if self.acc.rows() != self.a.rows() {
            self.acc = Mat::zeros(self.a.rows(), self.u.cols());
        } else {
            self.acc.as_mut_slice().fill(0.0);
        }
    }

    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        let nh = self.u.cols();
        let threads = self.pool.share();
        let acc = self.acc.as_mut_slice();
        match &self.csr {
            Some(csr) => csr.matmul_fold(col0, rows, x_slice, nh, acc, threads),
            None => self.a.matmul_fold(col0, rows, x_slice, nh, acc, threads),
        }
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        scale_divide_inplace(&self.t, self.t_stride, &self.acc, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        &self.acc
    }

    fn supports_greedy(&self) -> bool {
        true
    }

    /// Greedy top-k half-step: maintain `gq = A·x` incrementally
    /// (`gq += A[:, changed]·dx` at O(k·nnz_col) when the caller
    /// declares the moved coordinates), rank rows by marginal
    /// violation, and damp only the selected rows.
    fn greedy_update(
        &mut self,
        x: &Mat,
        alpha: f64,
        spec: GreedySpec,
        changed: Option<&[u32]>,
    ) -> GreedyOutcome {
        let nh = self.u.cols();
        let threads = self.pool.share();
        match changed {
            Some(changed) if self.greedy_live => {
                debug_assert_changed_covers(x, &self.gx, changed);
                let mut dx = Vec::with_capacity(changed.len() * nh);
                for &j in changed {
                    let (new, old) = (x.row(j as usize), self.gx.row(j as usize));
                    for h in 0..nh {
                        dx.push(new[h] - old[h]);
                    }
                }
                match &self.csr {
                    Some(csr) => {
                        csr.matmul_delta_cols(changed, &dx, nh, self.gq.as_mut_slice(), threads)
                    }
                    None => {
                        self.a.matmul_delta_cols(changed, &dx, nh, self.gq.as_mut_slice(), threads)
                    }
                }
                for &j in changed {
                    self.gx.row_mut(j as usize).copy_from_slice(x.row(j as usize));
                }
            }
            _ => {
                if self.gq.rows() != self.a.rows() {
                    self.gq = Mat::zeros(self.a.rows(), nh);
                }
                match &self.csr {
                    Some(csr) => csr.matmul_into(x, &mut self.gq, threads),
                    None => self.a.matmul_into(x, &mut self.gq, threads),
                }
                self.gx = x.clone();
                self.greedy_live = true;
            }
        }
        row_violations_linear(&self.t, self.t_stride, &self.gq, &self.u, &mut self.gviol);
        let outcome = spec.select(&self.gviol);
        scale_divide_rows(&outcome.rows, &self.t, self.t_stride, &self.gq, alpha, &mut self.u);
        outcome
    }
}

/// Sparse twin of [`NativeLogBlockOp`]: the block is a θ-truncated
/// [`LogCsr`], the product a sparse row-wise max-absorbed logsumexp over
/// the stored entries only — O(nnz) instead of O(m·n) per iteration.
struct NativeSparseLogBlockOp {
    a_log: LogCsr,
    t_lin: Vec<f64>,
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    /// Streamed-exchange online-LSE accumulators (running max + scaled
    /// sum), distinct from `q` so marginal checks cannot clobber a
    /// pending accumulation. Lazily allocated.
    acc_mx: Vec<f64>,
    acc_sum: Vec<f64>,
    /// Greedy-schedule tracker (lazy): the log product `gq` refreshed
    /// exactly on the rows each greedy call updates (row-subset
    /// logsumexp, O(k·nnz_row)) and fully every
    /// [`GREEDY_REFRESH_EVERY`] calls — online-LSE row products cannot
    /// be downdated coordinate-wise, so unselected rows rank on a
    /// boundedly stale violation between full refreshes.
    gq: Mat,
    gq_rows: Vec<f64>,
    gviol: Vec<f64>,
    since_refresh: usize,
    greedy_live: bool,
    pool: Pool,
}

/// Full-refresh cadence of the sparse-log greedy tracker: every this
/// many greedy calls the whole O(nnz) product is recomputed so no
/// row's violation ranking can stay stale longer — amortized cost
/// O(nnz / GREEDY_REFRESH_EVERY + k·nnz_row) per call.
const GREEDY_REFRESH_EVERY: usize = 8;

impl NativeSparseLogBlockOp {
    fn accum_finish(&mut self) {
        finish_lse_accum(&self.acc_mx, &self.acc_sum, &mut self.q);
    }
}

impl BlockOp for NativeSparseLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        let len = self.a_log.rows() * self.u.cols();
        self.acc_mx.resize(len, 0.0);
        self.acc_sum.resize(len, 0.0);
        self.acc_mx.fill(f64::NEG_INFINITY);
        self.acc_sum.fill(0.0);
    }

    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        self.a_log.logsumexp_fold(
            col0,
            rows,
            x_slice,
            self.u.cols(),
            &mut self.acc_mx,
            &mut self.acc_sum,
            self.pool.share(),
        );
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        self.accum_finish();
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        self.accum_finish();
        &self.q
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    fn supports_greedy(&self) -> bool {
        true
    }

    /// Greedy top-k half-step on the truncated sparse block: select on
    /// the (boundedly stale) tracker, recompute the selected rows'
    /// log products exactly via the row-subset logsumexp, damp those
    /// rows. Selection staleness only reorders the heuristic ranking —
    /// the applied updates are always exact against the current `x`.
    fn greedy_update(
        &mut self,
        x_log: &Mat,
        alpha: f64,
        spec: GreedySpec,
        changed: Option<&[u32]>,
    ) -> GreedyOutcome {
        let nh = self.u.cols();
        let full = !self.greedy_live
            || changed.is_none()
            || self.since_refresh >= GREEDY_REFRESH_EVERY;
        if full {
            if self.gq.rows() != self.a_log.rows() {
                self.gq = Mat::zeros(self.a_log.rows(), nh);
            }
            self.a_log.logsumexp_into(x_log, &mut self.gq, self.pool.share());
            self.greedy_live = true;
            self.since_refresh = 0;
        }
        self.since_refresh += 1;
        row_violations_log(&self.t_lin, self.t_stride, &self.gq, &self.u, &mut self.gviol);
        let outcome = spec.select(&self.gviol);
        if !full {
            self.gq_rows.resize(outcome.rows.len() * nh, 0.0);
            self.a_log.logsumexp_rows(&outcome.rows, x_log, &mut self.gq_rows, self.pool.share());
            for (s, &ri) in outcome.rows.iter().enumerate() {
                self.gq
                    .row_mut(ri as usize)
                    .copy_from_slice(&self.gq_rows[s * nh..(s + 1) * nh]);
            }
        }
        damped_log_subtract_rows(
            &outcome.rows,
            &self.log_t,
            self.t_stride,
            &self.gq,
            alpha,
            &mut self.u,
        );
        outcome
    }
}

/// Absorption-hybrid log-domain operator (Schmitzer §3, the scaling
/// counterpart of the paper's small-ε regime), vectorized across `N`
/// histograms over a **shared-support** [`AbsorbedLogCsr`]: one
/// reference dual `ḡ` (the column-wise mean of the incoming
/// log-scalings) is absorbed and truncated once, and iterations run as
/// the batched sparse GEMM `q̃ = K̃ · exp(x − ḡ)` with per-histogram
/// column corrections — `log(K·x) = f̄ + ln q̃` exactly, every factor
/// well-scaled while each histogram's drift stays within the support's
/// capacity. When a histogram drifts past the capacity the kernel is
/// re-absorbed: a cheap `O(nnz)` reference move when the support is
/// still valid (anchor shift ≤ σ, spread still covered), a full
/// `O(m·n)` re-truncation otherwise.
///
/// The state and every exchanged slice stay log-scalings, so federated
/// protocols are oblivious to the schedule.
struct HybridLogBlockOp {
    /// Dense log-kernel block, kept for full re-truncations.
    a_log: Mat,
    t_lin: Vec<f64>,
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Log-product buffer `log(A·x)` (m×N).
    q: Mat,
    /// Shared-support absorbed kernel; a seeded op shares the problem's
    /// cached zero-reference truncation copy-on-write until the first
    /// re-absorption.
    kernel: Arc<AbsorbedLogCsr>,
    /// Scratch `exp(x − ḡ)` (n×N) and the linear product (m×N).
    ex: Mat,
    lin_q: Mat,
    /// Scratch: candidate reference duals (n) and per-histogram drift
    /// (N) — the hot loop never allocates.
    gref: Vec<f64>,
    drift: Vec<f64>,
    tau: f64,
    /// Set once a rebuild would need more drift capacity than f64 can
    /// represent ([`HYBRID_MAX_CAPACITY`]); every product then runs the
    /// dense logsumexp and counts as a non-linear iteration.
    dense_fallback: bool,
    /// Streamed-exchange state: the linear accumulator of the absorbed
    /// fold path, the online-LSE accumulators of the dense-fallback
    /// fold path (all lazy, distinct from the barrier-path scratch so a
    /// marginal check between folds cannot clobber them), whether an
    /// accumulation is pending, and which mode it runs in.
    acc_lin: Mat,
    acc_mx: Vec<f64>,
    acc_sum: Vec<f64>,
    accum_active: bool,
    acc_dense: bool,
    /// Greedy-schedule cache (lazy): the maintained *linear* absorbed
    /// product `glin = K̃·exp(gx − ḡ)` for the snapshot `gx`, valid
    /// only while the kernel's absorption frame is unchanged
    /// (`greedy_epoch == absorb_epoch`). Sparse coordinate moves fold
    /// `K̃[:, changed]·dex` into `glin` exactly (linearity) as long as
    /// the new values sit inside the covered drift budget.
    glin: Mat,
    gx: Mat,
    gq: Mat,
    gviol: Vec<f64>,
    greedy_live: bool,
    greedy_epoch: u64,
    /// Bumped on every kernel mutation (re-absorption, re-truncation,
    /// fleet command): a maintained linear product from an older frame
    /// is in the wrong absorption basis and must be rebuilt.
    absorb_epoch: u64,
    pool: Pool,
    stats: StabStats,
}

impl HybridLogBlockOp {
    #[allow(clippy::too_many_arguments)]
    fn new(
        a_log: Mat,
        t_lin: Vec<f64>,
        log_t: Vec<f64>,
        t_stride: usize,
        u0_log: Mat,
        seed: Option<Arc<AbsorbedLogCsr>>,
        stab: &Stabilization,
        pool: Pool,
    ) -> Self {
        let (m, n) = (a_log.rows(), a_log.cols());
        let nh = u0_log.cols();
        let tau = stab.absorb_threshold;
        let dense_fallback = !fits_support(stab.truncation_theta, tau, tau);
        // A usable seed is the same block truncated with the same (θ, τ)
        // tuning; anything else is rebuilt from the dense kernel (or
        // skipped entirely when τ already forces the dense fallback).
        let kernel = if dense_fallback {
            Arc::new(AbsorbedLogCsr::from_dense_log(
                &Mat::zeros(0, 0),
                &[],
                stab.truncation_theta,
                0.0,
                0.0,
            ))
        } else {
            seed.filter(|k| {
                k.rows() == m
                    && k.cols() == n
                    && k.theta() == stab.truncation_theta
                    && k.sigma() == tau
                    && k.covered() >= tau
                    && !k.support_saturated()
            })
            .unwrap_or_else(|| {
                Arc::new(AbsorbedLogCsr::from_dense_log(
                    &a_log,
                    &vec![0.0; n],
                    stab.truncation_theta,
                    tau,
                    tau,
                ))
            })
        };
        Self {
            a_log,
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q: Mat::zeros(m, nh),
            kernel,
            ex: Mat::zeros(n, nh),
            lin_q: Mat::zeros(m, nh),
            gref: vec![0.0; n],
            drift: vec![0.0; nh],
            tau,
            dense_fallback,
            acc_lin: Mat::zeros(0, 0),
            acc_mx: Vec::new(),
            acc_sum: Vec::new(),
            accum_active: false,
            acc_dense: false,
            glin: Mat::zeros(0, 0),
            gx: Mat::zeros(0, 0),
            gq: Mat::zeros(0, 0),
            gviol: Vec::new(),
            greedy_live: false,
            greedy_epoch: 0,
            absorb_epoch: 0,
            pool,
            stats: StabStats { absorb_triggers: vec![0; nh], ..StabStats::default() },
        }
    }

    /// `q = log(A·x)` via the batched absorbed GEMM, re-absorbing first
    /// if any histogram has drifted past the support's capacity.
    /// `count_absorb` is set from `update` and `matvec` (the latter is
    /// the star server's per-iteration product) so that
    /// `absorbs / updates` stays a true per-iteration ratio — `marginal`
    /// may also re-absorb (a convergence check with fresh scalings) but
    /// is not a Sinkhorn iteration and must not skew `linear_fraction`.
    fn product(&mut self, x_log: &Mat, count_absorb: bool) {
        let (n, nh) = (self.a_log.cols(), self.u.cols());
        debug_assert_eq!(x_log.rows(), n);
        debug_assert_eq!(x_log.cols(), nh);
        if self.dense_fallback {
            if count_absorb {
                self.stats.absorbs += 1;
            }
            self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
            return;
        }
        self.kernel.max_drift_into(x_log, &mut self.drift);
        let covered = self.kernel.covered();
        if self.drift.iter().any(|&d| d > covered) {
            if self.accum_active {
                // A pending streamed accumulation pins the kernel (its
                // folded partials would go stale under a re-absorption):
                // serve this product — a marginal check racing the
                // exchange — densely and leave the re-absorption to the
                // next unpinned product. Exact either way.
                if count_absorb {
                    self.stats.absorbs += 1;
                    for (t, &d) in self.stats.absorb_triggers.iter_mut().zip(&self.drift) {
                        if d > covered {
                            *t += 1;
                        }
                    }
                }
                self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
                return;
            }
            // New reference: the column-wise mean across histograms —
            // it centers the per-histogram corrections, so the residual
            // spread is the smallest symmetric drift bound.
            let spread = reference_candidate(x_log, 0, n, &mut self.gref);
            // Capacity the rebuilt kernel must cover before the next
            // re-absorption can trigger: the residual spread plus the
            // per-histogram drift budget τ.
            let needed = spread + self.tau;
            if !fits_support(self.kernel.theta(), self.tau, needed) {
                // Inter-histogram dual spread beyond any representable
                // shared support: degrade to the dense logsumexp for
                // the rest of this operator's life.
                self.dense_fallback = true;
                if count_absorb {
                    self.stats.absorbs += 1;
                    for (t, &d) in self.stats.absorb_triggers.iter_mut().zip(&self.drift) {
                        if d > covered {
                            *t += 1;
                        }
                    }
                }
                self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
                return;
            }
            self.absorb_epoch += 1;
            let k = Arc::make_mut(&mut self.kernel);
            if needed <= k.covered() && k.anchor_shift(&self.gref) <= k.sigma() {
                k.reabsorb(&self.gref);
            } else {
                k.retruncate(&self.a_log, &self.gref, needed);
                // A full rebuild is a real O(m·n) cost wherever it
                // happens — update, matvec, or a marginal check — so it
                // is always counted (the fleet comparison sums these);
                // only the per-iteration ratio counters below stay
                // update-gated.
                self.stats.rebuilds += 1;
            }
            if count_absorb {
                self.stats.absorbs += 1;
                for (t, &d) in self.stats.absorb_triggers.iter_mut().zip(&self.drift) {
                    if d > covered {
                        *t += 1;
                    }
                }
            }
        }
        let threads = self.pool.threads_for_work(self.kernel.nnz().saturating_mul(nh.max(1)));
        self.kernel
            .log_matmul_into(x_log, &mut self.ex, &mut self.lin_q, &mut self.q, threads);
    }

    /// Resolve a pending streamed accumulation into `q` and release the
    /// kernel pin.
    fn accum_finish(&mut self) {
        if self.acc_dense {
            finish_lse_accum(&self.acc_mx, &self.acc_sum, &mut self.q);
        } else {
            self.kernel.log_matmul_finish(&self.acc_lin, &mut self.q);
        }
        self.accum_active = false;
    }
}

impl BlockOp for HybridLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log, true);
        self.stats.updates += 1;
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log, true);
        self.stats.updates += 1;
        &self.q
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        let (m, nh) = (self.a_log.rows(), self.u.cols());
        self.acc_dense = self.dense_fallback;
        if self.acc_dense {
            self.acc_mx.resize(m * nh, 0.0);
            self.acc_sum.resize(m * nh, 0.0);
            self.acc_mx.fill(f64::NEG_INFINITY);
            self.acc_sum.fill(0.0);
        } else if self.acc_lin.rows() != m {
            self.acc_lin = Mat::zeros(m, nh);
        } else {
            self.acc_lin.as_mut_slice().fill(0.0);
        }
        self.accum_active = true;
    }

    /// Fold one slice: on the linear path the slice must sit inside the
    /// support's covered drift — a slice that trips the bound abandons
    /// streaming (returns `false`) so the caller's barrier fallback can
    /// re-absorb first; rare by the hybrid's own premise. The
    /// dense-fallback mode folds through the online LSE and never
    /// aborts.
    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        debug_assert!(self.accum_active, "accum_fold without accum_begin");
        let nh = self.u.cols();
        if self.acc_dense {
            self.a_log.logsumexp_fold(
                col0,
                rows,
                x_slice,
                nh,
                &mut self.acc_mx,
                &mut self.acc_sum,
                self.pool.share(),
            );
            return true;
        }
        if self.kernel.slice_drift(col0, rows, x_slice, nh) > self.kernel.covered() {
            self.accum_active = false;
            return false;
        }
        let threads = self.pool.threads_for_work(self.kernel.nnz().saturating_mul(nh.max(1)));
        let ex_slice = &mut self.ex.as_mut_slice()[col0 * nh..(col0 + rows) * nh];
        self.kernel
            .log_matmul_fold(col0, rows, x_slice, nh, ex_slice, &mut self.acc_lin, threads);
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        self.accum_finish();
        self.stats.updates += 1;
        if self.acc_dense {
            // Dense-fallback folds are logsumexp iterations, counted
            // non-linear exactly like the barrier fallback products.
            self.stats.absorbs += 1;
        }
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        self.accum_finish();
        self.stats.updates += 1;
        if self.acc_dense {
            self.stats.absorbs += 1;
        }
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log, false);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    /// Drop frozen histogram columns from the batch: pack the state,
    /// per-column targets, counters, and scratch to the `active` subset.
    /// The absorbed kernel is untouched — its support, reference, and
    /// anchor are column-count independent, so compaction costs a few
    /// memcpys and no rebuild. Refused (false) while a streamed
    /// accumulation is pending: the folded partials are full-width.
    fn compact_columns(&mut self, active: &[usize]) -> bool {
        if self.accum_active {
            return false;
        }
        let nh = self.u.cols();
        debug_assert!(active.windows(2).all(|p| p[0] < p[1]), "active strictly increasing");
        assert!(active.iter().all(|&c| c < nh), "active column in range");
        if active.len() == nh {
            return true;
        }
        let (m, n) = (self.a_log.rows(), self.a_log.cols());
        let w = active.len();
        self.u = self.u.select_cols(active);
        self.q = self.q.select_cols(active);
        if self.t_stride > 0 {
            let stride = self.t_stride;
            let pack = |src: &[f64]| {
                let mut out = vec![0.0; m * w];
                for i in 0..m {
                    for (k, &c) in active.iter().enumerate() {
                        out[i * w + k] = src[i * stride + c];
                    }
                }
                out
            };
            self.t_lin = pack(&self.t_lin);
            self.log_t = pack(&self.log_t);
            self.t_stride = w;
        }
        self.ex = Mat::zeros(n, w);
        self.lin_q = Mat::zeros(m, w);
        self.drift = vec![0.0; w];
        self.stats.absorb_triggers =
            active.iter().map(|&c| self.stats.absorb_triggers[c]).collect();
        // Streamed accumulators are lazy; zeroing the shapes forces the
        // next accum_begin to reallocate at the packed width. The
        // greedy cache is likewise width-dependent — drop it and let
        // the next greedy call refresh at the packed width.
        self.acc_lin = Mat::zeros(0, 0);
        self.acc_mx.clear();
        self.acc_sum.clear();
        self.glin = Mat::zeros(0, 0);
        self.gx = Mat::zeros(0, 0);
        self.gq = Mat::zeros(0, 0);
        self.greedy_live = false;
        true
    }

    fn stab_stats(&self) -> Option<StabStats> {
        Some(self.stats.clone())
    }

    /// Slice-local drift probe for the fleet-synchronized absorption
    /// protocol: drift/spread/reference-candidate over rows
    /// `[col0, col0 + rows)` of `x` only — the slice this node already
    /// owns in the scaling exchange.
    fn fleet_probe(&self, x: &Mat, col0: usize, rows: usize) -> Option<FleetProbe> {
        if self.dense_fallback {
            return None;
        }
        let nh = self.u.cols();
        debug_assert_eq!(x.cols(), nh);
        debug_assert!(col0 + rows <= x.rows());
        let mut gref_slice = vec![0.0; rows];
        let spread = reference_candidate(x, col0, rows, &mut gref_slice);
        let g = self.kernel.reference();
        let xs = x.as_slice();
        let mut drift = vec![0.0; nh];
        for j in col0..col0 + rows {
            let xrow = &xs[j * nh..(j + 1) * nh];
            let gj = g[j];
            for (d, &xv) in drift.iter_mut().zip(xrow) {
                let dj = (xv - gj).abs();
                if dj > *d {
                    *d = dj;
                }
            }
        }
        Some(FleetProbe { drift, spread, gref_slice, covered: self.kernel.covered() })
    }

    /// Obey a coordinator absorb command: partial reference move while
    /// the existing support serves it, full re-truncation otherwise. A
    /// command whose capacity no shared support can represent degrades
    /// the operator to the dense logsumexp — consistently fleet-wide,
    /// since every node receives the same broadcast.
    fn fleet_absorb(&mut self, gref: &[f64], covered: f64) -> bool {
        if self.dense_fallback {
            return false;
        }
        debug_assert_eq!(gref.len(), self.a_log.cols());
        self.stats.absorbs += 1;
        self.stats.fleet_commands += 1;
        if !fits_support(self.kernel.theta(), self.tau, covered) {
            self.dense_fallback = true;
            return false;
        }
        self.absorb_epoch += 1;
        let k = Arc::make_mut(&mut self.kernel);
        if covered <= k.covered() && k.anchor_shift(gref) <= k.sigma() {
            k.reabsorb(gref);
            false
        } else {
            k.retruncate(&self.a_log, gref, covered);
            self.stats.rebuilds += 1;
            self.stats.fleet_rebuilds += 1;
            true
        }
    }

    fn supports_greedy(&self) -> bool {
        true
    }

    /// Greedy top-k half-step under the absorption hybrid: coordinate
    /// moves inside the covered drift budget fold `K̃[:, changed]·dex`
    /// into the maintained linear product — exact by linearity, at
    /// O(k·nnz_col) — so only the finish `f̄ + ln glin` (O(m·N)) runs
    /// per call. Moves outside the budget, a changed absorption frame,
    /// or `changed = None` pay one full product through the ordinary
    /// absorbed schedule (which may re-absorb first).
    fn greedy_update(
        &mut self,
        x_log: &Mat,
        alpha: f64,
        spec: GreedySpec,
        changed: Option<&[u32]>,
    ) -> GreedyOutcome {
        let nh = self.u.cols();
        self.stats.updates += 1;
        let mut incremental = false;
        if !self.dense_fallback && self.greedy_live && self.greedy_epoch == self.absorb_epoch {
            if let Some(changed) = changed {
                debug_assert_changed_covers(x_log, &self.gx, changed);
                let mut vals = Vec::with_capacity(changed.len() * nh);
                for &j in changed {
                    vals.extend_from_slice(x_log.row(j as usize));
                }
                if self.kernel.coords_drift(changed, &vals, nh) <= self.kernel.covered() {
                    // dex = exp(x_new − ḡ) − exp(x_old − ḡ), packed.
                    let g = self.kernel.reference();
                    let mut dex = vals;
                    for (p, &j) in changed.iter().enumerate() {
                        let gj = g[j as usize];
                        let old = self.gx.row(j as usize);
                        for h in 0..nh {
                            let slot = &mut dex[p * nh + h];
                            *slot = (*slot - gj).exp() - (old[h] - gj).exp();
                        }
                    }
                    let per_col = self.kernel.nnz() / self.a_log.cols().max(1);
                    let threads = self.pool.threads_for_work(
                        per_col.saturating_mul(changed.len()).saturating_mul(nh.max(1)),
                    );
                    self.kernel.matmul_delta_cols(changed, &dex, nh, &mut self.glin, threads);
                    for &j in changed {
                        self.gx.row_mut(j as usize).copy_from_slice(x_log.row(j as usize));
                    }
                    // Cancellation guard: a maintained lane driven
                    // non-positive (or non-finite) where a fresh sum of
                    // positives cannot be — rebuild rather than finish
                    // into −∞/NaN log products.
                    let bad = |v: f64| v <= 0.0 || !v.is_finite();
                    incremental = !self.glin.as_slice().iter().any(|&v| bad(v));
                }
            }
        }
        if incremental {
            if self.gq.rows() != self.a_log.rows() {
                self.gq = Mat::zeros(self.a_log.rows(), nh);
            }
            self.kernel.log_matmul_finish(&self.glin, &mut self.gq);
        } else {
            // Full refresh through the ordinary absorbed product: q and
            // lin_q come out coherent, and the kernel re-absorbs under
            // its own schedule when the drift budget demands it.
            self.product(x_log, true);
            self.gq = self.q.clone();
            // A product served densely (permanent fallback, pending-
            // accumulation pin) leaves no linear product to maintain;
            // likewise a block whose fresh product already holds empty
            // rows never goes incremental.
            self.greedy_live = !self.dense_fallback
                && !self.accum_active
                && self.lin_q.as_slice().iter().all(|&v| v > 0.0 && v.is_finite());
            if self.greedy_live {
                self.glin = self.lin_q.clone();
                self.gx = x_log.clone();
                self.greedy_epoch = self.absorb_epoch;
            }
        }
        row_violations_log(&self.t_lin, self.t_stride, &self.gq, &self.u, &mut self.gviol);
        let outcome = spec.select(&self.gviol);
        damped_log_subtract_rows(
            &outcome.rows,
            &self.log_t,
            self.t_stride,
            &self.gq,
            alpha,
            &mut self.u,
        );
        outcome
    }
}

/// Log-domain twin of [`NativeBlockOp`]: the block is `log K`, the state
/// holds log-scalings, and the product is the row-wise max-absorbed
/// logsumexp (Schmitzer's stabilized scaling — the running maximum of
/// `log K + log x` is absorbed into the exponent so every `exp` argument
/// is ≤ 0; no kernel entry ever underflows).
struct NativeLogBlockOp {
    a_log: Mat,
    /// Linear-domain target (for the marginal error) …
    t_lin: Vec<f64>,
    /// … and its log (for the update).
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    /// Streamed-exchange online-LSE accumulators, distinct from `q` so
    /// marginal checks cannot clobber a pending accumulation. Lazy.
    acc_mx: Vec<f64>,
    acc_sum: Vec<f64>,
    /// Greedy-schedule violation scratch (lazy).
    gviol: Vec<f64>,
    pool: Pool,
}

impl NativeLogBlockOp {
    fn product(&mut self, x_log: &Mat) {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
    }

    fn accum_finish(&mut self) {
        finish_lse_accum(&self.acc_mx, &self.acc_sum, &mut self.q);
    }
}

impl BlockOp for NativeLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log);
        // log u = α (log t − q) + (1−α) log u, in place (element-wise, so
        // aliasing old and new state is safe). Note α < 1 damps the
        // *duals* — geometrically in the linear domain — which coincides
        // with linear damping at α = 1 (the Prop.-1 regime).
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        let len = self.a_log.rows() * self.u.cols();
        self.acc_mx.resize(len, 0.0);
        self.acc_sum.resize(len, 0.0);
        self.acc_mx.fill(f64::NEG_INFINITY);
        self.acc_sum.fill(0.0);
    }

    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        self.a_log.logsumexp_fold(
            col0,
            rows,
            x_slice,
            self.u.cols(),
            &mut self.acc_mx,
            &mut self.acc_sum,
            self.pool.share(),
        );
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        self.accum_finish();
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        self.accum_finish();
        &self.q
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log);
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log);
        // Linear-domain L1 error: |exp(log u + q) − t| per entry. The
        // exponent log u + q is the log of a marginal entry — O(log t)
        // near the fixed point — so the exp cannot overflow there.
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    fn supports_greedy(&self) -> bool {
        true
    }

    /// Greedy top-k half-step on the dense logsumexp block. The
    /// online-LSE row reduction cannot be maintained coordinate-wise,
    /// so every call pays the full O(m·n) product — the greedy win on
    /// this operator is communication only (the k-coordinate sparse
    /// exchange), which is exactly the regime the dense-log path
    /// serves (comm-bound small-ε solves).
    fn greedy_update(
        &mut self,
        x_log: &Mat,
        alpha: f64,
        spec: GreedySpec,
        changed: Option<&[u32]>,
    ) -> GreedyOutcome {
        let _ = changed;
        self.product(x_log);
        row_violations_log(&self.t_lin, self.t_stride, &self.q, &self.u, &mut self.gviol);
        let outcome = spec.select(&self.gviol);
        damped_log_subtract_rows(
            &outcome.rows,
            &self.log_t,
            self.t_stride,
            &self.q,
            alpha,
            &mut self.u,
        );
        outcome
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn absorbed_gemm_autotune_crossover() {
        // Below the pool-calibrated crossover the dispatch stays serial
        // no matter what share was configured; at or above it the
        // backend's share is honored. The crossover itself is measured
        // at pool construction (clamped to [2^12, 2^22]), so the test
        // pins behavior relative to `par_min_work()` rather than to a
        // fixed constant.
        let pool = Pool::new(4);
        let share = pool.with_share(4);
        let xover = share.par_min_work();
        assert!(xover >= 1, "calibration yields a usable crossover");
        assert_eq!(share.threads_for_work(0), 1);
        assert_eq!(share.threads_for_work(xover.saturating_sub(1)), 1);
        assert_eq!(share.threads_for_work(xover), 4);
        assert_eq!(share.threads_for_work(usize::MAX), 4, "saturating work product");
        // A serial pool never goes parallel, whatever the work size.
        assert_eq!(Pool::new(1).threads_for_work(usize::MAX), 1);
    }

    /// Run the streamed accumulation protocol over a scrambled column
    /// partition and return the updated state.
    fn streamed_update(op: &mut dyn BlockOp, x: &Mat, slices: usize, alpha: f64) -> Mat {
        let (n, nh) = (x.rows(), x.cols());
        assert_eq!(n % slices, 0);
        let m = n / slices;
        assert!(op.supports_streaming());
        op.accum_begin();
        let mut order: Vec<usize> = (0..slices).collect();
        order.reverse();
        for j in order {
            let slice = &x.as_slice()[j * m * nh..(j + 1) * m * nh];
            assert!(op.accum_fold(j * m, m, slice), "fold {j} aborted");
        }
        op.accum_update(alpha).clone()
    }

    fn sample_log(n: usize, nh: usize, lo: f64, seed: u64) -> (Mat, Vec<f64>, Mat, Mat) {
        let mut rng = Rng::seed_from(seed);
        let a_log = Mat::rand_uniform(n, n, lo, 0.0, &mut rng);
        let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let x = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let u0 = Mat::zeros(n, nh);
        (a_log, t, x, u0)
    }

    #[test]
    fn streamed_equals_barrier_linear_op() {
        let mut rng = Rng::seed_from(71);
        for density_drop in [0.0, 0.8] {
            // 0.8 drop pushes the op onto the CSR representation.
            let (n, nh) = (24, 3);
            let mut a = Mat::rand_uniform(n, n, 0.1, 1.0, &mut rng);
            for i in 0..n {
                for j in 0..n {
                    if i != j && rng.uniform() < density_drop {
                        a[(i, j)] = 0.0;
                    }
                }
            }
            let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
            let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
            let be = NativeBackend::new(2);
            let mut barrier = be.block_op(&a, Target::Vec(&t), Mat::ones(n, nh)).unwrap();
            let mut stream = be.block_op(&a, Target::Vec(&t), Mat::ones(n, nh)).unwrap();
            let want = barrier.update(&x, 0.7).clone();
            let got = streamed_update(&mut *stream, &x, 4, 0.7);
            assert!(got.allclose(&want, 1e-12), "drop {density_drop}");
        }
    }

    #[test]
    fn streamed_equals_barrier_log_ops() {
        // Dense logsumexp and truncated-sparse operators: the online
        // running-max merge over slices must match the one-shot product.
        let (a_log, t, x, u0) = sample_log(20, 2, -6.0, 72);
        let be = NativeBackend::new(2);
        let mut barrier = be.log_block_op(&a_log, Target::Vec(&t), u0.clone()).unwrap();
        let mut stream = be.log_block_op(&a_log, Target::Vec(&t), u0.clone()).unwrap();
        let want = barrier.update(&x, 1.0).clone();
        let got = streamed_update(&mut *stream, &x, 5, 1.0);
        assert!(got.allclose(&want, 1e-12), "dense log op");

        let truncated = LogCsr::from_dense_log(&a_log, -4.0);
        assert!(truncated.nnz() < 20 * 20);
        let mut barrier = be
            .sparse_log_block_op(&truncated, Target::Vec(&t), u0.clone())
            .unwrap();
        let mut stream = be.sparse_log_block_op(&truncated, Target::Vec(&t), u0).unwrap();
        let want = barrier.update(&x, 1.0).clone();
        let got = streamed_update(&mut *stream, &x, 5, 1.0);
        assert!(got.allclose(&want, 1e-12), "sparse log op");
    }

    #[test]
    fn streamed_equals_barrier_hybrid_op() {
        let (a_log, t, x, u0) = sample_log(24, 2, -200.0, 73);
        let stab = Stabilization::default();
        let be = NativeBackend::new(1);
        let mut barrier = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0.clone(), &stab)
            .unwrap();
        let mut stream = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0, &stab)
            .unwrap();
        let want = barrier.update(&x, 1.0).clone();
        let got = streamed_update(&mut *stream, &x, 4, 1.0);
        assert!(got.allclose(&want, 1e-12));
        // Both schedules counted one linear update, no absorbs.
        let (bs, ss) = (barrier.stab_stats().unwrap(), stream.stab_stats().unwrap());
        assert_eq!(bs.updates, 1);
        assert_eq!(ss.updates, 1);
        assert_eq!(ss.absorbs, bs.absorbs);
    }

    #[test]
    fn hybrid_drift_trip_aborts_streaming_then_barrier_recovers() {
        let (a_log, t, _, u0) = sample_log(24, 2, -200.0, 74);
        let stab = Stabilization { absorb_threshold: 2.0, ..Stabilization::default() };
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0.clone(), &stab)
            .unwrap();
        // Scalings far beyond the covered drift: the first fold must
        // abandon streaming, and the ordinary barrier update must then
        // re-absorb and stay exact.
        let mut rng = Rng::seed_from(75);
        let x = Mat::rand_uniform(24, 2, 5.0, 9.0, &mut rng);
        op.accum_begin();
        let slice = &x.as_slice()[0..6 * 2];
        assert!(!op.accum_fold(0, 6, slice), "drift trip must abort streaming");
        let got = op.update(&x, 1.0).clone();
        let st = op.stab_stats().unwrap();
        assert_eq!(st.absorbs, 1, "the barrier fallback re-absorbed");
        // Oracle: the pure dense log operator on the same inputs.
        let mut oracle = be.log_block_op(&a_log, Target::Vec(&t), u0).unwrap();
        let want = oracle.update(&x, 1.0).clone();
        assert!(got.allclose(&want, 1e-11));
    }

    #[test]
    fn compacted_hybrid_continues_like_a_packed_fresh_op() {
        // Freeze columns 1 and 3 out of a 4-wide hybrid batch after an
        // update: the compacted op must keep iterating exactly like the
        // dense-log oracle over the packed columns — state, per-column
        // targets (Target::Mat), marginals, and the absorb schedule
        // (the kernel survives compaction untouched).
        let mut rng = Rng::seed_from(78);
        let (n, nh) = (20, 4);
        let a_log = Mat::rand_uniform(n, n, -200.0, 0.0, &mut rng);
        let b = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let stab = Stabilization::default();
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op_stabilized(&a_log, Target::Mat(&b), Mat::zeros(n, nh), &stab)
            .unwrap();
        let mut oracle =
            be.log_block_op(&a_log, Target::Mat(&b), Mat::zeros(n, nh)).unwrap();
        let x1 = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        op.update(&x1, 0.8);
        oracle.update(&x1, 0.8);
        assert!(op.state().allclose(oracle.state(), 1e-11));

        let active = [0usize, 2];
        let packed_state = oracle.state().select_cols(&active);
        assert!(op.compact_columns(&active), "hybrid supports compaction");
        assert_eq!(op.hists(), 2);
        assert!(op.state().allclose(&packed_state, 1e-11));
        let b_packed = b.select_cols(&active);
        let mut oracle = be
            .log_block_op(&a_log, Target::Mat(&b_packed), packed_state)
            .unwrap();
        // Keep iterating with packed inputs, the later ones drifted far
        // enough to trip re-absorption on the compacted kernel.
        for k in 0..3 {
            let off = 12.0 * k as f64;
            let x = Mat::rand_uniform(n, 2, -2.0 + off, 2.0 + off, &mut rng);
            let got = op.update(&x, 0.8).clone();
            let want = oracle.update(&x, 0.8).clone();
            assert!(got.allclose(&want, 1e-11), "post-compaction update {k}");
            let errs_got = op.marginal(&x, &got);
            let errs_want = oracle.marginal(&x, &want);
            for (eg, ew) in errs_got.iter().zip(&errs_want) {
                assert!((eg - ew).abs() <= 1e-9 * ew.max(1.0), "marginal parity");
            }
        }
        let st = op.stab_stats().unwrap();
        assert!(st.absorbs >= 1, "shifted inputs re-absorbed post-compaction");
        assert_eq!(st.absorb_triggers.len(), 2, "trigger counters packed");
        // A pending streamed accumulation pins the width.
        op.accum_begin();
        assert!(!op.compact_columns(&[0]), "pending accumulation refuses compaction");
    }

    #[test]
    fn pending_accumulation_pins_the_hybrid_kernel() {
        // A marginal check whose scalings have drifted past the bound
        // runs while an accumulation is pending: it must not re-absorb
        // (the folded partials would go stale) and the finished streamed
        // update must still match the barrier oracle.
        let (a_log, t, x, u0) = sample_log(24, 2, -200.0, 76);
        let stab = Stabilization { absorb_threshold: 2.0, ..Stabilization::default() };
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0.clone(), &stab)
            .unwrap();
        op.accum_begin();
        for j in 0..4 {
            let slice = &x.as_slice()[j * 6 * 2..(j + 1) * 6 * 2];
            assert!(op.accum_fold(j * 6, 6, slice));
        }
        // Far-drifted marginal input mid-stream (served densely).
        let mut rng = Rng::seed_from(77);
        let far = Mat::rand_uniform(24, 2, 5.0, 9.0, &mut rng);
        let u_now = op.state().clone();
        let _ = op.marginal(&far, &u_now);
        let got = op.accum_update(1.0).clone();
        let mut oracle = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0, &stab)
            .unwrap();
        let want = oracle.update(&x, 1.0).clone();
        assert!(got.allclose(&want, 1e-12));
    }

    #[test]
    fn greedy_incremental_matches_full_refresh_linear() {
        // Incrementally maintained greedy products (declared coordinate
        // moves folded via matmul_delta_cols) vs. an op that refreshes
        // fully every call: same selections, same states, on both the
        // dense and the CSR representation — and bit-identical across
        // thread counts on the incremental path.
        let mut rng = Rng::seed_from(81);
        for density_drop in [0.0, 0.8] {
            let (n, nh) = (30, 2);
            let mut a = Mat::rand_uniform(n, n, 0.1, 1.0, &mut rng);
            for i in 0..n {
                for j in 0..n {
                    if i != j && rng.uniform() < density_drop {
                        a[(i, j)] = 0.0;
                    }
                }
            }
            let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
            let mut inc = NativeBackend::new(2)
                .block_op(&a, Target::Vec(&t), Mat::ones(n, nh))
                .unwrap();
            let mut wide = NativeBackend::new(8)
                .block_op(&a, Target::Vec(&t), Mat::ones(n, nh))
                .unwrap();
            let mut full = NativeBackend::new(2)
                .block_op(&a, Target::Vec(&t), Mat::ones(n, nh))
                .unwrap();
            assert!(inc.supports_greedy());
            let spec = GreedySpec::Count(6);
            let mut x = Mat::rand_uniform(n, nh, 0.5, 1.5, &mut rng);
            let mut changed: Option<Vec<u32>> = None;
            for round in 0..12 {
                let oi = inc.greedy_update(&x, 0.8, spec, changed.as_deref());
                let ow = wide.greedy_update(&x, 0.8, spec, changed.as_deref());
                let of = full.greedy_update(&x, 0.8, spec, None);
                assert_eq!(oi.rows, of.rows, "round {round} drop {density_drop}");
                assert_eq!(oi.rows.len(), 6);
                assert!(oi.selected_mass <= oi.total_mass + 1e-12);
                assert_eq!(oi.rows, ow.rows);
                let (ui, uw, uf) = (inc.state(), wide.state(), full.state());
                for ((a, w), b) in ui.as_slice().iter().zip(uw.as_slice()).zip(uf.as_slice()) {
                    assert_eq!(a.to_bits(), w.to_bits(), "thread-count parity");
                    assert!(
                        (a - b).abs() <= 1e-11 * b.abs().max(1.0),
                        "round {round} drop {density_drop}: {a} vs {b}"
                    );
                }
                if round == 0 {
                    // Unselected rows keep the seed state untouched.
                    for i in 0..n {
                        if !of.rows.contains(&(i as u32)) {
                            assert_eq!(uf.row(i), vec![1.0; nh]);
                        }
                    }
                }
                let mut moved: Vec<u32> = vec![(round % n) as u32, ((round * 7 + 3) % n) as u32];
                moved.sort_unstable();
                moved.dedup();
                for &j in &moved {
                    for h in 0..nh {
                        x[(j as usize, h)] *= 1.0 + 0.05 * rng.uniform();
                    }
                }
                changed = Some(moved);
            }
        }
    }

    #[test]
    fn greedy_sparse_log_updates_selected_rows_exactly() {
        // The sparse-log tracker may rank on boundedly stale
        // violations, but every row it selects must be damped against
        // the *exact* log product of the current x (row-subset
        // logsumexp) — and unselected rows must not move at all.
        let (a_log, t, mut x, u0) = sample_log(24, 2, -6.0, 83);
        let lc = LogCsr::from_dense_log(&a_log, f64::NEG_INFINITY);
        let be = NativeBackend::new(2);
        let mut op = be.sparse_log_block_op(&lc, Target::Vec(&t), u0).unwrap();
        assert!(op.supports_greedy());
        let (alpha, beta) = (0.9, 1.0 - 0.9);
        let spec = GreedySpec::MassFraction(0.5);
        let mut changed: Option<Vec<u32>> = None;
        for round in 0..10 {
            let u_prev = op.state().clone();
            let o = op.greedy_update(&x, alpha, spec, changed.as_deref());
            assert!(!o.rows.is_empty());
            assert!(o.selected_mass <= o.total_mass + 1e-12);
            let q = a_log.logsumexp(&x, 1);
            let u_now = op.state().clone();
            for i in 0..24 {
                for h in 0..2 {
                    let want = if o.rows.contains(&(i as u32)) {
                        alpha * (t[i].ln() - q[(i, h)]) + beta * u_prev[(i, h)]
                    } else {
                        u_prev[(i, h)]
                    };
                    assert!(
                        (u_now[(i, h)] - want).abs() <= 1e-12 * want.abs().max(1.0),
                        "round {round} ({i},{h}): {} vs {want}",
                        u_now[(i, h)]
                    );
                }
            }
            let mut moved: Vec<u32> = vec![(round % 24) as u32, ((round * 5 + 11) % 24) as u32];
            moved.sort_unstable();
            moved.dedup();
            for &j in &moved {
                for h in 0..2 {
                    x[(j as usize, h)] += 0.1 + 0.1 * (h as f64);
                }
            }
            changed = Some(moved);
        }
    }

    #[test]
    fn greedy_dense_log_matches_sparse_full_support() {
        // With a full-support truncation and full refreshes every call
        // (changed = None), the dense-log and sparse-log greedy steps
        // are the same arithmetic: selections and states must agree.
        let (a_log, t, x, u0) = sample_log(20, 3, -5.0, 84);
        let lc = LogCsr::from_dense_log(&a_log, f64::NEG_INFINITY);
        let be = NativeBackend::new(2);
        let mut dense = be.log_block_op(&a_log, Target::Vec(&t), u0.clone()).unwrap();
        let mut sparse = be.sparse_log_block_op(&lc, Target::Vec(&t), u0).unwrap();
        let spec = GreedySpec::MassFraction(0.3);
        for round in 0..4 {
            let od = dense.greedy_update(&x, 1.0, spec, None);
            let os = sparse.greedy_update(&x, 1.0, spec, None);
            assert_eq!(od.rows, os.rows, "round {round}");
            assert!(dense.state().allclose(sparse.state(), 1e-12));
        }
    }

    #[test]
    fn greedy_hybrid_incremental_matches_full_refresh() {
        // Absorbed-delta folds under the covered drift budget vs. a
        // full refresh every call — including a far jump past the
        // budget that must fall back to the full product and
        // re-absorb (epoch invalidation), then resume folding.
        let mut rng = Rng::seed_from(85);
        let (n, nh) = (26, 3);
        let a_log = Mat::rand_uniform(n, n, -60.0, 0.0, &mut rng);
        let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let stab = Stabilization::default();
        let be = NativeBackend::new(2);
        let mut inc = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), Mat::zeros(n, nh), &stab)
            .unwrap();
        let mut full = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), Mat::zeros(n, nh), &stab)
            .unwrap();
        assert!(inc.supports_greedy());
        let spec = GreedySpec::MassFraction(0.4);
        let mut x = Mat::rand_uniform(n, nh, -1.0, 1.0, &mut rng);
        let mut changed: Option<Vec<u32>> = None;
        for round in 0..14 {
            let oi = inc.greedy_update(&x, 1.0, spec, changed.as_deref());
            let of = full.greedy_update(&x, 1.0, spec, None);
            assert_eq!(oi.rows, of.rows, "round {round}");
            let (ui, uf) = (inc.state(), full.state());
            for (a, b) in ui.as_slice().iter().zip(uf.as_slice()) {
                assert!(
                    (a - b).abs() <= 1e-10 * b.abs().max(1.0),
                    "round {round}: {a} vs {b}"
                );
            }
            // Move three coordinates; round 7 jumps far past the
            // covered drift budget.
            let step = if round == 7 { -30.0 } else { 0.2 };
            let mut moved: Vec<u32> = [round % n, (round * 5 + 2) % n, (round * 11 + 6) % n]
                .iter()
                .map(|&j| j as u32)
                .collect();
            moved.sort_unstable();
            moved.dedup();
            for &j in &moved {
                for h in 0..nh {
                    x[(j as usize, h)] += step + 0.1 * rng.uniform();
                }
            }
            changed = Some(moved);
        }
        let (si, sf) = (inc.stab_stats().unwrap(), full.stab_stats().unwrap());
        assert_eq!(si.updates, 14);
        assert_eq!(sf.updates, 14);
        assert!(si.absorbs >= 1, "the far jump must re-absorb");
    }
}
