//! Pure-Rust compute backend.
//!
//! Reference semantics for the XLA path, the arbitrary-shape fallback,
//! and the deliberately CPU-speed substrate for the paper's §IV-E study
//! (where slower compute flips the comm/comp balance). Uses the blocked
//! GEMM/CSR kernels from [`crate::linalg`]; switches to CSR automatically
//! when the block is sparse enough to win. This is also the only backend
//! with a native log-domain operator (row-wise max-absorbed logsumexp) —
//! the small-ε path the AOT artifact grid does not cover.

use super::backend::{BlockOp, ComputeBackend, StabStats, Target};
use crate::linalg::{Csr, LogCsr, Mat, Stabilization};

/// In-place damped update: `u = α·t/q + (1−α)·u`.
fn scale_divide_inplace(t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let (m, nh) = (q.rows(), q.cols());
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let ti = t[i];
            for j in 0..nh {
                urow[j] = alpha * (ti / qrow[j]) + beta * urow[j];
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (trow[j] / qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Density below which CSR dispatch beats dense GEMM for this shape.
/// Measured in bench_kernels (n=1024): dense wins at density 0.31
/// (s=0.9), CSR wins at 0.25 (s=1.0) — cutoff set between them.
const CSR_DENSITY_CUTOFF: f64 = 0.27;

pub struct NativeBackend {
    threads: usize,
}

impl NativeBackend {
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }
}

/// Extract the linear target, its log, and the broadcast stride from a
/// [`Target`] — shared by every log-domain operator.
fn log_targets(
    t: Target<'_>,
    m: usize,
    nh: usize,
) -> anyhow::Result<(Vec<f64>, Vec<f64>, usize)> {
    anyhow::ensure!(t.rows() == m, "target rows != block rows");
    let (t_lin, t_stride) = match t {
        Target::Vec(v) => (v.to_vec(), 0),
        Target::Mat(mat) => {
            anyhow::ensure!(mat.cols() == nh, "target hists != state hists");
            (mat.as_slice().to_vec(), mat.cols())
        }
    };
    let log_t: Vec<f64> = t_lin.iter().map(|&x| x.ln()).collect();
    Ok((t_lin, log_t, t_stride))
}

impl ComputeBackend for NativeBackend {
    fn log_block_op(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            threads: self.threads,
        }))
    }

    fn supports_log(&self) -> bool {
        true
    }

    fn supports_sparse_log(&self) -> bool {
        true
    }

    fn sparse_log_block_op(
        &self,
        a_log: &LogCsr,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeSparseLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            threads: self.threads,
        }))
    }

    /// Stabilized log-domain dispatch: absorption-hybrid for single
    /// histograms, truncated sparse logsumexp when the block is sparse
    /// enough, dense logsumexp otherwise.
    fn log_block_op_stabilized(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        if u0_log.cols() == 1 && stab.hybrid_enabled() {
            anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
            let (t_lin, log_t, _) = log_targets(t, a_log.rows(), 1)?;
            return Ok(Box::new(HybridLogBlockOp::new(
                a_log.clone(),
                t_lin,
                log_t,
                u0_log,
                stab,
                self.threads,
            )));
        }
        // Cheap non-allocating probe first; only build the CSR when the
        // sparse path actually wins.
        if stab.sparse_density_cutoff > 0.0
            && LogCsr::density_of(a_log, stab.truncation_theta) < stab.sparse_density_cutoff
        {
            let truncated = LogCsr::from_dense_log(a_log, stab.truncation_theta);
            return self.sparse_log_block_op(&truncated, t, u0_log);
        }
        self.log_block_op(a_log, t, u0_log)
    }

    fn block_op(
        &self,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(t.rows() == a.rows(), "target rows != block rows");
        anyhow::ensure!(u0.rows() == a.rows(), "state rows != block rows");
        let csr = Csr::from_dense(a, 0.0);
        let csr = (csr.density() < CSR_DENSITY_CUTOFF).then_some(csr);
        let (t_data, t_stride) = match t {
            Target::Vec(v) => (v.to_vec(), 0),
            Target::Mat(m) => {
                anyhow::ensure!(m.cols() == u0.cols(), "target hists != state hists");
                (m.as_slice().to_vec(), m.cols())
            }
        };
        let q = Mat::zeros(a.rows(), u0.cols());
        Ok(Box::new(NativeBlockOp {
            a: a.clone(),
            csr,
            t: t_data,
            t_stride,
            u: u0,
            q,
            threads: self.threads,
        }))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

struct NativeBlockOp {
    a: Mat,
    csr: Option<Csr>,
    t: Vec<f64>,
    t_stride: usize,
    u: Mat,
    /// Preallocated product buffer — the hot loop never allocates.
    q: Mat,
    threads: usize,
}

impl NativeBlockOp {
    fn product(&mut self, x: &Mat) {
        match &self.csr {
            Some(csr) => csr.matmul_into(x, &mut self.q, self.threads),
            None => self.a.matmul_into(x, &mut self.q, self.threads),
        }
    }
}

impl BlockOp for NativeBlockOp {
    fn m(&self) -> usize {
        self.a.rows()
    }

    fn n(&self) -> usize {
        self.a.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat {
        self.product(x);
        // u = α t/q + (1−α) u, in place over the state buffer (element-
        // wise, so aliasing u_old with u_out is safe — no allocation).
        scale_divide_inplace(&self.t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn matvec(&mut self, x: &Mat) -> &Mat {
        self.product(x);
        &self.q
    }

    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64> {
        self.product(x);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u.row(i);
            if self.t_stride == 0 {
                let ti = self.t[i];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - ti).abs();
                }
            } else {
                let trow = &self.t[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}

/// Sparse twin of [`NativeLogBlockOp`]: the block is a θ-truncated
/// [`LogCsr`], the product a sparse row-wise max-absorbed logsumexp over
/// the stored entries only — O(nnz) instead of O(m·n) per iteration.
struct NativeSparseLogBlockOp {
    a_log: LogCsr,
    t_lin: Vec<f64>,
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    threads: usize,
}

impl BlockOp for NativeSparseLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.threads);
        let (m, nh) = (self.q.rows(), self.q.cols());
        let beta = 1.0 - alpha;
        for i in 0..m {
            let qrow = self.q.row(i);
            let urow = self.u.row_mut(i);
            if self.t_stride == 0 {
                let lti = self.log_t[i];
                for j in 0..nh {
                    urow[j] = alpha * (lti - qrow[j]) + beta * urow[j];
                }
            } else {
                let ltrow = &self.log_t[i * self.t_stride..(i + 1) * self.t_stride];
                for j in 0..nh {
                    urow[j] = alpha * (ltrow[j] - qrow[j]) + beta * urow[j];
                }
            }
        }
        &self.u
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.threads);
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.threads);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}

/// Absorption-hybrid log-domain operator (Schmitzer §3, the scaling
/// counterpart of the paper's small-ε regime): the incoming log-scalings
/// `x` are *absorbed* into the kernel —
/// `K̃[i,j] = exp(log K[i,j] + g[j] − f[i])` with `g` the absorbed copy
/// of `x` and `f[i] = max_j (log K[i,j] + g[j])` the row shift — and
/// truncated at `θ` into a [`Csr`]. While `x` stays within
/// `absorb_threshold` of `g`, the product is a plain sparse GEMV
/// `q̃ = K̃ · exp(x − g)` with every factor well-scaled
/// (`K̃ ∈ (e^θ, 1]`, `exp(x − g) ∈ [e^{−τ}, e^{τ}]`), and
/// `log(K·x) = f + ln q̃` exactly. Only when the scalings drift past `τ`
/// is the kernel re-absorbed + re-truncated (one O(m·n) rebuild — about
/// the cost of a single dense logsumexp iteration).
///
/// The state and every exchanged slice stay log-scalings, so federated
/// protocols are oblivious to the schedule. Single-histogram only: with
/// N histograms the absorbed kernel would need N copies (tracked on the
/// ROADMAP); multi-histogram log solves take the sparse/dense logsumexp
/// path instead.
struct HybridLogBlockOp {
    /// Dense log-kernel block, kept for rebuilds.
    a_log: Mat,
    t_lin: Vec<f64>,
    log_t: Vec<f64>,
    /// Log-scaling state `log u` (m×1).
    u: Mat,
    /// Log-product buffer `log(A·x)` (m×1).
    q: Mat,
    /// Absorbed column log-scalings (length n).
    g: Vec<f64>,
    /// Row shifts `f[i] = max_j (a_log[i,j] + g[j])` (length m).
    f: Vec<f64>,
    /// Truncated absorbed linear kernel `exp(a_log + g − f)`.
    k_abs: Csr,
    /// Scratch `exp(x − g)` (n×1) and the linear product (m×1).
    ex: Mat,
    lin_q: Mat,
    theta: f64,
    tau: f64,
    threads: usize,
    stats: StabStats,
}

impl HybridLogBlockOp {
    fn new(
        a_log: Mat,
        t_lin: Vec<f64>,
        log_t: Vec<f64>,
        u0_log: Mat,
        stab: &Stabilization,
        threads: usize,
    ) -> Self {
        let (m, n) = (a_log.rows(), a_log.cols());
        let mut op = Self {
            a_log,
            t_lin,
            log_t,
            u: u0_log,
            q: Mat::zeros(m, 1),
            g: vec![0.0; n],
            f: vec![0.0; m],
            k_abs: Csr::from_parts(m, n, vec![0; m + 1], Vec::new(), Vec::new()),
            ex: Mat::zeros(n, 1),
            lin_q: Mat::zeros(m, 1),
            theta: stab.truncation_theta,
            tau: stab.absorb_threshold,
            threads,
            stats: StabStats::default(),
        };
        op.rebuild();
        op
    }

    /// Re-absorb + re-truncate: recompute the row shifts against the
    /// current `g` and rebuild the truncated absorbed kernel.
    fn rebuild(&mut self) {
        let (m, n) = (self.a_log.rows(), self.a_log.cols());
        let mut row_ptr = Vec::with_capacity(m + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..m {
            let arow = self.a_log.row(i);
            let mut mx = f64::NEG_INFINITY;
            for j in 0..n {
                let v = arow[j] + self.g[j];
                if v > mx {
                    mx = v;
                }
            }
            self.f[i] = mx;
            if mx > f64::NEG_INFINITY {
                for j in 0..n {
                    let s = arow[j] + self.g[j] - mx;
                    if s >= self.theta {
                        col_idx.push(j as u32);
                        vals.push(s.exp());
                    }
                }
            }
            row_ptr.push(vals.len());
        }
        self.k_abs = Csr::from_parts(m, n, row_ptr, col_idx, vals);
    }

    /// `q = log(A·x)` via the absorbed GEMV, re-absorbing first if the
    /// scalings have drifted past `τ`. `count_absorb` is set only from
    /// `update` so that `absorbs / updates` stays a true per-iteration
    /// ratio — `matvec`/`marginal` may also re-absorb (a convergence
    /// check with fresh scalings, a star-server product) but those are
    /// not Sinkhorn iterations and must not skew `linear_fraction`.
    fn product(&mut self, x_log: &Mat, count_absorb: bool) {
        debug_assert_eq!(x_log.cols(), 1, "hybrid op is single-histogram");
        let n = self.a_log.cols();
        debug_assert_eq!(x_log.rows(), n);
        let xs = x_log.as_slice();
        let mut drift: f64 = 0.0;
        for j in 0..n {
            drift = drift.max((xs[j] - self.g[j]).abs());
        }
        if drift > self.tau {
            self.g.copy_from_slice(xs);
            self.rebuild();
            if count_absorb {
                self.stats.absorbs += 1;
            }
        }
        let exs = self.ex.as_mut_slice();
        for (e, (&x, &g)) in exs.iter_mut().zip(xs.iter().zip(&self.g)) {
            *e = (x - g).exp();
        }
        self.k_abs.matmul_into(&self.ex, &mut self.lin_q, self.threads);
        let qs = self.q.as_mut_slice();
        // A zero product only happens on a fully masked row (f = −∞):
        // kept entries are ≥ e^θ and the drift bound keeps exp(x − g)
        // ≥ e^{−τ}, so no kept term can underflow.
        for ((qv, &lq), &fi) in qs.iter_mut().zip(self.lin_q.as_slice()).zip(&self.f) {
            *qv = if lq > 0.0 { fi + lq.ln() } else { f64::NEG_INFINITY };
        }
    }
}

impl BlockOp for HybridLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        1
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log, true);
        self.stats.updates += 1;
        let beta = 1.0 - alpha;
        let us = self.u.as_mut_slice();
        for ((uv, &lti), &qv) in us.iter_mut().zip(&self.log_t).zip(self.q.as_slice()) {
            *uv = alpha * (lti - qv) + beta * *uv;
        }
        &self.u
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log, false);
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log, false);
        let mut err = 0.0;
        for ((&uv, &qv), &ti) in
            u_log.as_slice().iter().zip(self.q.as_slice()).zip(&self.t_lin)
        {
            err += ((uv + qv).exp() - ti).abs();
        }
        vec![err]
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    fn stab_stats(&self) -> Option<StabStats> {
        Some(self.stats)
    }
}

/// Log-domain twin of [`NativeBlockOp`]: the block is `log K`, the state
/// holds log-scalings, and the product is the row-wise max-absorbed
/// logsumexp (Schmitzer's stabilized scaling — the running maximum of
/// `log K + log x` is absorbed into the exponent so every `exp` argument
/// is ≤ 0; no kernel entry ever underflows).
struct NativeLogBlockOp {
    a_log: Mat,
    /// Linear-domain target (for the marginal error) …
    t_lin: Vec<f64>,
    /// … and its log (for the update).
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    threads: usize,
}

impl NativeLogBlockOp {
    fn product(&mut self, x_log: &Mat) {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.threads);
    }
}

impl BlockOp for NativeLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log);
        // log u = α (log t − q) + (1−α) log u, in place (element-wise, so
        // aliasing old and new state is safe). Note α < 1 damps the
        // *duals* — geometrically in the linear domain — which coincides
        // with linear damping at α = 1 (the Prop.-1 regime).
        let (m, nh) = (self.q.rows(), self.q.cols());
        let beta = 1.0 - alpha;
        for i in 0..m {
            let qrow = self.q.row(i);
            let urow = self.u.row_mut(i);
            if self.t_stride == 0 {
                let lti = self.log_t[i];
                for j in 0..nh {
                    urow[j] = alpha * (lti - qrow[j]) + beta * urow[j];
                }
            } else {
                let ltrow = &self.log_t[i * self.t_stride..(i + 1) * self.t_stride];
                for j in 0..nh {
                    urow[j] = alpha * (ltrow[j] - qrow[j]) + beta * urow[j];
                }
            }
        }
        &self.u
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log);
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log);
        // Linear-domain L1 error: |exp(log u + q) − t| per entry. The
        // exponent log u + q is the log of a marginal entry — O(log t)
        // near the fixed point — so the exp cannot overflow there.
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}
