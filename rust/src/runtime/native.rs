//! Pure-Rust compute backend.
//!
//! Reference semantics for the XLA path, the arbitrary-shape fallback,
//! and the deliberately CPU-speed substrate for the paper's §IV-E study
//! (where slower compute flips the comm/comp balance). Uses the blocked
//! GEMM/CSR kernels from [`crate::linalg`]; switches to CSR automatically
//! when the block is sparse enough to win. This is also the only backend
//! with a native log-domain operator (row-wise max-absorbed logsumexp) —
//! the small-ε path the AOT artifact grid does not cover.

use super::backend::{BlockOp, ComputeBackend, FleetProbe, StabStats, Target};
use super::pool::Pool;
use crate::linalg::{AbsorbedLogCsr, Csr, LogCsr, Mat, Stabilization};
use std::sync::Arc;

/// In-place damped update: `u = α·t/q + (1−α)·u`.
fn scale_divide_inplace(t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let (m, nh) = (q.rows(), q.cols());
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let ti = t[i];
            for j in 0..nh {
                urow[j] = alpha * (ti / qrow[j]) + beta * urow[j];
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (trow[j] / qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// In-place damped log-domain update: `log u = α·(log t − q) + (1−α)·
/// log u` (element-wise, so aliasing old and new state is safe). The
/// one implementation behind every log operator's `update` — barrier
/// and streamed paths must apply byte-identical arithmetic.
fn damped_log_subtract_inplace(log_t: &[f64], t_stride: usize, q: &Mat, alpha: f64, u: &mut Mat) {
    let (m, nh) = (q.rows(), q.cols());
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u.row_mut(i);
        if t_stride == 0 {
            let lti = log_t[i];
            for j in 0..nh {
                urow[j] = alpha * (lti - qrow[j]) + beta * urow[j];
            }
        } else {
            let ltrow = &log_t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                urow[j] = alpha * (ltrow[j] - qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Resolve online-logsumexp accumulators into the product buffer:
/// `q = mx + ln sum` (−∞ where no mass was folded).
fn finish_lse_accum(mx: &[f64], sum: &[f64], q: &mut Mat) {
    for (o, (m, s)) in q.as_mut_slice().iter_mut().zip(mx.iter().zip(sum)) {
        *o = if *s > 0.0 { m + s.ln() } else { f64::NEG_INFINITY };
    }
}

/// Density below which CSR dispatch beats dense GEMM for this shape.
/// Measured in bench_kernels (n=1024): dense wins at density 0.31
/// (s=0.9), CSR wins at 0.25 (s=1.0) — cutoff set between them.
const CSR_DENSITY_CUTOFF: f64 = 0.27;

// Threaded absorbed-GEMM autotuning: the banded SpMM only amortizes
// its dispatch overhead above the pool-calibrated crossover in
// stored-entry FMAs (`nnz·N`) — see [`Pool::threads_for_work`], which
// measures the hand-off cost once at pool construction and can be
// pinned via `FEDSINK_PAR_MIN_WORK`. The hybrid dispatch picks threads
// per shape from it, the way the CSR path picks its representation
// from the measured [`CSR_DENSITY_CUTOFF`].

/// Drift-capacity ceiling for the shared-support hybrid: the
/// per-histogram corrections `exp(x − ḡ)` and the row sums they feed
/// must stay inside f64's normal range (|exponent| ≲ 709, with headroom
/// for the n-term sum and the support slack). A tuning or an
/// inter-histogram dual spread that needs more capacity has no
/// numerically safe shared support — the operator then falls back to
/// the dense logsumexp permanently instead of silently producing
/// inf/NaN iterates.
pub const HYBRID_MAX_CAPACITY: f64 = 300.0;

/// Whether a shared support with anchor budget `sigma` can represent
/// drift capacity `needed`: the per-histogram corrections must stay
/// inside f64's exponent range ([`HYBRID_MAX_CAPACITY`]) *and* the
/// truncation slack `θ − 2(σ + needed)` must stay above
/// [`crate::linalg::THETA_SUPPORT_FLOOR`] so no stored absorbed entry
/// underflows into a degenerate (structurally kept, numerically zero)
/// support. A tuning that fails either bound has no numerically safe
/// shared support and the operator degrades to the dense logsumexp.
fn fits_support(theta: f64, sigma: f64, needed: f64) -> bool {
    needed.is_finite()
        && needed <= HYBRID_MAX_CAPACITY
        && needed <= AbsorbedLogCsr::max_covered(theta, sigma)
}

/// Column-mean reference candidate and inter-histogram spread over rows
/// `[r0, r0 + rows)` of the log-scalings `x`, written into
/// `gref[..rows]`; returns the spread. The ONE implementation shared by
/// the hybrid's internal schedule (full range, scratch buffer) and the
/// slice-local fleet probe — slice results merge into exactly the
/// full-range result only while both sides compute identically, so
/// there must be a single copy of this arithmetic.
fn reference_candidate(x: &Mat, r0: usize, rows: usize, gref: &mut [f64]) -> f64 {
    let nh = x.cols();
    debug_assert_eq!(gref.len(), rows);
    let xs = x.as_slice();
    let inv = 1.0 / nh as f64;
    let mut spread: f64 = 0.0;
    for (slot, j) in gref.iter_mut().zip(r0..r0 + rows) {
        let xrow = &xs[j * nh..(j + 1) * nh];
        let mean = xrow.iter().sum::<f64>() * inv;
        *slot = mean;
        for &xv in xrow {
            let s = (xv - mean).abs();
            if s > spread {
                spread = s;
            }
        }
    }
    spread
}

pub struct NativeBackend {
    /// Handle onto the process-wide persistent worker pool, scoped to
    /// this backend's share of the cores (the per-node share under a
    /// federated simulation). Every op clones it — kernels dispatch
    /// bands onto resident workers instead of spawning per call.
    pool: Pool,
}

impl NativeBackend {
    pub fn new(threads: usize) -> Self {
        Self { pool: Pool::global().with_share(threads.max(1)) }
    }
}

/// Extract the linear target, its log, and the broadcast stride from a
/// [`Target`] — shared by every log-domain operator.
fn log_targets(
    t: Target<'_>,
    m: usize,
    nh: usize,
) -> anyhow::Result<(Vec<f64>, Vec<f64>, usize)> {
    anyhow::ensure!(t.rows() == m, "target rows != block rows");
    let (t_lin, t_stride) = match t {
        Target::Vec(v) => (v.to_vec(), 0),
        Target::Mat(mat) => {
            anyhow::ensure!(mat.cols() == nh, "target hists != state hists");
            (mat.as_slice().to_vec(), mat.cols())
        }
    };
    let log_t: Vec<f64> = t_lin.iter().map(|&x| x.ln()).collect();
    Ok((t_lin, log_t, t_stride))
}

impl ComputeBackend for NativeBackend {
    fn log_block_op(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            acc_mx: Vec::new(),
            acc_sum: Vec::new(),
            pool: self.pool.clone(),
        }))
    }

    fn supports_log(&self) -> bool {
        true
    }

    fn supports_sparse_log(&self) -> bool {
        true
    }

    fn sparse_log_block_op(
        &self,
        a_log: &LogCsr,
        t: Target<'_>,
        u0_log: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
        let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
        let q = Mat::zeros(a_log.rows(), u0_log.cols());
        Ok(Box::new(NativeSparseLogBlockOp {
            a_log: a_log.clone(),
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q,
            acc_mx: Vec::new(),
            acc_sum: Vec::new(),
            pool: self.pool.clone(),
        }))
    }

    /// Stabilized log-domain dispatch: the absorption-hybrid schedule
    /// for any histogram count when enabled, the truncated sparse
    /// logsumexp when the hybrid is off and the block is sparse enough,
    /// dense logsumexp otherwise.
    fn log_block_op_stabilized(
        &self,
        a_log: &Mat,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        self.log_block_op_stabilized_seeded(a_log, None, t, u0_log, stab)
    }

    /// Seeded stabilized dispatch: a matching pre-built absorbed kernel
    /// (the problem's per-(θ, τ) zero-reference cache entry) is shared
    /// copy-on-write until the first re-absorption, so multi-solve
    /// experiments truncate each kernel exactly once.
    fn log_block_op_stabilized_seeded(
        &self,
        a_log: &Mat,
        seed: Option<Arc<AbsorbedLogCsr>>,
        t: Target<'_>,
        u0_log: Mat,
        stab: &Stabilization,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        if stab.hybrid_enabled() {
            anyhow::ensure!(u0_log.rows() == a_log.rows(), "state rows != block rows");
            let (t_lin, log_t, t_stride) = log_targets(t, a_log.rows(), u0_log.cols())?;
            return Ok(Box::new(HybridLogBlockOp::new(
                a_log.clone(),
                t_lin,
                log_t,
                t_stride,
                u0_log,
                seed,
                stab,
                self.pool.clone(),
            )));
        }
        // Cheap non-allocating probe first; only build the CSR when the
        // sparse path actually wins.
        if stab.sparse_density_cutoff > 0.0
            && LogCsr::density_of(a_log, stab.truncation_theta) < stab.sparse_density_cutoff
        {
            let truncated = LogCsr::from_dense_log(a_log, stab.truncation_theta);
            return self.sparse_log_block_op(&truncated, t, u0_log);
        }
        self.log_block_op(a_log, t, u0_log)
    }

    fn block_op(
        &self,
        a: &Mat,
        t: Target<'_>,
        u0: Mat,
    ) -> anyhow::Result<Box<dyn BlockOp>> {
        anyhow::ensure!(t.rows() == a.rows(), "target rows != block rows");
        anyhow::ensure!(u0.rows() == a.rows(), "state rows != block rows");
        let csr = Csr::from_dense(a, 0.0);
        let csr = (csr.density() < CSR_DENSITY_CUTOFF).then_some(csr);
        let (t_data, t_stride) = match t {
            Target::Vec(v) => (v.to_vec(), 0),
            Target::Mat(m) => {
                anyhow::ensure!(m.cols() == u0.cols(), "target hists != state hists");
                (m.as_slice().to_vec(), m.cols())
            }
        };
        let q = Mat::zeros(a.rows(), u0.cols());
        Ok(Box::new(NativeBlockOp {
            a: a.clone(),
            csr,
            t: t_data,
            t_stride,
            u: u0,
            q,
            acc: Mat::zeros(0, 0),
            pool: self.pool.clone(),
        }))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

struct NativeBlockOp {
    a: Mat,
    csr: Option<Csr>,
    t: Vec<f64>,
    t_stride: usize,
    u: Mat,
    /// Preallocated product buffer — the hot loop never allocates.
    q: Mat,
    /// Streamed-exchange accumulator, distinct from `q` so a marginal
    /// check between folds (its product writes `q`) cannot clobber a
    /// pending accumulation. Allocated lazily — only streamed runs pay.
    acc: Mat,
    pool: Pool,
}

impl NativeBlockOp {
    fn product(&mut self, x: &Mat) {
        let threads = self.pool.share();
        match &self.csr {
            Some(csr) => csr.matmul_into(x, &mut self.q, threads),
            None => self.a.matmul_into(x, &mut self.q, threads),
        }
    }
}

impl BlockOp for NativeBlockOp {
    fn m(&self) -> usize {
        self.a.rows()
    }

    fn n(&self) -> usize {
        self.a.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x: &Mat, alpha: f64) -> &Mat {
        self.product(x);
        // u = α t/q + (1−α) u, in place over the state buffer (element-
        // wise, so aliasing u_old with u_out is safe — no allocation).
        scale_divide_inplace(&self.t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn matvec(&mut self, x: &Mat) -> &Mat {
        self.product(x);
        &self.q
    }

    fn marginal(&mut self, x: &Mat, u: &Mat) -> Vec<f64> {
        self.product(x);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u.row(i);
            if self.t_stride == 0 {
                let ti = self.t[i];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - ti).abs();
                }
            } else {
                let trow = &self.t[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += (urow[h] * qrow[h] - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        if self.acc.rows() != self.a.rows() {
            self.acc = Mat::zeros(self.a.rows(), self.u.cols());
        } else {
            self.acc.as_mut_slice().fill(0.0);
        }
    }

    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        let nh = self.u.cols();
        let threads = self.pool.share();
        let acc = self.acc.as_mut_slice();
        match &self.csr {
            Some(csr) => csr.matmul_fold(col0, rows, x_slice, nh, acc, threads),
            None => self.a.matmul_fold(col0, rows, x_slice, nh, acc, threads),
        }
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        scale_divide_inplace(&self.t, self.t_stride, &self.acc, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        &self.acc
    }
}

/// Sparse twin of [`NativeLogBlockOp`]: the block is a θ-truncated
/// [`LogCsr`], the product a sparse row-wise max-absorbed logsumexp over
/// the stored entries only — O(nnz) instead of O(m·n) per iteration.
struct NativeSparseLogBlockOp {
    a_log: LogCsr,
    t_lin: Vec<f64>,
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    /// Streamed-exchange online-LSE accumulators (running max + scaled
    /// sum), distinct from `q` so marginal checks cannot clobber a
    /// pending accumulation. Lazily allocated.
    acc_mx: Vec<f64>,
    acc_sum: Vec<f64>,
    pool: Pool,
}

impl NativeSparseLogBlockOp {
    fn accum_finish(&mut self) {
        finish_lse_accum(&self.acc_mx, &self.acc_sum, &mut self.q);
    }
}

impl BlockOp for NativeSparseLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        let len = self.a_log.rows() * self.u.cols();
        self.acc_mx.resize(len, 0.0);
        self.acc_sum.resize(len, 0.0);
        self.acc_mx.fill(f64::NEG_INFINITY);
        self.acc_sum.fill(0.0);
    }

    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        self.a_log.logsumexp_fold(
            col0,
            rows,
            x_slice,
            self.u.cols(),
            &mut self.acc_mx,
            &mut self.acc_sum,
            self.pool.share(),
        );
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        self.accum_finish();
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        self.accum_finish();
        &self.q
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}

/// Absorption-hybrid log-domain operator (Schmitzer §3, the scaling
/// counterpart of the paper's small-ε regime), vectorized across `N`
/// histograms over a **shared-support** [`AbsorbedLogCsr`]: one
/// reference dual `ḡ` (the column-wise mean of the incoming
/// log-scalings) is absorbed and truncated once, and iterations run as
/// the batched sparse GEMM `q̃ = K̃ · exp(x − ḡ)` with per-histogram
/// column corrections — `log(K·x) = f̄ + ln q̃` exactly, every factor
/// well-scaled while each histogram's drift stays within the support's
/// capacity. When a histogram drifts past the capacity the kernel is
/// re-absorbed: a cheap `O(nnz)` reference move when the support is
/// still valid (anchor shift ≤ σ, spread still covered), a full
/// `O(m·n)` re-truncation otherwise.
///
/// The state and every exchanged slice stay log-scalings, so federated
/// protocols are oblivious to the schedule.
struct HybridLogBlockOp {
    /// Dense log-kernel block, kept for full re-truncations.
    a_log: Mat,
    t_lin: Vec<f64>,
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Log-product buffer `log(A·x)` (m×N).
    q: Mat,
    /// Shared-support absorbed kernel; a seeded op shares the problem's
    /// cached zero-reference truncation copy-on-write until the first
    /// re-absorption.
    kernel: Arc<AbsorbedLogCsr>,
    /// Scratch `exp(x − ḡ)` (n×N) and the linear product (m×N).
    ex: Mat,
    lin_q: Mat,
    /// Scratch: candidate reference duals (n) and per-histogram drift
    /// (N) — the hot loop never allocates.
    gref: Vec<f64>,
    drift: Vec<f64>,
    tau: f64,
    /// Set once a rebuild would need more drift capacity than f64 can
    /// represent ([`HYBRID_MAX_CAPACITY`]); every product then runs the
    /// dense logsumexp and counts as a non-linear iteration.
    dense_fallback: bool,
    /// Streamed-exchange state: the linear accumulator of the absorbed
    /// fold path, the online-LSE accumulators of the dense-fallback
    /// fold path (all lazy, distinct from the barrier-path scratch so a
    /// marginal check between folds cannot clobber them), whether an
    /// accumulation is pending, and which mode it runs in.
    acc_lin: Mat,
    acc_mx: Vec<f64>,
    acc_sum: Vec<f64>,
    accum_active: bool,
    acc_dense: bool,
    pool: Pool,
    stats: StabStats,
}

impl HybridLogBlockOp {
    #[allow(clippy::too_many_arguments)]
    fn new(
        a_log: Mat,
        t_lin: Vec<f64>,
        log_t: Vec<f64>,
        t_stride: usize,
        u0_log: Mat,
        seed: Option<Arc<AbsorbedLogCsr>>,
        stab: &Stabilization,
        pool: Pool,
    ) -> Self {
        let (m, n) = (a_log.rows(), a_log.cols());
        let nh = u0_log.cols();
        let tau = stab.absorb_threshold;
        let dense_fallback = !fits_support(stab.truncation_theta, tau, tau);
        // A usable seed is the same block truncated with the same (θ, τ)
        // tuning; anything else is rebuilt from the dense kernel (or
        // skipped entirely when τ already forces the dense fallback).
        let kernel = if dense_fallback {
            Arc::new(AbsorbedLogCsr::from_dense_log(
                &Mat::zeros(0, 0),
                &[],
                stab.truncation_theta,
                0.0,
                0.0,
            ))
        } else {
            seed.filter(|k| {
                k.rows() == m
                    && k.cols() == n
                    && k.theta() == stab.truncation_theta
                    && k.sigma() == tau
                    && k.covered() >= tau
                    && !k.support_saturated()
            })
            .unwrap_or_else(|| {
                Arc::new(AbsorbedLogCsr::from_dense_log(
                    &a_log,
                    &vec![0.0; n],
                    stab.truncation_theta,
                    tau,
                    tau,
                ))
            })
        };
        Self {
            a_log,
            t_lin,
            log_t,
            t_stride,
            u: u0_log,
            q: Mat::zeros(m, nh),
            kernel,
            ex: Mat::zeros(n, nh),
            lin_q: Mat::zeros(m, nh),
            gref: vec![0.0; n],
            drift: vec![0.0; nh],
            tau,
            dense_fallback,
            acc_lin: Mat::zeros(0, 0),
            acc_mx: Vec::new(),
            acc_sum: Vec::new(),
            accum_active: false,
            acc_dense: false,
            pool,
            stats: StabStats { absorb_triggers: vec![0; nh], ..StabStats::default() },
        }
    }

    /// `q = log(A·x)` via the batched absorbed GEMM, re-absorbing first
    /// if any histogram has drifted past the support's capacity.
    /// `count_absorb` is set from `update` and `matvec` (the latter is
    /// the star server's per-iteration product) so that
    /// `absorbs / updates` stays a true per-iteration ratio — `marginal`
    /// may also re-absorb (a convergence check with fresh scalings) but
    /// is not a Sinkhorn iteration and must not skew `linear_fraction`.
    fn product(&mut self, x_log: &Mat, count_absorb: bool) {
        let (n, nh) = (self.a_log.cols(), self.u.cols());
        debug_assert_eq!(x_log.rows(), n);
        debug_assert_eq!(x_log.cols(), nh);
        if self.dense_fallback {
            if count_absorb {
                self.stats.absorbs += 1;
            }
            self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
            return;
        }
        self.kernel.max_drift_into(x_log, &mut self.drift);
        let covered = self.kernel.covered();
        if self.drift.iter().any(|&d| d > covered) {
            if self.accum_active {
                // A pending streamed accumulation pins the kernel (its
                // folded partials would go stale under a re-absorption):
                // serve this product — a marginal check racing the
                // exchange — densely and leave the re-absorption to the
                // next unpinned product. Exact either way.
                if count_absorb {
                    self.stats.absorbs += 1;
                    for (t, &d) in self.stats.absorb_triggers.iter_mut().zip(&self.drift) {
                        if d > covered {
                            *t += 1;
                        }
                    }
                }
                self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
                return;
            }
            // New reference: the column-wise mean across histograms —
            // it centers the per-histogram corrections, so the residual
            // spread is the smallest symmetric drift bound.
            let spread = reference_candidate(x_log, 0, n, &mut self.gref);
            // Capacity the rebuilt kernel must cover before the next
            // re-absorption can trigger: the residual spread plus the
            // per-histogram drift budget τ.
            let needed = spread + self.tau;
            if !fits_support(self.kernel.theta(), self.tau, needed) {
                // Inter-histogram dual spread beyond any representable
                // shared support: degrade to the dense logsumexp for
                // the rest of this operator's life.
                self.dense_fallback = true;
                if count_absorb {
                    self.stats.absorbs += 1;
                    for (t, &d) in self.stats.absorb_triggers.iter_mut().zip(&self.drift) {
                        if d > covered {
                            *t += 1;
                        }
                    }
                }
                self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
                return;
            }
            let k = Arc::make_mut(&mut self.kernel);
            if needed <= k.covered() && k.anchor_shift(&self.gref) <= k.sigma() {
                k.reabsorb(&self.gref);
            } else {
                k.retruncate(&self.a_log, &self.gref, needed);
                // A full rebuild is a real O(m·n) cost wherever it
                // happens — update, matvec, or a marginal check — so it
                // is always counted (the fleet comparison sums these);
                // only the per-iteration ratio counters below stay
                // update-gated.
                self.stats.rebuilds += 1;
            }
            if count_absorb {
                self.stats.absorbs += 1;
                for (t, &d) in self.stats.absorb_triggers.iter_mut().zip(&self.drift) {
                    if d > covered {
                        *t += 1;
                    }
                }
            }
        }
        let threads = self.pool.threads_for_work(self.kernel.nnz().saturating_mul(nh.max(1)));
        self.kernel
            .log_matmul_into(x_log, &mut self.ex, &mut self.lin_q, &mut self.q, threads);
    }

    /// Resolve a pending streamed accumulation into `q` and release the
    /// kernel pin.
    fn accum_finish(&mut self) {
        if self.acc_dense {
            finish_lse_accum(&self.acc_mx, &self.acc_sum, &mut self.q);
        } else {
            self.kernel.log_matmul_finish(&self.acc_lin, &mut self.q);
        }
        self.accum_active = false;
    }
}

impl BlockOp for HybridLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log, true);
        self.stats.updates += 1;
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log, true);
        self.stats.updates += 1;
        &self.q
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        let (m, nh) = (self.a_log.rows(), self.u.cols());
        self.acc_dense = self.dense_fallback;
        if self.acc_dense {
            self.acc_mx.resize(m * nh, 0.0);
            self.acc_sum.resize(m * nh, 0.0);
            self.acc_mx.fill(f64::NEG_INFINITY);
            self.acc_sum.fill(0.0);
        } else if self.acc_lin.rows() != m {
            self.acc_lin = Mat::zeros(m, nh);
        } else {
            self.acc_lin.as_mut_slice().fill(0.0);
        }
        self.accum_active = true;
    }

    /// Fold one slice: on the linear path the slice must sit inside the
    /// support's covered drift — a slice that trips the bound abandons
    /// streaming (returns `false`) so the caller's barrier fallback can
    /// re-absorb first; rare by the hybrid's own premise. The
    /// dense-fallback mode folds through the online LSE and never
    /// aborts.
    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        debug_assert!(self.accum_active, "accum_fold without accum_begin");
        let nh = self.u.cols();
        if self.acc_dense {
            self.a_log.logsumexp_fold(
                col0,
                rows,
                x_slice,
                nh,
                &mut self.acc_mx,
                &mut self.acc_sum,
                self.pool.share(),
            );
            return true;
        }
        if self.kernel.slice_drift(col0, rows, x_slice, nh) > self.kernel.covered() {
            self.accum_active = false;
            return false;
        }
        let threads = self.pool.threads_for_work(self.kernel.nnz().saturating_mul(nh.max(1)));
        let ex_slice = &mut self.ex.as_mut_slice()[col0 * nh..(col0 + rows) * nh];
        self.kernel
            .log_matmul_fold(col0, rows, x_slice, nh, ex_slice, &mut self.acc_lin, threads);
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        self.accum_finish();
        self.stats.updates += 1;
        if self.acc_dense {
            // Dense-fallback folds are logsumexp iterations, counted
            // non-linear exactly like the barrier fallback products.
            self.stats.absorbs += 1;
        }
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        self.accum_finish();
        self.stats.updates += 1;
        if self.acc_dense {
            self.stats.absorbs += 1;
        }
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log, false);
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }

    /// Drop frozen histogram columns from the batch: pack the state,
    /// per-column targets, counters, and scratch to the `active` subset.
    /// The absorbed kernel is untouched — its support, reference, and
    /// anchor are column-count independent, so compaction costs a few
    /// memcpys and no rebuild. Refused (false) while a streamed
    /// accumulation is pending: the folded partials are full-width.
    fn compact_columns(&mut self, active: &[usize]) -> bool {
        if self.accum_active {
            return false;
        }
        let nh = self.u.cols();
        debug_assert!(active.windows(2).all(|p| p[0] < p[1]), "active strictly increasing");
        assert!(active.iter().all(|&c| c < nh), "active column in range");
        if active.len() == nh {
            return true;
        }
        let (m, n) = (self.a_log.rows(), self.a_log.cols());
        let w = active.len();
        self.u = self.u.select_cols(active);
        self.q = self.q.select_cols(active);
        if self.t_stride > 0 {
            let stride = self.t_stride;
            let pack = |src: &[f64]| {
                let mut out = vec![0.0; m * w];
                for i in 0..m {
                    for (k, &c) in active.iter().enumerate() {
                        out[i * w + k] = src[i * stride + c];
                    }
                }
                out
            };
            self.t_lin = pack(&self.t_lin);
            self.log_t = pack(&self.log_t);
            self.t_stride = w;
        }
        self.ex = Mat::zeros(n, w);
        self.lin_q = Mat::zeros(m, w);
        self.drift = vec![0.0; w];
        self.stats.absorb_triggers =
            active.iter().map(|&c| self.stats.absorb_triggers[c]).collect();
        // Streamed accumulators are lazy; zeroing the shapes forces the
        // next accum_begin to reallocate at the packed width.
        self.acc_lin = Mat::zeros(0, 0);
        self.acc_mx.clear();
        self.acc_sum.clear();
        true
    }

    fn stab_stats(&self) -> Option<StabStats> {
        Some(self.stats.clone())
    }

    /// Slice-local drift probe for the fleet-synchronized absorption
    /// protocol: drift/spread/reference-candidate over rows
    /// `[col0, col0 + rows)` of `x` only — the slice this node already
    /// owns in the scaling exchange.
    fn fleet_probe(&self, x: &Mat, col0: usize, rows: usize) -> Option<FleetProbe> {
        if self.dense_fallback {
            return None;
        }
        let nh = self.u.cols();
        debug_assert_eq!(x.cols(), nh);
        debug_assert!(col0 + rows <= x.rows());
        let mut gref_slice = vec![0.0; rows];
        let spread = reference_candidate(x, col0, rows, &mut gref_slice);
        let g = self.kernel.reference();
        let xs = x.as_slice();
        let mut drift = vec![0.0; nh];
        for j in col0..col0 + rows {
            let xrow = &xs[j * nh..(j + 1) * nh];
            let gj = g[j];
            for (d, &xv) in drift.iter_mut().zip(xrow) {
                let dj = (xv - gj).abs();
                if dj > *d {
                    *d = dj;
                }
            }
        }
        Some(FleetProbe { drift, spread, gref_slice, covered: self.kernel.covered() })
    }

    /// Obey a coordinator absorb command: partial reference move while
    /// the existing support serves it, full re-truncation otherwise. A
    /// command whose capacity no shared support can represent degrades
    /// the operator to the dense logsumexp — consistently fleet-wide,
    /// since every node receives the same broadcast.
    fn fleet_absorb(&mut self, gref: &[f64], covered: f64) -> bool {
        if self.dense_fallback {
            return false;
        }
        debug_assert_eq!(gref.len(), self.a_log.cols());
        self.stats.absorbs += 1;
        self.stats.fleet_commands += 1;
        if !fits_support(self.kernel.theta(), self.tau, covered) {
            self.dense_fallback = true;
            return false;
        }
        let k = Arc::make_mut(&mut self.kernel);
        if covered <= k.covered() && k.anchor_shift(gref) <= k.sigma() {
            k.reabsorb(gref);
            false
        } else {
            k.retruncate(&self.a_log, gref, covered);
            self.stats.rebuilds += 1;
            self.stats.fleet_rebuilds += 1;
            true
        }
    }
}

/// Log-domain twin of [`NativeBlockOp`]: the block is `log K`, the state
/// holds log-scalings, and the product is the row-wise max-absorbed
/// logsumexp (Schmitzer's stabilized scaling — the running maximum of
/// `log K + log x` is absorbed into the exponent so every `exp` argument
/// is ≤ 0; no kernel entry ever underflows).
struct NativeLogBlockOp {
    a_log: Mat,
    /// Linear-domain target (for the marginal error) …
    t_lin: Vec<f64>,
    /// … and its log (for the update).
    log_t: Vec<f64>,
    t_stride: usize,
    /// Log-scaling state `log u` (m×N).
    u: Mat,
    /// Preallocated logsumexp buffer — the hot loop never allocates.
    q: Mat,
    /// Streamed-exchange online-LSE accumulators, distinct from `q` so
    /// marginal checks cannot clobber a pending accumulation. Lazy.
    acc_mx: Vec<f64>,
    acc_sum: Vec<f64>,
    pool: Pool,
}

impl NativeLogBlockOp {
    fn product(&mut self, x_log: &Mat) {
        self.a_log.logsumexp_into(x_log, &mut self.q, self.pool.share());
    }

    fn accum_finish(&mut self) {
        finish_lse_accum(&self.acc_mx, &self.acc_sum, &mut self.q);
    }
}

impl BlockOp for NativeLogBlockOp {
    fn m(&self) -> usize {
        self.a_log.rows()
    }

    fn n(&self) -> usize {
        self.a_log.cols()
    }

    fn hists(&self) -> usize {
        self.u.cols()
    }

    fn update(&mut self, x_log: &Mat, alpha: f64) -> &Mat {
        self.product(x_log);
        // log u = α (log t − q) + (1−α) log u, in place (element-wise, so
        // aliasing old and new state is safe). Note α < 1 damps the
        // *duals* — geometrically in the linear domain — which coincides
        // with linear damping at α = 1 (the Prop.-1 regime).
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn accum_begin(&mut self) {
        let len = self.a_log.rows() * self.u.cols();
        self.acc_mx.resize(len, 0.0);
        self.acc_sum.resize(len, 0.0);
        self.acc_mx.fill(f64::NEG_INFINITY);
        self.acc_sum.fill(0.0);
    }

    fn accum_fold(&mut self, col0: usize, rows: usize, x_slice: &[f64]) -> bool {
        self.a_log.logsumexp_fold(
            col0,
            rows,
            x_slice,
            self.u.cols(),
            &mut self.acc_mx,
            &mut self.acc_sum,
            self.pool.share(),
        );
        true
    }

    fn accum_update(&mut self, alpha: f64) -> &Mat {
        self.accum_finish();
        damped_log_subtract_inplace(&self.log_t, self.t_stride, &self.q, alpha, &mut self.u);
        &self.u
    }

    fn accum_matvec(&mut self) -> &Mat {
        self.accum_finish();
        &self.q
    }

    fn matvec(&mut self, x_log: &Mat) -> &Mat {
        self.product(x_log);
        &self.q
    }

    fn marginal(&mut self, x_log: &Mat, u_log: &Mat) -> Vec<f64> {
        self.product(x_log);
        // Linear-domain L1 error: |exp(log u + q) − t| per entry. The
        // exponent log u + q is the log of a marginal entry — O(log t)
        // near the fixed point — so the exp cannot overflow there.
        let nh = self.q.cols();
        let mut err = vec![0.0; nh];
        for i in 0..self.q.rows() {
            let qrow = self.q.row(i);
            let urow = u_log.row(i);
            if self.t_stride == 0 {
                let ti = self.t_lin[i];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - ti).abs();
                }
            } else {
                let trow = &self.t_lin[i * self.t_stride..(i + 1) * self.t_stride];
                for h in 0..nh {
                    err[h] += ((urow[h] + qrow[h]).exp() - trow[h]).abs();
                }
            }
        }
        err
    }

    fn state(&self) -> &Mat {
        &self.u
    }

    fn set_state(&mut self, u: &Mat) {
        assert_eq!(u.rows(), self.u.rows());
        assert_eq!(u.cols(), self.u.cols());
        self.u = u.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn absorbed_gemm_autotune_crossover() {
        // Below the pool-calibrated crossover the dispatch stays serial
        // no matter what share was configured; at or above it the
        // backend's share is honored. The crossover itself is measured
        // at pool construction (clamped to [2^12, 2^22]), so the test
        // pins behavior relative to `par_min_work()` rather than to a
        // fixed constant.
        let pool = Pool::new(4);
        let share = pool.with_share(4);
        let xover = share.par_min_work();
        assert!(xover >= 1, "calibration yields a usable crossover");
        assert_eq!(share.threads_for_work(0), 1);
        assert_eq!(share.threads_for_work(xover.saturating_sub(1)), 1);
        assert_eq!(share.threads_for_work(xover), 4);
        assert_eq!(share.threads_for_work(usize::MAX), 4, "saturating work product");
        // A serial pool never goes parallel, whatever the work size.
        assert_eq!(Pool::new(1).threads_for_work(usize::MAX), 1);
    }

    /// Run the streamed accumulation protocol over a scrambled column
    /// partition and return the updated state.
    fn streamed_update(op: &mut dyn BlockOp, x: &Mat, slices: usize, alpha: f64) -> Mat {
        let (n, nh) = (x.rows(), x.cols());
        assert_eq!(n % slices, 0);
        let m = n / slices;
        assert!(op.supports_streaming());
        op.accum_begin();
        let mut order: Vec<usize> = (0..slices).collect();
        order.reverse();
        for j in order {
            let slice = &x.as_slice()[j * m * nh..(j + 1) * m * nh];
            assert!(op.accum_fold(j * m, m, slice), "fold {j} aborted");
        }
        op.accum_update(alpha).clone()
    }

    fn sample_log(n: usize, nh: usize, lo: f64, seed: u64) -> (Mat, Vec<f64>, Mat, Mat) {
        let mut rng = Rng::seed_from(seed);
        let a_log = Mat::rand_uniform(n, n, lo, 0.0, &mut rng);
        let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
        let x = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let u0 = Mat::zeros(n, nh);
        (a_log, t, x, u0)
    }

    #[test]
    fn streamed_equals_barrier_linear_op() {
        let mut rng = Rng::seed_from(71);
        for density_drop in [0.0, 0.8] {
            // 0.8 drop pushes the op onto the CSR representation.
            let (n, nh) = (24, 3);
            let mut a = Mat::rand_uniform(n, n, 0.1, 1.0, &mut rng);
            for i in 0..n {
                for j in 0..n {
                    if i != j && rng.uniform() < density_drop {
                        a[(i, j)] = 0.0;
                    }
                }
            }
            let t: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.1, 1.0)).collect();
            let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
            let be = NativeBackend::new(2);
            let mut barrier = be.block_op(&a, Target::Vec(&t), Mat::ones(n, nh)).unwrap();
            let mut stream = be.block_op(&a, Target::Vec(&t), Mat::ones(n, nh)).unwrap();
            let want = barrier.update(&x, 0.7).clone();
            let got = streamed_update(&mut *stream, &x, 4, 0.7);
            assert!(got.allclose(&want, 1e-12), "drop {density_drop}");
        }
    }

    #[test]
    fn streamed_equals_barrier_log_ops() {
        // Dense logsumexp and truncated-sparse operators: the online
        // running-max merge over slices must match the one-shot product.
        let (a_log, t, x, u0) = sample_log(20, 2, -6.0, 72);
        let be = NativeBackend::new(2);
        let mut barrier = be.log_block_op(&a_log, Target::Vec(&t), u0.clone()).unwrap();
        let mut stream = be.log_block_op(&a_log, Target::Vec(&t), u0.clone()).unwrap();
        let want = barrier.update(&x, 1.0).clone();
        let got = streamed_update(&mut *stream, &x, 5, 1.0);
        assert!(got.allclose(&want, 1e-12), "dense log op");

        let truncated = LogCsr::from_dense_log(&a_log, -4.0);
        assert!(truncated.nnz() < 20 * 20);
        let mut barrier = be
            .sparse_log_block_op(&truncated, Target::Vec(&t), u0.clone())
            .unwrap();
        let mut stream = be.sparse_log_block_op(&truncated, Target::Vec(&t), u0).unwrap();
        let want = barrier.update(&x, 1.0).clone();
        let got = streamed_update(&mut *stream, &x, 5, 1.0);
        assert!(got.allclose(&want, 1e-12), "sparse log op");
    }

    #[test]
    fn streamed_equals_barrier_hybrid_op() {
        let (a_log, t, x, u0) = sample_log(24, 2, -200.0, 73);
        let stab = Stabilization::default();
        let be = NativeBackend::new(1);
        let mut barrier = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0.clone(), &stab)
            .unwrap();
        let mut stream = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0, &stab)
            .unwrap();
        let want = barrier.update(&x, 1.0).clone();
        let got = streamed_update(&mut *stream, &x, 4, 1.0);
        assert!(got.allclose(&want, 1e-12));
        // Both schedules counted one linear update, no absorbs.
        let (bs, ss) = (barrier.stab_stats().unwrap(), stream.stab_stats().unwrap());
        assert_eq!(bs.updates, 1);
        assert_eq!(ss.updates, 1);
        assert_eq!(ss.absorbs, bs.absorbs);
    }

    #[test]
    fn hybrid_drift_trip_aborts_streaming_then_barrier_recovers() {
        let (a_log, t, _, u0) = sample_log(24, 2, -200.0, 74);
        let stab = Stabilization { absorb_threshold: 2.0, ..Stabilization::default() };
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0.clone(), &stab)
            .unwrap();
        // Scalings far beyond the covered drift: the first fold must
        // abandon streaming, and the ordinary barrier update must then
        // re-absorb and stay exact.
        let mut rng = Rng::seed_from(75);
        let x = Mat::rand_uniform(24, 2, 5.0, 9.0, &mut rng);
        op.accum_begin();
        let slice = &x.as_slice()[0..6 * 2];
        assert!(!op.accum_fold(0, 6, slice), "drift trip must abort streaming");
        let got = op.update(&x, 1.0).clone();
        let st = op.stab_stats().unwrap();
        assert_eq!(st.absorbs, 1, "the barrier fallback re-absorbed");
        // Oracle: the pure dense log operator on the same inputs.
        let mut oracle = be.log_block_op(&a_log, Target::Vec(&t), u0).unwrap();
        let want = oracle.update(&x, 1.0).clone();
        assert!(got.allclose(&want, 1e-11));
    }

    #[test]
    fn compacted_hybrid_continues_like_a_packed_fresh_op() {
        // Freeze columns 1 and 3 out of a 4-wide hybrid batch after an
        // update: the compacted op must keep iterating exactly like the
        // dense-log oracle over the packed columns — state, per-column
        // targets (Target::Mat), marginals, and the absorb schedule
        // (the kernel survives compaction untouched).
        let mut rng = Rng::seed_from(78);
        let (n, nh) = (20, 4);
        let a_log = Mat::rand_uniform(n, n, -200.0, 0.0, &mut rng);
        let b = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let stab = Stabilization::default();
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op_stabilized(&a_log, Target::Mat(&b), Mat::zeros(n, nh), &stab)
            .unwrap();
        let mut oracle =
            be.log_block_op(&a_log, Target::Mat(&b), Mat::zeros(n, nh)).unwrap();
        let x1 = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        op.update(&x1, 0.8);
        oracle.update(&x1, 0.8);
        assert!(op.state().allclose(oracle.state(), 1e-11));

        let active = [0usize, 2];
        let packed_state = oracle.state().select_cols(&active);
        assert!(op.compact_columns(&active), "hybrid supports compaction");
        assert_eq!(op.hists(), 2);
        assert!(op.state().allclose(&packed_state, 1e-11));
        let b_packed = b.select_cols(&active);
        let mut oracle = be
            .log_block_op(&a_log, Target::Mat(&b_packed), packed_state)
            .unwrap();
        // Keep iterating with packed inputs, the later ones drifted far
        // enough to trip re-absorption on the compacted kernel.
        for k in 0..3 {
            let off = 12.0 * k as f64;
            let x = Mat::rand_uniform(n, 2, -2.0 + off, 2.0 + off, &mut rng);
            let got = op.update(&x, 0.8).clone();
            let want = oracle.update(&x, 0.8).clone();
            assert!(got.allclose(&want, 1e-11), "post-compaction update {k}");
            let errs_got = op.marginal(&x, &got);
            let errs_want = oracle.marginal(&x, &want);
            for (eg, ew) in errs_got.iter().zip(&errs_want) {
                assert!((eg - ew).abs() <= 1e-9 * ew.max(1.0), "marginal parity");
            }
        }
        let st = op.stab_stats().unwrap();
        assert!(st.absorbs >= 1, "shifted inputs re-absorbed post-compaction");
        assert_eq!(st.absorb_triggers.len(), 2, "trigger counters packed");
        // A pending streamed accumulation pins the width.
        op.accum_begin();
        assert!(!op.compact_columns(&[0]), "pending accumulation refuses compaction");
    }

    #[test]
    fn pending_accumulation_pins_the_hybrid_kernel() {
        // A marginal check whose scalings have drifted past the bound
        // runs while an accumulation is pending: it must not re-absorb
        // (the folded partials would go stale) and the finished streamed
        // update must still match the barrier oracle.
        let (a_log, t, x, u0) = sample_log(24, 2, -200.0, 76);
        let stab = Stabilization { absorb_threshold: 2.0, ..Stabilization::default() };
        let be = NativeBackend::new(1);
        let mut op = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0.clone(), &stab)
            .unwrap();
        op.accum_begin();
        for j in 0..4 {
            let slice = &x.as_slice()[j * 6 * 2..(j + 1) * 6 * 2];
            assert!(op.accum_fold(j * 6, 6, slice));
        }
        // Far-drifted marginal input mid-stream (served densely).
        let mut rng = Rng::seed_from(77);
        let far = Mat::rand_uniform(24, 2, 5.0, 9.0, &mut rng);
        let u_now = op.state().clone();
        let _ = op.marginal(&far, &u_now);
        let got = op.accum_update(1.0).clone();
        let mut oracle = be
            .log_block_op_stabilized(&a_log, Target::Vec(&t), u0, &stab)
            .unwrap();
        let want = oracle.update(&x, 1.0).clone();
        assert!(got.allclose(&want, 1e-12));
    }
}
