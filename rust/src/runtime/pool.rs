//! Persistent worker pool: resident threads executing banded closures.
//!
//! Every threaded kernel in the stack used to pay a fresh
//! `crossbeam_utils::thread::scope` spawn on each call — fatal for the
//! streamed-fold path, where each fold carries only `1/c` of a band's
//! work and the spawn dominates. The pool keeps a fixed set of workers
//! parked between dispatches (short bounded spin first, so back-to-back
//! kernel calls never touch the scheduler) and hands them band tickets
//! through an atomic counter, which makes a dispatch a few atomic ops
//! instead of thread creation.
//!
//! Shape contract: [`Pool::run_bands`] splits `n_items` into at most
//! `share` contiguous bands — the same `div_ceil` decomposition the old
//! scoped-spawn call sites used — and every item is processed serially
//! inside exactly one band, so results are bit-identical to the scoped
//! code at every thread count (pinned by the linalg identity tests).
//!
//! Sizing: the process-wide pool behind [`Pool::global`] takes its size
//! from `--threads` / `FEDSINK_THREADS` (default `available_parallelism`)
//! via [`crate::config::compute_threads_from_settings`]. Under simulated
//! federation each node holds a [`Pool::with_share`] handle, so `c`
//! nodes split the resident workers instead of oversubscribing
//! `c × available_parallelism` spawned threads.
//!
//! Crossover: construction measures the pool's own dispatch overhead
//! against a serial FMA unit cost and derives the work-unit count
//! (`nnz·N` currency) below which parallel dispatch loses to its own
//! hand-off — replacing the old fixed `ABSORBED_GEMM_PAR_MIN_WORK`
//! constant. Override with `FEDSINK_PAR_MIN_WORK=<units>`.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::{self, JoinHandle, Thread};
use std::time::{Duration, Instant};

/// Spin rounds an idle worker (or a waiting submitter) burns before
/// parking — keeps back-to-back kernel dispatches off the scheduler.
const IDLE_SPIN_ROUNDS: u32 = 64;

/// Backstop park timeout. The unpark-before-park token protocol already
/// prevents lost wakeups; the timeout is pure insurance.
const IDLE_PARK: Duration = Duration::from_millis(2);

/// Clamp range for the calibrated crossover (work units ≈ one FMA each,
/// the `nnz·N` currency the kernels dispatch on).
const MIN_CROSSOVER: usize = 1 << 12;
const MAX_CROSSOVER: usize = 1 << 22;

/// One banded dispatch. Workers (and the submitter) claim band indices
/// through `next`; the job is finished when `remaining` hits zero.
struct Job {
    /// Type-erased banded closure, lifetime-erased to `'static`: the
    /// submitting thread blocks in [`PoolCore::run`] until `remaining`
    /// reaches zero, and a worker only dereferences `f` while it holds
    /// a valid ticket (`band < n_bands`) — every such ticket completes
    /// before the submitter can return, so the pointee outlives every
    /// dereference.
    f: *const (dyn Fn(usize) + Sync),
    n_bands: usize,
    next: AtomicUsize,
    remaining: AtomicUsize,
    panicked: AtomicBool,
    owner: Thread,
}

// Safety: `f` is only dereferenced under the blocking protocol described
// on the field; every other field is an atomic or immutable.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// Erase the closure's borrow lifetime so it can sit in the shared job
/// list. Safety: the caller must block until the job completes (see
/// [`Job::f`]).
unsafe fn erase<'a>(f: &'a (dyn Fn(usize) + Sync)) -> *const (dyn Fn(usize) + Sync) {
    std::mem::transmute::<
        *const (dyn Fn(usize) + Sync + 'a),
        *const (dyn Fn(usize) + Sync + 'static),
    >(f)
}

/// Claim and execute tickets until the job runs dry. Shared by workers
/// and the submitting thread (which always participates, so a fully
/// busy pool degrades to inline execution rather than deadlock).
fn run_tickets(job: &Job) {
    loop {
        let band = job.next.fetch_add(1, Ordering::Relaxed);
        if band >= job.n_bands {
            return;
        }
        // Safety: valid ticket ⇒ the submitter is still blocked in
        // `PoolCore::run`, keeping the closure alive (see `Job::f`).
        let f = unsafe { &*job.f };
        if panic::catch_unwind(AssertUnwindSafe(|| f(band))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        // Release pairs with the submitter's Acquire load: band writes
        // become visible through the `remaining` release sequence.
        if job.remaining.fetch_sub(1, Ordering::Release) == 1 {
            job.owner.unpark();
        }
    }
}

/// State shared with the worker threads.
struct Shared {
    jobs: Mutex<Vec<Arc<Job>>>,
    shutdown: AtomicBool,
}

fn worker_main(shared: Arc<Shared>) {
    let mut idle_rounds = 0u32;
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let job = {
            let jobs = shared.jobs.lock().unwrap();
            jobs.iter()
                .find(|j| j.next.load(Ordering::Relaxed) < j.n_bands)
                .cloned()
        };
        match job {
            Some(job) => {
                idle_rounds = 0;
                run_tickets(&job);
            }
            None => {
                idle_rounds += 1;
                if idle_rounds <= IDLE_SPIN_ROUNDS {
                    std::hint::spin_loop();
                } else {
                    // Submitters unpark every worker after pushing a
                    // job, and an unpark before this park leaves a
                    // token that makes it return immediately — no lost
                    // wakeup window.
                    thread::park_timeout(IDLE_PARK);
                    idle_rounds = 0;
                }
            }
        }
    }
}

/// The resident worker set: `threads − 1` spawned workers (the
/// submitting thread is the remaining executor).
struct PoolCore {
    shared: Arc<Shared>,
    /// Worker thread handles for wakeups (immutable after construction).
    unparkers: Vec<Thread>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    threads: usize,
    par_min_work: AtomicUsize,
}

impl PoolCore {
    fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            jobs: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::new();
        let mut unparkers = Vec::new();
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            let h = thread::Builder::new()
                .name(format!("fedsink-pool-{i}"))
                .spawn(move || worker_main(sh))
                .expect("spawn pool worker");
            unparkers.push(h.thread().clone());
            handles.push(h);
        }
        let core = PoolCore {
            shared,
            unparkers,
            handles: Mutex::new(handles),
            threads,
            par_min_work: AtomicUsize::new(MAX_CROSSOVER),
        };
        let xover = core.calibrate();
        core.par_min_work.store(xover, Ordering::Relaxed);
        core
    }

    /// Execute `n_bands` tickets of `f`, the calling thread included.
    /// Returns once every band finished; re-panics if any band did.
    fn run(&self, n_bands: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_bands == 0 {
            return;
        }
        if n_bands == 1 || self.unparkers.is_empty() {
            for band in 0..n_bands {
                f(band);
            }
            return;
        }
        let job = Arc::new(Job {
            f: unsafe { erase(f) },
            n_bands,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n_bands),
            panicked: AtomicBool::new(false),
            owner: thread::current(),
        });
        self.shared.jobs.lock().unwrap().push(Arc::clone(&job));
        for t in &self.unparkers {
            t.unpark();
        }
        run_tickets(&job);
        // Straggler wait: bounded spin, then park until the last worker
        // unparks us on `remaining → 0` (timeout is insurance).
        let mut spins = 0u32;
        while job.remaining.load(Ordering::Acquire) != 0 {
            spins += 1;
            if spins <= IDLE_SPIN_ROUNDS {
                std::hint::spin_loop();
            } else {
                thread::park_timeout(Duration::from_micros(50));
            }
        }
        let mut jobs = self.shared.jobs.lock().unwrap();
        if let Some(pos) = jobs.iter().position(|j| Arc::ptr_eq(j, &job)) {
            jobs.remove(pos);
        }
        drop(jobs);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker-pool band panicked (propagated to the submitting thread)");
        }
    }

    /// Measure the crossover work-unit count: pool dispatch overhead
    /// (best-of empty two-band hand-offs, min filters scheduler noise)
    /// against a serial FMA as the stand-in for one `nnz·N` work unit.
    fn calibrate(&self) -> usize {
        if let Ok(v) = std::env::var("FEDSINK_PAR_MIN_WORK") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        if self.unparkers.is_empty() {
            // A serial pool can never profit from parallel dispatch.
            return usize::MAX;
        }
        let mut overhead = f64::INFINITY;
        for _ in 0..32 {
            let t0 = Instant::now();
            self.run(2, &|_band| {});
            overhead = overhead.min(t0.elapsed().as_secs_f64());
        }
        let reps = 1usize << 16;
        let mut acc = 1.0f64;
        let t0 = Instant::now();
        for i in 0..reps {
            acc = acc.mul_add(0.999_999, (i & 7) as f64 * 1.0e-3);
        }
        std::hint::black_box(acc);
        let per_unit = t0.elapsed().as_secs_f64() / reps as f64;
        if per_unit <= 0.0 || !overhead.is_finite() {
            return MIN_CROSSOVER;
        }
        // Parallel pays once the compute it offloads (≈ half the work
        // at two bands) beats the hand-off: crossover ≈ 2·overhead/unit.
        ((2.0 * overhead / per_unit) as usize).clamp(MIN_CROSSOVER, MAX_CROSSOVER)
    }
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for t in &self.unparkers {
            t.unpark();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Cheap cloneable handle on a resident worker set: an `Arc` of the
/// core plus the band-count `share` this handle dispatches with.
#[derive(Clone)]
pub struct Pool {
    core: Arc<PoolCore>,
    share: usize,
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

impl Pool {
    /// Dedicated pool with its own `threads − 1` resident workers.
    /// Dropping the last clone shuts them down and joins them.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        Pool { core: Arc::new(PoolCore::new(threads)), share: threads }
    }

    /// The process-wide pool, sized on first use from `--threads` /
    /// `FEDSINK_THREADS` (default `available_parallelism`).
    /// [`Pool::init_global`] can pin the size earlier.
    pub fn global() -> &'static Pool {
        GLOBAL.get_or_init(|| Pool::new(crate::config::compute_threads_from_settings()))
    }

    /// Size the global pool explicitly (the CLI `--threads` path).
    /// First caller wins; returns the global either way.
    pub fn init_global(threads: usize) -> &'static Pool {
        GLOBAL.get_or_init(|| Pool::new(threads))
    }

    /// A handle dispatching at most `share` bands per call — one
    /// simulated node's share of the resident workers.
    pub fn with_share(&self, share: usize) -> Pool {
        Pool { core: Arc::clone(&self.core), share: share.max(1) }
    }

    /// Band count this handle dispatches with.
    pub fn share(&self) -> usize {
        self.share
    }

    /// Resident executor count (spawned workers + submitting thread).
    pub fn threads(&self) -> usize {
        self.core.threads
    }

    /// Calibrated work-unit crossover below which parallel dispatch
    /// loses to its own hand-off (`FEDSINK_PAR_MIN_WORK` overrides).
    pub fn par_min_work(&self) -> usize {
        self.core.par_min_work.load(Ordering::Relaxed)
    }

    /// Band count worth dispatching for `work` units: the full share at
    /// or above the calibrated crossover, serial below it.
    pub fn threads_for_work(&self, work: usize) -> usize {
        if work >= self.par_min_work() {
            self.share
        } else {
            1
        }
    }

    /// Split `n_items` into at most `share` contiguous bands (the same
    /// `div_ceil` decomposition the scoped-spawn call sites used) and
    /// run `f(band, r0, r1)` for each on the resident workers, the
    /// calling thread included. Blocks until every band finished;
    /// panics if any band panicked.
    pub fn run_bands(&self, n_items: usize, f: impl Fn(usize, usize, usize) + Sync) {
        if n_items == 0 {
            return;
        }
        let n_bands = self.share.min(n_items);
        if n_bands <= 1 {
            f(0, 0, n_items);
            return;
        }
        let per = n_items.div_ceil(n_bands);
        let n_bands = n_items.div_ceil(per);
        self.core.run(n_bands, &|band| {
            let r0 = band * per;
            let r1 = (r0 + per).min(n_items);
            f(band, r0, r1);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_cover_every_item_exactly_once() {
        let pool = Pool::new(4);
        for n_items in [1usize, 2, 3, 4, 5, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..n_items).map(|_| AtomicUsize::new(0)).collect();
            pool.run_bands(n_items, |_band, r0, r1| {
                for hit in &hits[r0..r1] {
                    hit.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n_items {n_items}: some item missed or double-banded"
            );
        }
    }

    #[test]
    fn banding_matches_the_scoped_spawn_decomposition() {
        // Same div_ceil split the old crossbeam call sites computed.
        let pool = Pool::new(3);
        let bands = Mutex::new(Vec::new());
        pool.run_bands(10, |band, r0, r1| {
            bands.lock().unwrap().push((band, r0, r1));
        });
        let mut got = bands.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 0, 4), (1, 4, 8), (2, 8, 10)]);
    }

    #[test]
    fn share_one_runs_inline_on_the_submitter() {
        let pool = Pool::new(4).with_share(1);
        let caller = thread::current().id();
        pool.run_bands(100, |_band, r0, r1| {
            assert_eq!((r0, r1), (0, 100), "share 1 must be one band");
            assert_eq!(thread::current().id(), caller);
        });
    }

    #[test]
    fn concurrent_submitters_share_the_workers() {
        // Two simulated nodes dispatching against one core at once —
        // both sums must come out exact.
        let pool = Pool::new(3);
        let total = 5000usize;
        thread::scope(|s| {
            for _ in 0..2 {
                let p = pool.with_share(3);
                s.spawn(move || {
                    let sum = AtomicUsize::new(0);
                    for _ in 0..50 {
                        sum.store(0, Ordering::Relaxed);
                        p.run_bands(total, |_b, r0, r1| {
                            sum.fetch_add(r1 - r0, Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(Ordering::Relaxed), total);
                    }
                });
            }
        });
    }

    #[test]
    fn band_panic_propagates_and_pool_stays_usable() {
        let pool = Pool::new(2);
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_bands(2, |band, _r0, _r1| {
                if band == 1 {
                    panic!("aborted solve");
                }
            });
        }));
        assert!(r.is_err(), "band panic must reach the submitter");
        // Clean re-entry: the same workers keep serving jobs.
        let count = AtomicUsize::new(0);
        pool.run_bands(64, |_b, r0, r1| {
            count.fetch_add(r1 - r0, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn drop_joins_workers_without_leaks() {
        let pool = Pool::new(4);
        let weak = Arc::downgrade(&pool.core.shared);
        pool.run_bands(32, |_b, _r0, _r1| {});
        drop(pool);
        // Workers hold the only other refs to the shared state; a dead
        // weak proves every worker exited and was joined.
        assert!(weak.upgrade().is_none(), "worker leaked past Drop");
        // Fresh pool after a shutdown works (clean re-entry).
        let again = Pool::new(2);
        let count = AtomicUsize::new(0);
        again.run_bands(8, |_b, r0, r1| {
            count.fetch_add(r1 - r0, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn crossover_gates_threads_for_work() {
        let pool = Pool::new(4);
        let xover = pool.par_min_work();
        assert!(xover >= 1);
        if xover > 1 {
            assert_eq!(pool.threads_for_work(xover - 1), 1);
        }
        if xover != usize::MAX {
            assert_eq!(pool.threads_for_work(xover), 4);
            assert_eq!(pool.with_share(2).threads_for_work(xover), 2);
        }
        // A serial pool never goes parallel.
        let serial = Pool::new(1);
        assert_eq!(serial.threads_for_work(usize::MAX), 1);
    }
}
