//! TOML-subset config file loader.
//!
//! Supports: `[section]` headers (flattened to `section.key`), `k = v`
//! with string/number/bool values, `#` comments, blank lines. That is the
//! entire subset the launcher documents; anything else is an error, not
//! a silent skip.

use super::Settings;
use std::fmt;

#[derive(Debug)]
pub struct FileError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for FileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for FileError {}

/// Parse config text into settings (keys become `section.key`).
pub fn load_file(text: &str) -> Result<Settings, FileError> {
    let mut out = Settings::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let line = match line.find('#') {
            // Allow inline comments outside quotes.
            Some(idx) if !line[..idx].contains('"') => line[..idx].trim(),
            _ => line,
        };
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or(FileError {
                line: lineno + 1,
                message: "unterminated [section]".into(),
            })?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line.find('=').ok_or(FileError {
            line: lineno + 1,
            message: "expected `key = value`".into(),
        })?;
        let key = line[..eq].trim();
        let mut val = line[eq + 1..].trim().to_string();
        if key.is_empty() {
            return Err(FileError { line: lineno + 1, message: "empty key".into() });
        }
        // Strip matching quotes.
        if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
            val = val[1..val.len() - 1].to_string();
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.set(&full, val);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let s = load_file(
            r#"
            # experiment config
            seed = 7
            [net]
            base_latency = 1e-4   # seconds
            name = "wan profile"
            [solver]
            alpha = 0.5
            damped = true
            "#,
        )
        .unwrap();
        assert_eq!(s.get_usize("seed"), Some(7));
        assert_eq!(s.get_f64("net.base_latency"), Some(1e-4));
        assert_eq!(s.get("net.name"), Some("wan profile"));
        assert_eq!(s.get_f64("solver.alpha"), Some(0.5));
        assert_eq!(s.get_bool("solver.damped"), Some(true));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(load_file("key_without_value").is_err());
        assert!(load_file("[unclosed").is_err());
        assert!(load_file("= novalue").is_err());
    }
}
