//! Runtime & experiment configuration.
//!
//! A small typed layer over key=value pairs: values come from (in
//! precedence order) CLI flags, environment (`FEDSINK_*`), and an optional
//! config file in a TOML subset (`key = value`, `[section]` headers,
//! strings/numbers/bools). No `serde`/`toml` crates resolve offline, so
//! the loader lives here.

mod file;

pub use file::{load_file, FileError};

use crate::linalg::{Domain, Stabilization};
use crate::runtime::GreedySpec;
use crate::workload::{CondClass, Problem};
use std::collections::BTreeMap;

/// Which federated variant to run — the paper's four protocols, the two
/// decentralized topologies (ring, gossip), and the centralized
/// baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Centralized,
    SyncA2A,
    AsyncA2A,
    SyncStar,
    AsyncStar,
    Ring,
    Gossip,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "centralized" | "central" => Some(Variant::Centralized),
            "sync-a2a" | "sync_a2a" => Some(Variant::SyncA2A),
            "async-a2a" | "async_a2a" => Some(Variant::AsyncA2A),
            "sync-star" | "sync_star" => Some(Variant::SyncStar),
            "async-star" | "async_star" => Some(Variant::AsyncStar),
            "ring" => Some(Variant::Ring),
            "gossip" => Some(Variant::Gossip),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Variant::Centralized => "centralized",
            Variant::SyncA2A => "sync-a2a",
            Variant::AsyncA2A => "async-a2a",
            Variant::SyncStar => "sync-star",
            Variant::AsyncStar => "async-star",
            Variant::Ring => "ring",
            Variant::Gossip => "gossip",
        }
    }

    /// The exchange-graph name of the variant (the `topology` column of
    /// the experiment grids): the paper's four protocols factor into
    /// synchrony × topology, and ring/gossip add two more graphs.
    pub fn topology_name(self) -> &'static str {
        match self {
            Variant::Centralized => "none",
            Variant::SyncA2A | Variant::AsyncA2A => "a2a",
            Variant::SyncStar | Variant::AsyncStar => "star",
            Variant::Ring => "ring",
            Variant::Gossip => "gossip",
        }
    }

    /// The paper's four protocols (the synchrony × {a2a, star} matrix).
    /// Deliberately excludes ring/gossip: drivers that iterate this set
    /// (e.g. the fleet-absorption comparison) assume paper semantics.
    pub const ALL_FEDERATED: [Variant; 4] = [
        Variant::SyncA2A,
        Variant::AsyncA2A,
        Variant::SyncStar,
        Variant::AsyncStar,
    ];

    /// Every federated topology, including the decentralized pair.
    pub const ALL_TOPOLOGIES: [Variant; 6] = [
        Variant::SyncA2A,
        Variant::AsyncA2A,
        Variant::SyncStar,
        Variant::AsyncStar,
        Variant::Ring,
        Variant::Gossip,
    ];
}

/// `exp(−C/ε)` leaves the normal f64 range once `max C / ε` exceeds
/// ~708.4 (−ln(f64::MIN_POSITIVE), subnormals with shrinking mantissa
/// beyond) and is exactly zero past ~744.4 (−1074·ln 2); `auto` flips to
/// the log domain at the edge of the normal range, where the linear
/// kernel starts losing mantissa bits.
pub const AUTO_LOG_RATIO: f64 = 700.0;

/// Requested numerics domain: the two concrete representations plus
/// `auto`, which picks per problem based on the kernel's exponent range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DomainChoice {
    Linear,
    Log,
    /// Log iff `max C / ε > AUTO_LOG_RATIO` — i.e. exactly when the
    /// linear Gibbs kernel would underflow to zero.
    Auto,
}

impl DomainChoice {
    /// `auto` plus whatever spellings [`Domain::parse`] accepts (one
    /// shared string table — the two never diverge).
    pub fn parse(s: &str) -> Option<DomainChoice> {
        if s == "auto" {
            return Some(DomainChoice::Auto);
        }
        Domain::parse(s).map(|d| match d {
            Domain::Linear => DomainChoice::Linear,
            Domain::Log => DomainChoice::Log,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DomainChoice::Linear => "linear",
            DomainChoice::Log => "log",
            DomainChoice::Auto => "auto",
        }
    }

    /// Resolve against a concrete problem.
    pub fn resolve(self, p: &Problem) -> Domain {
        match self {
            DomainChoice::Linear => Domain::Linear,
            DomainChoice::Log => Domain::Log,
            DomainChoice::Auto => {
                if p.cost_max() / p.eps > AUTO_LOG_RATIO {
                    Domain::Log
                } else {
                    Domain::Linear
                }
            }
        }
    }
}

/// Which compute backend executes the block products.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO through PJRT — the "GPU-speed" accelerator stand-in.
    Xla,
    /// Pure-Rust blocked kernels — the "CPU-speed" stand-in (§IV-E).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "xla" => Some(BackendKind::Xla),
            "native" => Some(BackendKind::Native),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::Native => "native",
        }
    }
}

/// What the coordinators put on the wire each communication round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Dense slice exchange — every coordinate of the owned scaling
    /// slice moves every round (the paper's protocols as written).
    Full,
    /// Greedy top-k exchange: each half-iteration updates only the rows
    /// with the largest marginal violations and ships just those
    /// coordinates as sparse index+value frames. Convergence checks
    /// still use the full marginal, so greedy can never report a
    /// converged state that full exchange would reject.
    Greedy,
}

impl ExchangeMode {
    pub fn parse(s: &str) -> Option<ExchangeMode> {
        match s {
            "full" | "dense" => Some(ExchangeMode::Full),
            "greedy" | "topk" | "top-k" => Some(ExchangeMode::Greedy),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExchangeMode::Full => "full",
            ExchangeMode::Greedy => "greedy",
        }
    }
}

/// Scale an async staleness bound by the observed round-trip time. Pure
/// rule shared by every SRTT-gated wait site: with no primed RTT
/// estimate (or degenerate inputs) the nominal bound stands; otherwise
/// the bound stretches by `srtt / nominal`, clamped to `[1, 8]×` so a
/// pathological estimate can neither tighten the bound below the
/// configured window nor unbound the ARock delay assumption.
pub fn srtt_scaled_bound(bound: u64, srtt_secs: f64, nominal_secs: f64) -> u64 {
    let unusable = |v: f64| v <= 0.0 || !v.is_finite();
    if unusable(srtt_secs) || unusable(nominal_secs) {
        return bound;
    }
    let scale = (srtt_secs / nominal_secs).clamp(1.0, 8.0);
    ((bound as f64) * scale).round() as u64
}

/// Full solver configuration (defaults mirror the paper's settings).
#[derive(Clone, Debug)]
pub struct SolveConfig {
    pub variant: Variant,
    pub backend: BackendKind,
    /// Numerics domain for the scaling iteration (linear, log-stabilized
    /// or per-problem auto selection).
    pub domain: DomainChoice,
    /// Stabilized log-path tuning: truncation θ, absorption τ, sparse
    /// dispatch cutoff (`--truncation-threshold` / `--absorb-threshold`).
    pub stab: Stabilization,
    pub clients: usize,
    /// Damping step size α (async variants; 1.0 = undamped).
    pub alpha: f64,
    /// Local iterations between communications (w; App. A).
    pub local_iters: usize,
    /// Convergence threshold on the a-marginal L1 error.
    pub threshold: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Wall-clock timeout in seconds (0 = none) — the paper's
    /// fast/slow limits in §IV-C2.
    pub timeout_secs: f64,
    /// Check convergence every this many iterations.
    pub check_every: usize,
    /// Async variants: max local iterations a node may run ahead of the
    /// freshest message from any live peer before it waits (the bounded
    /// delay assumption of the ARock analysis behind Prop. 2).
    pub max_staleness: u64,
    /// Threads for the native backend's GEMM.
    pub compute_threads: usize,
    /// RNG seed (workloads + network jitter).
    pub seed: u64,
    /// Artifact directory for the XLA backend.
    pub artifacts_dir: String,
    /// Network latency profile.
    pub net: crate::net::LatencyModel,
    /// Wire codec for the coded scaling/chunk/Gref streams
    /// (`--wire-format`): latency and byte counters are priced on the
    /// encoded frames, so the lossy formats halve the β term. Control
    /// traffic (votes, barriers, stop decisions) always rides exact
    /// frames.
    pub wire: crate::net::WireFormat,
    /// Slice-streaming exchange (`--stream-exchange`): synchronous
    /// coordinators fold peer scaling slices into the pending block
    /// product as their frames become deliverable instead of waiting
    /// out the full gather barrier. Inert for async variants (no
    /// barrier to stream) and under `--fleet-absorb` (the fleet round
    /// must see the product *after* the commanded re-absorption).
    pub stream_exchange: bool,
    /// DeltaF32 keyframe cadence (`--wire-keyframe-every`): force a full
    /// keyframe frame every K encoded rounds per stream, bounding how
    /// long a reconstruction can drift from exact state under future
    /// lossy links. 0 (default) keys only on stream (re)priming.
    pub wire_keyframe_every: usize,
    /// Fault-injection schedule (`--drop-prob` / `--dup-prob` /
    /// `--reorder-prob` / `--crash-at` / …). The inactive default keeps
    /// every fabric path byte-for-byte on the lossless code.
    pub faults: crate::net::FaultPlan,
    /// Peer-death detection + node-loss policy (`--recv-timeout` /
    /// `--strikes` / `--on-node-loss`). Only consulted when the fault
    /// plan is active — lossless runs never arm recovery timeouts.
    pub recovery: crate::net::Recovery,
    /// Dense or greedy top-k slice exchange (`--exchange full|greedy`).
    pub exchange: ExchangeMode,
    /// Greedy row budget per half-iteration (`--greedy-topk`): `0.5`
    /// covers half the violation mass, `k=16` a fixed row count. Only
    /// consulted under `ExchangeMode::Greedy`.
    pub greedy_topk: GreedySpec,
    /// SRTT-scaled async staleness bounds (`--srtt-staleness`): stretch
    /// the bounded-delay window per link by the measured round-trip
    /// estimate so slow-but-alive links under fault injection are not
    /// throttled as if they were LAN-fast. Inert on lossless runs —
    /// the RTT estimator only primes under an active fault plan.
    pub srtt_staleness: bool,
}

impl SolveConfig {
    /// The effective bounded-delay window of the async protocols: the
    /// configured `max_staleness`, floored at 1 so a zero setting cannot
    /// deadlock the wait loops. Single source of truth for the three
    /// async wait/gate sites (a2a clients, star server, star clients).
    pub fn staleness_bound(&self) -> u64 {
        self.max_staleness.max(1)
    }

    /// The staleness bound for one link, optionally SRTT-scaled: under
    /// `--srtt-staleness` the nominal bound stretches by the link's
    /// smoothed RTT relative to the configured base latency (see
    /// [`srtt_scaled_bound`]); otherwise, and whenever the estimator is
    /// unprimed, the nominal bound stands.
    pub fn staleness_bound_for(&self, srtt_secs: f64) -> u64 {
        let bound = self.staleness_bound();
        if !self.srtt_staleness {
            return bound;
        }
        srtt_scaled_bound(bound, srtt_secs, self.net.base_secs)
    }
}

impl Default for SolveConfig {
    fn default() -> Self {
        Self {
            variant: Variant::SyncA2A,
            backend: BackendKind::Xla,
            domain: domain_choice_from_settings(),
            stab: Stabilization::default(),
            clients: 2,
            alpha: 1.0,
            local_iters: 1,
            threshold: 1e-10,
            max_iters: 1500,
            timeout_secs: 0.0,
            check_every: 1,
            max_staleness: 8,
            compute_threads: compute_threads_from_settings(),
            seed: 42,
            artifacts_dir: default_artifacts_dir(),
            net: crate::net::LatencyModel::lan(),
            wire: crate::net::WireFormat::F64,
            stream_exchange: false,
            wire_keyframe_every: 0,
            faults: crate::net::FaultPlan::none(),
            recovery: crate::net::Recovery::default(),
            exchange: ExchangeMode::Full,
            greedy_topk: GreedySpec::MassFraction(0.5),
            srtt_staleness: false,
        }
    }
}

/// The numerics-domain choice carried by a [`Settings`] map (the
/// `domain` key — `FEDSINK_DOMAIN` in the environment, `domain = ...` in
/// a config file). `Auto` when absent or unparseable.
pub fn domain_choice_from(settings: &Settings) -> DomainChoice {
    settings
        .get("domain")
        .and_then(DomainChoice::parse)
        .unwrap_or(DomainChoice::Auto)
}

/// Resolve the default numerics domain from the process environment:
/// `FEDSINK_DOMAIN` first, then a `domain = ...` key in the config file
/// named by `FEDSINK_CONFIG`. This is what `SolveConfig::default()`
/// uses, so *every* experiment driver — not just `solve`/`epsilon-study`
/// — honors the setting without plumbing a flag through. Resolved once
/// per process (experiment grids build thousands of configs; `Default`
/// must not re-read files or rescan the environment each time).
pub fn domain_choice_from_settings() -> DomainChoice {
    static RESOLVED: std::sync::OnceLock<DomainChoice> = std::sync::OnceLock::new();
    *RESOLVED.get_or_init(|| {
        let mut s = Settings::default();
        s.overlay_env();
        if let Ok(path) = std::env::var("FEDSINK_CONFIG") {
            if let Ok(text) = std::fs::read_to_string(&path) {
                if let Ok(file) = load_file(&text) {
                    for (k, v) in file.map {
                        s.map.entry(k).or_insert(v); // env keys win over file keys
                    }
                }
            }
        }
        domain_choice_from(&s)
    })
}

/// The compute-thread count carried by a [`Settings`] map (the
/// `threads` key — `FEDSINK_THREADS` in the environment, `threads = ...`
/// in a config file). Defaults to `available_parallelism` when absent,
/// unparseable or zero.
pub fn compute_threads_from(settings: &Settings) -> usize {
    match settings.get_usize("threads") {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

static COMPUTE_THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();

/// Pin the process-default compute-thread count (the `--threads` flag).
/// First caller wins and must run before the first
/// `SolveConfig::default()` / `runtime::Pool::global()` — once either
/// has resolved the count, this is a no-op. Returns the effective value.
pub fn init_compute_threads(n: usize) -> usize {
    *COMPUTE_THREADS.get_or_init(|| n.max(1))
}

/// Resolve the default compute-thread count from the process
/// environment: `FEDSINK_THREADS` first, then a `threads = ...` key in
/// the config file named by `FEDSINK_CONFIG`, else
/// `available_parallelism`. Sizes `SolveConfig::default()` and the
/// persistent worker pool (`runtime::Pool::global`); resolved once per
/// process, mirroring [`domain_choice_from_settings`]. A `--threads`
/// flag pins it first via [`init_compute_threads`].
pub fn compute_threads_from_settings() -> usize {
    *COMPUTE_THREADS.get_or_init(|| {
        let mut s = Settings::default();
        s.overlay_env();
        if let Ok(path) = std::env::var("FEDSINK_CONFIG") {
            if let Ok(text) = std::fs::read_to_string(&path) {
                if let Ok(file) = load_file(&text) {
                    for (k, v) in file.map {
                        s.map.entry(k).or_insert(v); // env keys win over file keys
                    }
                }
            }
        }
        compute_threads_from(&s)
    })
}

/// artifacts/ next to the binary's workspace (overridable by env).
pub fn default_artifacts_dir() -> String {
    if let Ok(d) = std::env::var("FEDSINK_ARTIFACTS") {
        return d;
    }
    // Walk up from cwd looking for artifacts/manifest.json.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts").join("manifest.json");
        if cand.exists() {
            return dir.join("artifacts").to_string_lossy().into_owned();
        }
        if !dir.pop() {
            return "artifacts".to_string();
        }
    }
}

/// Workload description shared by experiment drivers.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub n: usize,
    pub hists: usize,
    pub eps: f64,
    pub sparsity: f64,
    pub cond: CondClass,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            n: 256,
            hists: 1,
            eps: 0.05,
            sparsity: 0.0,
            cond: CondClass::Well,
        }
    }
}

/// Flat key=value map with typed getters — the substrate both the file
/// loader and the CLI write into.
#[derive(Clone, Debug, Default)]
pub struct Settings {
    pub map: BTreeMap<String, String>,
}

impl Settings {
    pub fn set(&mut self, k: &str, v: impl Into<String>) {
        self.map.insert(k.to_string(), v.into());
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.map.get(k).map(|s| s.as_str())
    }

    pub fn get_f64(&self, k: &str) -> Option<f64> {
        self.get(k)?.parse().ok()
    }

    pub fn get_usize(&self, k: &str) -> Option<usize> {
        self.get(k)?.parse().ok()
    }

    pub fn get_bool(&self, k: &str) -> Option<bool> {
        match self.get(k)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }

    /// Overlay `FEDSINK_*` environment variables (lower-cased, `_`→`.`).
    pub fn overlay_env(&mut self) {
        for (k, v) in std::env::vars() {
            if let Some(rest) = k.strip_prefix("FEDSINK_") {
                let key = rest.to_ascii_lowercase().replace('_', ".");
                self.map.entry(key).or_insert(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        for v in [
            Variant::Centralized,
            Variant::SyncA2A,
            Variant::AsyncA2A,
            Variant::SyncStar,
            Variant::AsyncStar,
            Variant::Ring,
            Variant::Gossip,
        ] {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        assert_eq!(Variant::parse("bogus"), None);
    }

    #[test]
    fn topology_sets_and_names() {
        // ALL_FEDERATED stays the paper's 2×2 matrix — drivers that
        // assume paper semantics iterate it; the decentralized pair only
        // appears in ALL_TOPOLOGIES.
        assert_eq!(Variant::ALL_FEDERATED.len(), 4);
        assert!(!Variant::ALL_FEDERATED.contains(&Variant::Ring));
        assert!(!Variant::ALL_FEDERATED.contains(&Variant::Gossip));
        assert_eq!(Variant::ALL_TOPOLOGIES.len(), 6);
        for v in Variant::ALL_FEDERATED {
            assert!(Variant::ALL_TOPOLOGIES.contains(&v));
        }
        assert_eq!(Variant::SyncA2A.topology_name(), "a2a");
        assert_eq!(Variant::AsyncStar.topology_name(), "star");
        assert_eq!(Variant::Ring.topology_name(), "ring");
        assert_eq!(Variant::Gossip.topology_name(), "gossip");
        assert_eq!(Variant::Centralized.topology_name(), "none");
    }

    #[test]
    fn settings_typed_getters() {
        let mut s = Settings::default();
        s.set("alpha", "0.5");
        s.set("clients", "8");
        s.set("verbose", "true");
        assert_eq!(s.get_f64("alpha"), Some(0.5));
        assert_eq!(s.get_usize("clients"), Some(8));
        assert_eq!(s.get_bool("verbose"), Some(true));
        assert_eq!(s.get_f64("missing"), None);
    }

    #[test]
    fn default_config_is_sane() {
        let c = SolveConfig::default();
        assert!(c.alpha > 0.0 && c.alpha <= 1.0);
        assert!(c.max_iters > 0);
        assert_eq!(c.local_iters, 1);
        assert_eq!(c.domain, DomainChoice::Auto);
        // The default wire is the exact PR-4 baseline: F64 frames,
        // barrier exchange.
        assert_eq!(c.wire, crate::net::WireFormat::F64);
        assert!(!c.stream_exchange);
        // Lossless fabric + abort-on-loss recovery by default.
        assert!(!c.faults.is_active());
        assert_eq!(c.recovery.on_node_loss, crate::net::NodeLoss::Abort);
        assert!(c.recovery.death_secs() > 0.0);
    }

    #[test]
    fn domain_choice_parses_and_resolves() {
        for d in [DomainChoice::Linear, DomainChoice::Log, DomainChoice::Auto] {
            assert_eq!(DomainChoice::parse(d.name()), Some(d));
        }
        assert_eq!(DomainChoice::parse("bogus"), None);
        // Auto: moderate ε stays linear, underflow-range ε flips to log.
        let easy = crate::workload::Problem::paper_4x4(0.5);
        let hard = crate::workload::Problem::paper_4x4(1e-3);
        assert_eq!(DomainChoice::Auto.resolve(&easy), Domain::Linear);
        assert_eq!(DomainChoice::Auto.resolve(&hard), Domain::Log);
        assert_eq!(DomainChoice::Log.resolve(&easy), Domain::Log);
        assert_eq!(DomainChoice::Linear.resolve(&hard), Domain::Linear);
    }

    #[test]
    fn domain_key_resolves_from_settings() {
        // The key `FEDSINK_DOMAIN` lands on via `Settings::overlay_env`
        // (FEDSINK_ → strip, lowercase) and a config file's `domain =`
        // line both resolve through `domain_choice_from`; bad or absent
        // values fall back to Auto. (Tested on a hand-built Settings —
        // mutating the process environment would race parallel tests.)
        let mut s = Settings::default();
        assert_eq!(domain_choice_from(&s), DomainChoice::Auto);
        s.set("domain", "log");
        assert_eq!(domain_choice_from(&s), DomainChoice::Log);
        s.set("domain", "linear");
        assert_eq!(domain_choice_from(&s), DomainChoice::Linear);
        s.set("domain", "bogus");
        assert_eq!(domain_choice_from(&s), DomainChoice::Auto);
        // The file loader produces the same key shape.
        let f = load_file("domain = log").unwrap();
        assert_eq!(domain_choice_from(&f), DomainChoice::Log);
    }

    #[test]
    fn threads_key_resolves_from_settings() {
        // The key `FEDSINK_THREADS` lands on via `Settings::overlay_env`
        // and a config file's `threads =` line both resolve through
        // `compute_threads_from`; absent, bad, or zero values fall back
        // to available_parallelism. (Hand-built Settings — mutating the
        // process environment would race parallel tests.)
        let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut s = Settings::default();
        assert_eq!(compute_threads_from(&s), auto);
        s.set("threads", "3");
        assert_eq!(compute_threads_from(&s), 3);
        s.set("threads", "0");
        assert_eq!(compute_threads_from(&s), auto);
        s.set("threads", "bogus");
        assert_eq!(compute_threads_from(&s), auto);
        let f = load_file("threads = 2").unwrap();
        assert_eq!(compute_threads_from(&f), 2);
        // The resolved default sizes SolveConfig.
        assert!(SolveConfig::default().compute_threads >= 1);
    }

    #[test]
    fn keyframe_cadence_defaults_off() {
        assert_eq!(SolveConfig::default().wire_keyframe_every, 0);
    }

    #[test]
    fn exchange_defaults_to_full_dense() {
        let c = SolveConfig::default();
        assert_eq!(c.exchange, ExchangeMode::Full);
        assert_eq!(c.greedy_topk, GreedySpec::MassFraction(0.5));
        assert!(!c.srtt_staleness);
        for m in [ExchangeMode::Full, ExchangeMode::Greedy] {
            assert_eq!(ExchangeMode::parse(m.name()), Some(m));
        }
        assert_eq!(ExchangeMode::parse("topk"), Some(ExchangeMode::Greedy));
        assert_eq!(ExchangeMode::parse("bogus"), None);
    }

    #[test]
    fn srtt_scaling_clamps_and_falls_back() {
        // Unprimed / degenerate estimates leave the nominal bound alone.
        assert_eq!(srtt_scaled_bound(8, 0.0, 1e-3), 8);
        assert_eq!(srtt_scaled_bound(8, -1.0, 1e-3), 8);
        assert_eq!(srtt_scaled_bound(8, f64::NAN, 1e-3), 8);
        assert_eq!(srtt_scaled_bound(8, 1e-3, 0.0), 8);
        // A fast link never tightens below the configured window...
        assert_eq!(srtt_scaled_bound(8, 1e-4, 1e-3), 8);
        // ...a 3× RTT stretches it 3×, and the stretch caps at 8×.
        assert_eq!(srtt_scaled_bound(8, 3e-3, 1e-3), 24);
        assert_eq!(srtt_scaled_bound(8, 1.0, 1e-3), 64);
        // The config method gates on the flag.
        let mut c = SolveConfig::default();
        c.max_staleness = 8;
        let slow = c.net.base_secs * 4.0;
        assert_eq!(c.staleness_bound_for(slow), 8);
        c.srtt_staleness = true;
        assert_eq!(c.staleness_bound_for(slow), 32);
        assert_eq!(c.staleness_bound_for(0.0), 8);
    }

    #[test]
    fn default_stabilization_is_sane() {
        let s = Stabilization::default();
        assert!(s.truncation_theta < 0.0);
        assert!(s.absorb_threshold > 0.0 && s.hybrid_enabled());
        assert!((0.0..=1.0).contains(&s.sparse_density_cutoff));
        assert!(!Stabilization::disabled().hybrid_enabled());
    }

    #[test]
    fn auto_ignores_deliberate_sparsification_zeros() {
        // §IV-D sparsified problems push killed blocks to cost 800·ε so
        // they underflow *on purpose* — auto must stay linear (the CSR
        // fast path), keyed off the genuine cost range only.
        let sparse = crate::workload::ProblemSpec::new(32)
            .with_sparsity(0.5, 4)
            .build(7);
        assert!(sparse.masked_cost_min.is_some());
        assert_eq!(DomainChoice::Auto.resolve(&sparse), Domain::Linear);
    }
}
