//! Shared-support absorbed sparse log-kernel for the multi-histogram
//! absorption-hybrid schedule (Schmitzer's kernel absorption, PAPERS.md
//! 1610.06519, extended to vectorized solves).
//!
//! One *reference* dual vector `ḡ` (e.g. the column-wise mean of the `N`
//! log-scalings) is absorbed into the kernel —
//! `K̃[i,j] = exp(log K[i,j] + ḡ[j] − f̄[i])` with
//! `f̄[i] = max_j (log K[i,j] + ḡ[j])` — and the support is truncated
//! once against the reference. Per-histogram products then run as one
//! batched sparse GEMM with per-column scaling corrections:
//!
//! ```text
//! q[:,h] = K̃ · exp(x[:,h] − ḡ)        (multi-RHS, shared support)
//! log(K·x)[i,h] = f̄[i] + ln q[i,h]
//! ```
//!
//! Every factor stays well-scaled as long as each histogram's drift
//! `max_j |x[j,h] − ḡ[j]|` is below the capacity the support was built
//! for: kept entries are `K̃ ∈ (e^{θ_s}, 1]` and the corrections are
//! `exp(x − ḡ) ∈ [e^{−d}, e^{d}]`.
//!
//! Two re-absorption tiers keep the schedule cheap:
//! * **partial** (`O(nnz)`): move `ḡ` to a new reference and recompute
//!   `f̄` + the absorbed values over the *existing* support — valid while
//!   the reference stays within `σ` of the anchor it was truncated at;
//! * **full** (`O(m·n)`): re-truncate the support from the dense
//!   log-kernel (the cost of about one dense logsumexp iteration).
//!
//! The support threshold carries the slack that makes both tiers exact:
//! `θ_s = θ − 2(σ + d)` guarantees that every entry within `θ` of *any*
//! histogram's own row maximum is stored, for all scalings within drift
//! `d` of a reference within `σ` of the anchor — so the batched product
//! matches the per-histogram dense logsumexp up to the same truncation
//! error as the single-histogram hybrid.

use super::{Csr, Mat};

/// Floor on the effective support threshold `θ_s = θ − 2(σ + covered)`.
///
/// Stored absorbed entries are `exp(s)` with `s ∈ [θ_s, 0]`: once `θ_s`
/// falls below `−ln(f64::MIN_POSITIVE) ≈ −708.4`, entries near the
/// bottom of the slack range underflow to subnormals/zero — they are
/// "kept" in structure but degenerate in value, so the exactness
/// guarantee the slack exists for is silently broken. −700 keeps every
/// stored value a normal f64 with headroom. Requested capacities whose
/// slack would cross the floor are clamped (see
/// [`AbsorbedLogCsr::max_covered`]) and flagged via
/// [`AbsorbedLogCsr::support_saturated`] so callers can degrade
/// explicitly instead of iterating on a hollow support.
pub const THETA_SUPPORT_FLOOR: f64 = -700.0;

/// Absorbed, θ-truncated sparse log-kernel with a shared support across
/// `N` histograms. The absorbed linear entries live in a [`Csr`] (so
/// the batched product reuses its threaded SpMM kernels, including the
/// unrolled `nh == 1` GEMV lane); the raw log-kernel entries are kept
/// alongside for `O(nnz)` partial re-absorption.
#[derive(Clone, Debug)]
pub struct AbsorbedLogCsr {
    /// Absorbed linear kernel `K̃ = exp(log K + g[col] − f[row])` on the
    /// truncated support.
    k: Csr,
    /// Raw `log K` entries on the same support (index-aligned with the
    /// CSR values).
    log_vals: Vec<f64>,
    /// Current absorbed reference duals (length n).
    g: Vec<f64>,
    /// Reference at the last full truncation (the support's anchor).
    g_anchor: Vec<f64>,
    /// Row shifts `f[i] = max_j (log K[i,j] + g[j])` (length m).
    f: Vec<f64>,
    /// User-facing truncation threshold θ (< 0) the support slack is
    /// derived from.
    theta: f64,
    /// Per-histogram drift capacity the current support covers.
    covered: f64,
    /// Anchor-shift budget: partial re-absorption is exact while the
    /// reference stays within `σ` of `g_anchor` (inclusive — the slack
    /// derivation is non-strict throughout, so the boundary
    /// `anchor_shift == σ` is itself exact).
    sigma: f64,
    /// Whether the requested drift capacity was clamped because its
    /// support slack would cross [`THETA_SUPPORT_FLOOR`].
    saturated: bool,
}

impl AbsorbedLogCsr {
    /// Full truncation: absorb `gref` into `a_log`, keep entries within
    /// the slack-widened threshold `θ − 2(σ + covered)` of their row
    /// maximum. `covered` is the per-histogram drift the support must
    /// stay exact for; `sigma` bounds future reference moves served by
    /// partial re-absorption.
    pub fn from_dense_log(
        a_log: &Mat,
        gref: &[f64],
        theta: f64,
        covered: f64,
        sigma: f64,
    ) -> Self {
        assert_eq!(gref.len(), a_log.cols(), "reference dual length");
        debug_assert!(covered >= 0.0 && sigma >= 0.0, "capacities are non-negative");
        let (m, n) = (a_log.rows(), a_log.cols());
        let (covered, saturated) = Self::clamp_covered(theta, covered, sigma);
        let mut out = Self {
            k: Csr::from_parts(m, n, vec![0; m + 1], Vec::new(), Vec::new()),
            log_vals: Vec::new(),
            g: gref.to_vec(),
            g_anchor: gref.to_vec(),
            f: vec![f64::NEG_INFINITY; m],
            theta,
            covered,
            sigma,
            saturated,
        };
        out.truncate_from(a_log);
        out
    }

    /// Re-truncate the support from the dense log-kernel against a new
    /// reference and drift capacity — the `O(m·n)` tier. Resets the
    /// anchor. The capacity is clamped to [`AbsorbedLogCsr::max_covered`]
    /// (flagged via [`AbsorbedLogCsr::support_saturated`]) so the stored
    /// entries never underflow past [`THETA_SUPPORT_FLOOR`].
    pub fn retruncate(&mut self, a_log: &Mat, gref: &[f64], covered: f64) {
        assert_eq!(a_log.rows(), self.rows(), "kernel rows");
        assert_eq!(a_log.cols(), self.cols(), "kernel cols");
        assert_eq!(gref.len(), self.cols(), "reference dual length");
        self.g.copy_from_slice(gref);
        self.g_anchor.copy_from_slice(gref);
        let (covered, saturated) = Self::clamp_covered(self.theta, covered, self.sigma);
        self.covered = covered;
        self.saturated = saturated;
        self.truncate_from(a_log);
    }

    /// Largest drift capacity whose support slack keeps the effective
    /// threshold `θ − 2(σ + covered)` at or above
    /// [`THETA_SUPPORT_FLOOR`] (0 when even a zero-drift support would
    /// cross it). Callers that need more capacity than this have no
    /// numerically sound shared support and must degrade to a dense
    /// logsumexp path.
    pub fn max_covered(theta: f64, sigma: f64) -> f64 {
        ((theta - THETA_SUPPORT_FLOOR) / 2.0 - sigma).max(0.0)
    }

    fn clamp_covered(theta: f64, covered: f64, sigma: f64) -> (f64, bool) {
        let cap = Self::max_covered(theta, sigma);
        if covered > cap {
            (cap, true)
        } else {
            (covered, false)
        }
    }

    fn truncate_from(&mut self, a_log: &Mat) {
        let (m, n) = (a_log.rows(), a_log.cols());
        let theta_s = self.theta_support();
        let mut row_ptr = Vec::with_capacity(m + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        self.log_vals.clear();
        row_ptr.push(0);
        for i in 0..m {
            let arow = a_log.row(i);
            let mut mx = f64::NEG_INFINITY;
            for j in 0..n {
                let v = arow[j] + self.g[j];
                if v > mx {
                    mx = v;
                }
            }
            self.f[i] = mx;
            if mx > f64::NEG_INFINITY {
                for j in 0..n {
                    let s = arow[j] + self.g[j] - mx;
                    if s >= theta_s {
                        col_idx.push(j as u32);
                        self.log_vals.push(arow[j]);
                        vals.push(s.exp());
                    }
                }
            }
            row_ptr.push(vals.len());
        }
        self.k = Csr::from_parts(m, n, row_ptr, col_idx, vals);
    }

    /// Partial re-absorption (`O(nnz)`): move the reference to `gref`
    /// and recompute the row shifts + absorbed values over the existing
    /// support. Exact while `anchor_shift(gref) ≤ sigma` (the caller's
    /// contract — [`AbsorbedLogCsr::retruncate`] otherwise). The
    /// boundary is *inclusive*: every inequality in the support-slack
    /// derivation is non-strict, so `anchor_shift == sigma` is exact —
    /// pinned by the `partial_reabsorb_exact_at_sigma_boundary` test.
    pub fn reabsorb(&mut self, gref: &[f64]) {
        assert_eq!(gref.len(), self.cols(), "reference dual length");
        self.g.copy_from_slice(gref);
        let rows = self.rows();
        let (row_ptr, col_idx, vals) = self.k.parts_mut();
        for i in 0..rows {
            let (s, e) = (row_ptr[i], row_ptr[i + 1]);
            let mut mx = f64::NEG_INFINITY;
            for idx in s..e {
                let v = self.log_vals[idx] + self.g[col_idx[idx] as usize];
                if v > mx {
                    mx = v;
                }
            }
            self.f[i] = mx;
            for idx in s..e {
                let v = self.log_vals[idx] + self.g[col_idx[idx] as usize];
                vals[idx] = (v - mx).exp();
            }
        }
    }

    /// How far a candidate reference sits from the support's anchor —
    /// compared against `sigma` to pick partial vs. full re-absorption.
    pub fn anchor_shift(&self, gref: &[f64]) -> f64 {
        debug_assert_eq!(gref.len(), self.cols());
        gref.iter()
            .zip(&self.g_anchor)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Per-histogram drift `max_j |x[j,h] − g[j]|` of `N` log-scaling
    /// columns against the absorbed reference, written into `out`
    /// (length `N`, no allocation on the hot path).
    pub fn max_drift_into(&self, x_log: &Mat, out: &mut [f64]) {
        let nh = x_log.cols();
        assert_eq!(x_log.rows(), self.cols(), "scaling rows");
        assert_eq!(out.len(), nh, "drift slots");
        out.fill(0.0);
        let xs = x_log.as_slice();
        for j in 0..self.cols() {
            let gj = self.g[j];
            let xrow = &xs[j * nh..(j + 1) * nh];
            for (o, &x) in out.iter_mut().zip(xrow) {
                let d = (x - gj).abs();
                if d > *o {
                    *o = d;
                }
            }
        }
    }

    /// Batched absorbed log-product: `out[i,h] = log Σ_j exp(log K[i,j]
    /// + x[j,h])` over the stored support, computed as the sparse GEMM
    /// `K̃ · (exp(x − ḡ))` with per-column scaling corrections, then
    /// shifted back by `f̄`. `ex` (n×N) and `lin` (m×N) are caller-owned
    /// scratch so the hot loop never allocates.
    pub fn log_matmul_into(
        &self,
        x_log: &Mat,
        ex: &mut Mat,
        lin: &mut Mat,
        out: &mut Mat,
        threads: usize,
    ) {
        let nh = x_log.cols();
        assert_eq!(x_log.rows(), self.cols(), "inner dims");
        assert_eq!((ex.rows(), ex.cols()), (self.cols(), nh), "ex scratch shape");
        assert_eq!((lin.rows(), lin.cols()), (self.rows(), nh), "lin scratch shape");
        assert_eq!((out.rows(), out.cols()), (self.rows(), nh), "out shape");

        // Per-column scaling corrections: ex = exp(x − ḡ), bounded by
        // e^{±covered} while the caller's drift contract holds.
        {
            let xs = x_log.as_slice();
            let es = ex.as_mut_slice();
            for j in 0..self.cols() {
                let gj = self.g[j];
                for h in 0..nh {
                    es[j * nh + h] = (xs[j * nh + h] - gj).exp();
                }
            }
        }

        self.matmul_into(ex, lin, threads);
        self.log_matmul_finish(lin, out);
    }

    /// Streamed partial fold of the absorbed product: `lin +=
    /// K̃[:, col0..col0+xr) · exp(x_slice − ḡ[col0..])`, with `x_slice`
    /// the `xr×N` flat log-scaling slice and `ex_slice` caller scratch
    /// of the same shape. Folding every slice of a column partition
    /// (any order) then calling [`AbsorbedLogCsr::log_matmul_finish`]
    /// equals one [`AbsorbedLogCsr::log_matmul_into`] up to
    /// summation-order round-off. Caller contract (same as the batched
    /// product): every folded slice stays within the covered drift of
    /// the reference — checked upstream via
    /// [`AbsorbedLogCsr::slice_drift`].
    #[allow(clippy::too_many_arguments)]
    pub fn log_matmul_fold(
        &self,
        col0: usize,
        xr: usize,
        x_slice: &[f64],
        nh: usize,
        ex_slice: &mut [f64],
        lin: &mut Mat,
        threads: usize,
    ) {
        assert!(col0 + xr <= self.cols(), "column range");
        assert_eq!(x_slice.len(), xr * nh, "slice shape");
        assert_eq!(ex_slice.len(), xr * nh, "ex scratch shape");
        assert_eq!((lin.rows(), lin.cols()), (self.rows(), nh), "lin shape");
        for (j, g) in self.g[col0..col0 + xr].iter().enumerate() {
            for h in 0..nh {
                ex_slice[j * nh + h] = (x_slice[j * nh + h] - g).exp();
            }
        }
        self.k.matmul_fold(col0, xr, ex_slice, nh, lin.as_mut_slice(), threads);
    }

    /// Column-subset absorbed product for per-column freezing without
    /// repacking: compute the batched product for the `active` columns
    /// only (strictly increasing indices into `x_log`'s histograms),
    /// writing packed results — `out[:, k]` is the product of column
    /// `active[k]`. Bit-identical to `x_log.select_cols(active)` followed
    /// by [`AbsorbedLogCsr::log_matmul_into`], minus the intermediate
    /// copy: callers that keep full-width state while converged columns
    /// are frozen pay O(nnz·|active|) instead of O(nnz·N). `ex` (n×w)
    /// and `lin` (m×w) are caller scratch with `w = active.len()`.
    pub fn log_matmul_select(
        &self,
        x_log: &Mat,
        active: &[usize],
        ex: &mut Mat,
        lin: &mut Mat,
        out: &mut Mat,
        threads: usize,
    ) {
        let nh = x_log.cols();
        let w = active.len();
        debug_assert!(active.windows(2).all(|p| p[0] < p[1]), "active strictly increasing");
        assert!(active.iter().all(|&c| c < nh), "active column in range");
        assert_eq!(x_log.rows(), self.cols(), "inner dims");
        assert_eq!((ex.rows(), ex.cols()), (self.cols(), w), "ex scratch shape");
        assert_eq!((lin.rows(), lin.cols()), (self.rows(), w), "lin scratch shape");
        assert_eq!((out.rows(), out.cols()), (self.rows(), w), "out shape");
        {
            let xs = x_log.as_slice();
            let es = ex.as_mut_slice();
            for j in 0..self.cols() {
                let gj = self.g[j];
                let xrow = &xs[j * nh..(j + 1) * nh];
                for (k, &c) in active.iter().enumerate() {
                    es[j * w + k] = (xrow[c] - gj).exp();
                }
            }
        }
        self.matmul_into(ex, lin, threads);
        self.log_matmul_finish(lin, out);
    }

    /// Incremental greedy fold: `lin += K̃[:, changed] · dex`, with
    /// `changed` a strictly increasing set of *columns* of the absorbed
    /// kernel and `dex` the packed `k×N` block of correction deltas
    /// `exp(x_new − ḡ) − exp(x_old − ḡ)` at those columns. Folding the
    /// delta into a previously computed full accumulator is exact (the
    /// batched product is linear in `ex`), so a k-coordinate dual update
    /// refreshes the product in `O(k·nnz_col)` instead of `O(nnz)` —
    /// provided every updated scaling stays within the covered drift of
    /// the reference (the caller's admission check, same contract as
    /// [`AbsorbedLogCsr::log_matmul_fold`]). Delegates to
    /// [`Csr::matmul_delta_cols`]: banded, bit-identical at any thread
    /// count.
    pub fn matmul_delta_cols(
        &self,
        changed: &[u32],
        dex: &[f64],
        nh: usize,
        lin: &mut Mat,
        threads: usize,
    ) {
        assert_eq!((lin.rows(), lin.cols()), (self.rows(), nh), "lin shape");
        self.k.matmul_delta_cols(changed, dex, nh, lin.as_mut_slice(), threads);
    }

    /// Row-subset absorbed product for greedy row refresh: computes
    /// `out[p,h] = log Σ_j exp(log K[rows_sel[p],j] + x[j,h])` for the
    /// selected rows only (strictly increasing), `lin` and `out` packed
    /// `k×N` caller scratch, `ex` full `n×N` scratch. Equivalent to the
    /// matching rows of [`AbsorbedLogCsr::log_matmul_into`] — the
    /// correction pass is identical and the selected-row reductions run
    /// in the same stored order — at `O(n·N + Σ_{i∈sel} nnz_i)` cost.
    #[allow(clippy::too_many_arguments)]
    pub fn log_matmul_rows(
        &self,
        x_log: &Mat,
        rows_sel: &[u32],
        ex: &mut Mat,
        lin: &mut Mat,
        out: &mut Mat,
        threads: usize,
    ) {
        let nh = x_log.cols();
        let w = rows_sel.len();
        assert_eq!(x_log.rows(), self.cols(), "inner dims");
        assert_eq!((ex.rows(), ex.cols()), (self.cols(), nh), "ex scratch shape");
        assert_eq!((lin.rows(), lin.cols()), (w, nh), "lin scratch shape");
        assert_eq!((out.rows(), out.cols()), (w, nh), "out shape");
        {
            let xs = x_log.as_slice();
            let es = ex.as_mut_slice();
            for j in 0..self.cols() {
                let gj = self.g[j];
                for h in 0..nh {
                    es[j * nh + h] = (xs[j * nh + h] - gj).exp();
                }
            }
        }
        self.k.matmul_select_rows(rows_sel, ex, lin.as_mut_slice(), threads);
        let os = out.as_mut_slice();
        let ls = lin.as_slice();
        for (p, &ri) in rows_sel.iter().enumerate() {
            let fi = self.f[ri as usize];
            for h in 0..nh {
                let lq = ls[p * nh + h];
                os[p * nh + h] = if lq > 0.0 { fi + lq.ln() } else { f64::NEG_INFINITY };
            }
        }
    }

    /// Row shifts `f̄` (length m) — greedy callers that maintain the
    /// linear accumulator incrementally finish selected rows themselves
    /// as `f̄[i] + ln lin[i]`.
    pub fn row_shifts(&self) -> &[f64] {
        &self.f
    }

    /// Max drift of a scattered coordinate set against the absorbed
    /// reference: `max_p max_h |vals[p,h] − ḡ[changed[p]]|`, with `vals`
    /// the packed `k×N` block of updated log-scalings. The greedy
    /// admission check — a sparse update whose coordinates all sit
    /// within the covered drift can ride the incremental
    /// [`AbsorbedLogCsr::matmul_delta_cols`] fold; anything beyond the
    /// budget must take the re-absorption path.
    pub fn coords_drift(&self, changed: &[u32], vals: &[f64], nh: usize) -> f64 {
        assert_eq!(vals.len(), changed.len() * nh, "coords shape");
        let mut worst: f64 = 0.0;
        for (p, &j) in changed.iter().enumerate() {
            let gj = self.g[j as usize];
            for &x in &vals[p * nh..(p + 1) * nh] {
                let d = (x - gj).abs();
                if d > worst {
                    worst = d;
                }
            }
        }
        worst
    }

    /// Shift a (fully folded or batch-computed) linear accumulator back
    /// to the log domain: `out = f̄ + ln lin`. A zero accumulator entry
    /// only happens on a fully masked row (f̄ = −∞): kept entries are
    /// ≥ e^{θ_s} and the drift contract keeps exp(x − ḡ) ≥ e^{−d}, so
    /// no kept term can underflow the sum to zero.
    pub fn log_matmul_finish(&self, lin: &Mat, out: &mut Mat) {
        let nh = lin.cols();
        assert_eq!((lin.rows(), nh), (out.rows(), out.cols()), "shape");
        assert_eq!(lin.rows(), self.rows(), "rows");
        let os = out.as_mut_slice();
        let ls = lin.as_slice();
        for i in 0..self.rows() {
            let fi = self.f[i];
            for h in 0..nh {
                let lq = ls[i * nh + h];
                os[i * nh + h] = if lq > 0.0 { fi + lq.ln() } else { f64::NEG_INFINITY };
            }
        }
    }

    /// Max drift of an `xr×N` log-scaling slice (rows `[col0,
    /// col0+xr)` of the full input) against the absorbed reference —
    /// the per-slice admission check of the streamed fold (drift is a
    /// row-decomposable max, so per-slice checks compose into exactly
    /// the full-input check).
    pub fn slice_drift(&self, col0: usize, xr: usize, x_slice: &[f64], nh: usize) -> f64 {
        assert!(col0 + xr <= self.cols(), "column range");
        assert_eq!(x_slice.len(), xr * nh, "slice shape");
        let mut worst: f64 = 0.0;
        for (j, g) in self.g[col0..col0 + xr].iter().enumerate() {
            for &x in &x_slice[j * nh..(j + 1) * nh] {
                let d = (x - g).abs();
                if d > worst {
                    worst = d;
                }
            }
        }
        worst
    }

    /// Batched multi-RHS product over the absorbed values: `out = K̃·x`
    /// — delegates to the shared [`Csr::matmul_into`] kernels (banded
    /// threading, unrolled `nh == 1` GEMV lane).
    pub fn matmul_into(&self, x: &Mat, out: &mut Mat, threads: usize) {
        self.k.matmul_into(x, out, threads);
    }

    pub fn rows(&self) -> usize {
        self.k.rows()
    }

    pub fn cols(&self) -> usize {
        self.k.cols()
    }

    pub fn nnz(&self) -> usize {
        self.k.nnz()
    }

    /// Fill fraction (1 = dense) of the shared support.
    pub fn density(&self) -> f64 {
        self.k.density()
    }

    /// User-facing truncation threshold θ this kernel derives its
    /// support slack from.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Per-histogram drift capacity the current support is exact for.
    pub fn covered(&self) -> f64 {
        self.covered
    }

    /// Anchor-shift budget for partial re-absorption.
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Currently absorbed reference duals (length n) — what per-node
    /// drift probes compare incoming log-scaling slices against.
    pub fn reference(&self) -> &[f64] {
        &self.g
    }

    /// Whether the last (re)truncation clamped the requested drift
    /// capacity to keep the support representable — the caller's signal
    /// to stop relying on the full requested slack (degrade path).
    pub fn support_saturated(&self) -> bool {
        self.saturated
    }

    /// Effective support threshold `θ − 2(σ + covered)`, floored at
    /// [`THETA_SUPPORT_FLOOR`] (the capacity clamp keeps the raw value
    /// above the floor already; the max is defense in depth for callers
    /// probing hypothetical tunings).
    pub fn theta_support(&self) -> f64 {
        (self.theta - 2.0 * (self.sigma + self.covered)).max(THETA_SUPPORT_FLOOR)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Reference: per-histogram dense logsumexp of `a_log + x`.
    fn dense_log_product(a_log: &Mat, x_log: &Mat) -> Mat {
        a_log.logsumexp(x_log, 1)
    }

    fn scratch(k: &AbsorbedLogCsr, nh: usize) -> (Mat, Mat, Mat) {
        (Mat::zeros(k.cols(), nh), Mat::zeros(k.rows(), nh), Mat::zeros(k.rows(), nh))
    }

    #[test]
    fn zero_reference_matches_dense_logsumexp() {
        let mut rng = Rng::seed_from(51);
        let (m, n, nh) = (13, 9, 4);
        let a_log = Mat::rand_uniform(m, n, -8.0, 0.0, &mut rng);
        let x_log = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, 15.0, 15.0);
        assert_eq!(k.nnz(), m * n, "moderate range: nothing truncated");
        let (mut ex, mut lin, mut out) = scratch(&k, nh);
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut out, 1);
        let want = dense_log_product(&a_log, &x_log);
        assert!(out.allclose(&want, 1e-12));
    }

    #[test]
    fn partial_reabsorb_equals_full_retruncate() {
        let mut rng = Rng::seed_from(52);
        let (m, n, nh) = (11, 7, 3);
        let a_log = Mat::rand_uniform(m, n, -30.0, 0.0, &mut rng);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-3.0, 3.0)).collect();
        let mut partial = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, 5.0, 5.0);
        let mut full = partial.clone();
        // The shift stays within σ, so the partial tier must reproduce
        // the full rebuild exactly on the (identical) support.
        assert!(partial.anchor_shift(&gref) <= partial.sigma());
        partial.reabsorb(&gref);
        full.retruncate(&a_log, &gref, 5.0);
        let x_log = Mat::rand_uniform(n, nh, -4.0, 4.0, &mut rng);
        let (mut ex, mut lin, mut o1) = scratch(&partial, nh);
        let mut o2 = o1.clone();
        partial.log_matmul_into(&x_log, &mut ex, &mut lin, &mut o1, 1);
        full.log_matmul_into(&x_log, &mut ex, &mut lin, &mut o2, 1);
        assert!(o1.allclose(&o2, 1e-13));
        // Both agree with the dense per-histogram product.
        assert!(o1.allclose(&dense_log_product(&a_log, &x_log), 1e-12));
    }

    #[test]
    fn support_slack_keeps_per_histogram_truncation_invisible() {
        // A kernel with genuinely droppable entries (range ≫ |θ_s|):
        // after a reference move within σ and per-histogram scalings
        // within the covered drift, the truncated product matches the
        // dense logsumexp to round-off.
        let mut rng = Rng::seed_from(53);
        let (m, n, nh) = (17, 13, 2);
        let a_log = Mat::rand_uniform(m, n, -400.0, 0.0, &mut rng);
        let gref = vec![0.0; n];
        let k0 = AbsorbedLogCsr::from_dense_log(&a_log, &gref, -60.0, 10.0, 10.0);
        assert!(k0.nnz() < m * n, "the -400 range must truncate something");
        let mut k = k0;
        let shift: Vec<f64> = (0..n).map(|_| rng.uniform_range(-8.0, 8.0)).collect();
        k.reabsorb(&shift);
        let mut x_log = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x_log[(j, h)] = shift[j] + rng.uniform_range(-9.0, 9.0);
            }
        }
        let (mut ex, mut lin, mut out) = scratch(&k, nh);
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut out, 1);
        let want = dense_log_product(&a_log, &x_log);
        for i in 0..m {
            for h in 0..nh {
                let (w, g) = (want[(i, h)], out[(i, h)]);
                assert!(
                    (w - g).abs() <= 1e-11 * w.abs().max(1.0),
                    "({i},{h}): {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn partial_reabsorb_exact_at_sigma_boundary() {
        // The ≤/< contract at the boundary: a reference move of exactly
        // σ must still be served exactly by the O(nnz) partial tier —
        // every inequality in the slack derivation is non-strict. The
        // kernel range (−400) guarantees genuinely truncated entries, so
        // a wrong (strict) boundary would surface as a truncation error
        // against the dense oracle.
        let mut rng = Rng::seed_from(55);
        let (m, n, nh) = (19, 11, 3);
        let a_log = Mat::rand_uniform(m, n, -400.0, 0.0, &mut rng);
        let (covered, sigma) = (5.0, 5.0);
        let mut partial =
            AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, covered, sigma);
        assert!(partial.nnz() < m * n, "the -400 range must truncate something");
        let mut full = partial.clone();
        // Shift sitting exactly on the σ boundary (alternating sign so
        // the move is not a uniform gauge shift).
        let gref: Vec<f64> = (0..n).map(|j| if j % 2 == 0 { sigma } else { -sigma }).collect();
        assert_eq!(partial.anchor_shift(&gref), sigma, "exact boundary case");
        partial.reabsorb(&gref);
        full.retruncate(&a_log, &gref, covered);
        // Scalings sitting exactly on the covered-drift boundary too.
        let mut x_log = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x_log[(j, h)] = gref[j] + if (j + h) % 2 == 0 { covered } else { -covered };
            }
        }
        let (mut ex, mut lin, mut o1) = scratch(&partial, nh);
        let mut o2 = o1.clone();
        partial.log_matmul_into(&x_log, &mut ex, &mut lin, &mut o1, 1);
        full.log_matmul_into(&x_log, &mut ex, &mut lin, &mut o2, 1);
        let want = dense_log_product(&a_log, &x_log);
        for i in 0..m {
            for h in 0..nh {
                let (w, g) = (want[(i, h)], o1[(i, h)]);
                assert!(
                    (w - g).abs() <= 1e-11 * w.abs().max(1.0),
                    "partial ({i},{h}): {g} vs {w}"
                );
                let g2 = o2[(i, h)];
                assert!(
                    (w - g2).abs() <= 1e-11 * w.abs().max(1.0),
                    "full ({i},{h}): {g2} vs {w}"
                );
            }
        }
    }

    #[test]
    fn support_slack_clamps_at_the_representable_floor() {
        // A capacity request whose slack would push θ_s below the exp
        // floor is clamped, flagged, and the clamped kernel still
        // matches the dense oracle within the capacity it reports.
        let mut rng = Rng::seed_from(56);
        let (m, n, nh) = (9, 7, 2);
        let a_log = Mat::rand_uniform(m, n, -30.0, 0.0, &mut rng);
        let (theta, sigma) = (-60.0, 20.0);
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], theta, 500.0, sigma);
        assert!(k.support_saturated(), "500 must exceed the representable capacity");
        let cap = AbsorbedLogCsr::max_covered(theta, sigma);
        assert_eq!(k.covered(), cap);
        assert_eq!(k.theta_support(), THETA_SUPPORT_FLOOR);
        // Every stored absorbed value is a normal (non-degenerate) f64.
        assert!(k.nnz() > 0);
        // Within the clamped capacity the product stays exact.
        let x_log = Mat::rand_uniform(n, nh, -3.0, 3.0, &mut rng);
        let (mut ex, mut lin, mut out) = scratch(&k, nh);
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut out, 1);
        assert!(out.allclose(&dense_log_product(&a_log, &x_log), 1e-11));
        // An unsaturated request reports exactly what it asked for, and
        // retruncate re-evaluates the clamp.
        let mut k2 = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], theta, 10.0, sigma);
        assert!(!k2.support_saturated());
        assert_eq!(k2.covered(), 10.0);
        k2.retruncate(&a_log, &vec![0.0; n], 1e6);
        assert!(k2.support_saturated());
        assert_eq!(k2.covered(), cap);
    }

    #[test]
    fn streamed_folds_reassemble_the_batched_product() {
        // Fold a 4-slice column partition in scrambled order, finish,
        // and compare against the one-shot batched product and the
        // dense oracle — the streamed-exchange equivalence the
        // coordinators rely on.
        let mut rng = Rng::seed_from(57);
        let (m, n, nh) = (23, 20, 3);
        let a_log = Mat::rand_uniform(m, n, -200.0, 0.0, &mut rng);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &gref, -60.0, 8.0, 8.0);
        assert!(k.nnz() < m * n, "the -200 range must truncate something");
        let mut x_log = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x_log[(j, h)] = gref[j] + rng.uniform_range(-6.0, 6.0);
            }
        }
        let (mut ex, mut lin, mut want) = scratch(&k, nh);
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut want, 1);
        let mut acc = Mat::zeros(m, nh);
        let mut ex_slice = vec![0.0; 5 * nh];
        for &j in &[1usize, 3, 0, 2] {
            let (c0, xr) = (j * 5, 5);
            let slice = &x_log.as_slice()[c0 * nh..(c0 + xr) * nh];
            assert!(k.slice_drift(c0, xr, slice, nh) <= k.covered());
            k.log_matmul_fold(c0, xr, slice, nh, &mut ex_slice, &mut acc, 1);
        }
        let mut got = Mat::zeros(m, nh);
        k.log_matmul_finish(&acc, &mut got);
        assert!(got.allclose(&want, 1e-12));
        assert!(got.allclose(&dense_log_product(&a_log, &x_log), 1e-11));
    }

    #[test]
    fn slice_drift_composes_into_the_full_drift() {
        let mut rng = Rng::seed_from(58);
        let (n, nh) = (12, 2);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let k = AbsorbedLogCsr::from_dense_log(&Mat::zeros(6, n), &gref, -60.0, 15.0, 15.0);
        let x = Mat::rand_uniform(n, nh, -3.0, 3.0, &mut rng);
        let mut full = [0.0; 2];
        k.max_drift_into(&x, &mut full);
        let full_max = full.iter().cloned().fold(0.0, f64::max);
        let merged = [0usize, 1, 2]
            .iter()
            .map(|&j| k.slice_drift(j * 4, 4, &x.as_slice()[j * 4 * nh..(j + 1) * 4 * nh], nh))
            .fold(0.0, f64::max);
        assert_eq!(merged, full_max);
    }

    #[test]
    fn select_product_matches_packed_full_product() {
        // The per-column-freeze primitive: producing only the active
        // columns must be bit-identical to packing the scalings first
        // and running the full batched product.
        let mut rng = Rng::seed_from(59);
        let (m, n, nh) = (15, 12, 5);
        let a_log = Mat::rand_uniform(m, n, -200.0, 0.0, &mut rng);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &gref, -60.0, 8.0, 8.0);
        let mut x_log = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x_log[(j, h)] = gref[j] + rng.uniform_range(-6.0, 6.0);
            }
        }
        let active = [0usize, 2, 3];
        let w = active.len();
        let (mut ex, mut lin) = (Mat::zeros(n, w), Mat::zeros(m, w));
        let mut got = Mat::zeros(m, w);
        k.log_matmul_select(&x_log, &active, &mut ex, &mut lin, &mut got, 1);
        let packed = x_log.select_cols(&active);
        let (mut ex2, mut lin2, mut want) = scratch(&k, w);
        k.log_matmul_into(&packed, &mut ex2, &mut lin2, &mut want, 1);
        assert!(got.allclose(&want, 0.0), "select ≡ pack + full product");
        assert!(got.allclose(
            &dense_log_product(&a_log, &x_log).select_cols(&active),
            1e-11
        ));
    }

    #[test]
    fn delta_fold_tracks_coordinate_updates_within_drift() {
        // A k-coordinate dual update folded into the maintained linear
        // accumulator must match the from-scratch batched product on
        // every row ≤ 1e-12, and the fold must be bit-identical at
        // thread counts {1, 2, 8} — the incremental-marginal contract
        // the greedy solver leans on.
        let mut rng = Rng::seed_from(61);
        let (m, n, nh) = (31, 24, 3);
        let a_log = Mat::rand_uniform(m, n, -200.0, 0.0, &mut rng);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &gref, -60.0, 8.0, 8.0);
        assert!(k.nnz() < m * n, "the -200 range must truncate something");
        let mut x0 = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x0[(j, h)] = gref[j] + rng.uniform_range(-4.0, 4.0);
            }
        }
        let (mut ex, mut lin, mut _out0) = scratch(&k, nh);
        k.log_matmul_into(&x0, &mut ex, &mut lin, &mut _out0, 1);
        // Perturb a scattered coordinate subset, staying within drift.
        let changed: Vec<u32> = (0..n as u32).filter(|_| rng.uniform() < 0.25).collect();
        assert!(!changed.is_empty());
        let mut x1 = x0.clone();
        let mut dex = vec![0.0; changed.len() * nh];
        let mut new_vals = vec![0.0; changed.len() * nh];
        for (p, &j) in changed.iter().enumerate() {
            for h in 0..nh {
                x1[(j as usize, h)] = gref[j as usize] + rng.uniform_range(-4.0, 4.0);
                new_vals[p * nh + h] = x1[(j as usize, h)];
                dex[p * nh + h] = (x1[(j as usize, h)] - gref[j as usize]).exp()
                    - (x0[(j as usize, h)] - gref[j as usize]).exp();
            }
        }
        assert!(k.coords_drift(&changed, &new_vals, nh) <= k.covered(), "admitted");
        let base = lin.clone();
        k.matmul_delta_cols(&changed, &dex, nh, &mut lin, 1);
        let mut got = Mat::zeros(m, nh);
        k.log_matmul_finish(&lin, &mut got);
        let (mut ex2, mut lin2, mut want) = scratch(&k, nh);
        k.log_matmul_into(&x1, &mut ex2, &mut lin2, &mut want, 1);
        for i in 0..m {
            for h in 0..nh {
                let (g, w) = (got[(i, h)], want[(i, h)]);
                assert!((g - w).abs() <= 1e-12 * w.abs().max(1.0), "({i},{h}): {g} vs {w}");
            }
        }
        for threads in [2usize, 8] {
            let mut par = base.clone();
            k.matmul_delta_cols(&changed, &dex, nh, &mut par, threads);
            assert_eq!(
                par.as_slice(),
                lin.as_slice(),
                "threads={threads} must be bit-identical"
            );
        }
    }

    #[test]
    fn row_subset_product_matches_the_batched_rows() {
        // The packed row-subset absorbed product equals the matching
        // rows of the batched product bit for bit, at {1, 2, 8} threads.
        let mut rng = Rng::seed_from(62);
        let (m, n, nh) = (29, 18, 4);
        let a_log = Mat::rand_uniform(m, n, -200.0, 0.0, &mut rng);
        let gref: Vec<f64> = (0..n).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &gref, -60.0, 8.0, 8.0);
        let mut x_log = Mat::zeros(n, nh);
        for j in 0..n {
            for h in 0..nh {
                x_log[(j, h)] = gref[j] + rng.uniform_range(-6.0, 6.0);
            }
        }
        let (mut ex, mut lin, mut full) = scratch(&k, nh);
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut full, 1);
        let sel: Vec<u32> = (0..m as u32).filter(|_| rng.uniform() < 0.4).collect();
        let w = sel.len();
        let (mut ex_s, mut lin_s) = (Mat::zeros(n, nh), Mat::zeros(w, nh));
        let mut got = Mat::zeros(w, nh);
        k.log_matmul_rows(&x_log, &sel, &mut ex_s, &mut lin_s, &mut got, 1);
        for (p, &ri) in sel.iter().enumerate() {
            for h in 0..nh {
                assert_eq!(
                    got[(p, h)].to_bits(),
                    full[(ri as usize, h)].to_bits(),
                    "row {ri} h {h}"
                );
            }
        }
        for threads in [2usize, 8] {
            let mut par = Mat::zeros(w, nh);
            k.log_matmul_rows(&x_log, &sel, &mut ex_s, &mut lin_s, &mut par, threads);
            assert_eq!(par.as_slice(), got.as_slice(), "threads={threads}");
        }
        // Row shifts line up with the finish identity on selected rows.
        for (p, &ri) in sel.iter().enumerate() {
            let fi = k.row_shifts()[ri as usize];
            for h in 0..nh {
                let lq = lin_s[(p, h)];
                let expect = if lq > 0.0 { fi + lq.ln() } else { f64::NEG_INFINITY };
                assert_eq!(got[(p, h)].to_bits(), expect.to_bits());
            }
        }
    }

    #[test]
    fn masked_rows_stay_neg_infinity() {
        let ni = f64::NEG_INFINITY;
        let a = Mat::from_vec(2, 3, vec![0.0, ni, -1.0, ni, ni, ni]);
        let k = AbsorbedLogCsr::from_dense_log(&a, &[0.0; 3], -60.0, 15.0, 15.0);
        assert_eq!(k.nnz(), 2);
        let x = Mat::zeros(3, 2);
        let (mut ex, mut lin, mut out) = scratch(&k, 2);
        k.log_matmul_into(&x, &mut ex, &mut lin, &mut out, 1);
        assert!(out[(0, 0)].is_finite());
        assert_eq!(out[(1, 0)], ni);
        assert_eq!(out[(1, 1)], ni);
    }

    #[test]
    fn drift_is_per_histogram() {
        let k = AbsorbedLogCsr::from_dense_log(
            &Mat::zeros(2, 3),
            &[1.0, 2.0, 3.0],
            -60.0,
            15.0,
            15.0,
        );
        let x = Mat::from_vec(3, 2, vec![1.0, 4.0, 2.0, 2.0, 3.0, -1.0]);
        let mut drift = [0.0f64; 2];
        k.max_drift_into(&x, &mut drift);
        // hist 0: |1−1|, |2−2|, |3−3| = 0; hist 1: |4−1|, |2−2|, |−1−3|.
        assert_eq!(drift[0], 0.0);
        assert_eq!(drift[1], 4.0);
    }

    #[test]
    fn threaded_matches_serial() {
        let mut rng = Rng::seed_from(54);
        let (m, n, nh) = (57, 33, 3);
        let mut a_log = Mat::rand_uniform(m, n, -200.0, 0.0, &mut rng);
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.5 {
                    a_log[(i, j)] = f64::NEG_INFINITY;
                }
            }
        }
        let k = AbsorbedLogCsr::from_dense_log(&a_log, &vec![0.0; n], -60.0, 15.0, 15.0);
        let x_log = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let (mut ex, mut lin, mut serial) = scratch(&k, nh);
        let mut par = serial.clone();
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut serial, 1);
        k.log_matmul_into(&x_log, &mut ex, &mut lin, &mut par, 4);
        assert!(serial.allclose(&par, 0.0));
    }
}
