//! Compressed sparse row kernels for the sparsity study (paper §IV-D).
//!
//! The paper parameterizes workloads by "off-diagonal block sparsity"
//! `s ∈ {0, 0.5, 0.9, 1}`. Sparse Gibbs kernels arise when the cost of
//! far pairs is set to +∞ (K entries underflow to exact 0); CSR lets the
//! native backend exploit that, and the ablation bench compares it
//! against dense dispatch.

use super::Mat;

/// CSR matrix (f64).
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f64>,
}

impl Csr {
    /// Build from dense, dropping entries with `|x| <= drop_tol`.
    pub fn from_dense(m: &Mat, drop_tol: f64) -> Self {
        let mut row_ptr = Vec::with_capacity(m.rows() + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..m.rows() {
            for (j, &x) in m.row(i).iter().enumerate() {
                if x.abs() > drop_tol {
                    col_idx.push(j as u32);
                    vals.push(x);
                }
            }
            row_ptr.push(vals.len());
        }
        Self { rows: m.rows(), cols: m.cols(), row_ptr, col_idx, vals }
    }

    /// Assemble from raw CSR arrays without materializing a dense
    /// intermediate (the multi-histogram absorbed kernel keeps its own
    /// arrays in [`super::AbsorbedLogCsr`]; this stays for callers that
    /// build plain sparse kernels incrementally).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        vals: Vec<f64>,
    ) -> Self {
        assert_eq!(row_ptr.len(), rows + 1, "row_ptr length");
        assert_eq!(col_idx.len(), vals.len(), "col_idx/vals length");
        assert_eq!(*row_ptr.last().unwrap(), vals.len(), "row_ptr tail");
        Self { rows, cols, row_ptr, col_idx, vals }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Raw CSR arrays `(row_ptr, col_idx, vals)` with the values
    /// mutable — the absorbed kernel re-scales its stored entries in
    /// place during partial re-absorption without rebuilding the
    /// structure.
    pub fn parts_mut(&mut self) -> (&[usize], &[u32], &mut [f64]) {
        (&self.row_ptr, &self.col_idx, &mut self.vals)
    }

    /// Fill fraction (1 = dense).
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Streamed partial fold: `out += self[:, col0..col0+xr) · x_slice`
    /// (`x_slice` is `xr×N` flat, `out` is `rows×N` flat). Stored
    /// columns are ascending within each row (every constructor emits
    /// them that way), so the range bounds come from two binary searches
    /// per row — `O(nnz_range + rows·log nnz_row)` per fold instead of a
    /// full `O(nnz)` scan per slice.
    pub fn matmul_fold(
        &self,
        col0: usize,
        xr: usize,
        x_slice: &[f64],
        nh: usize,
        out: &mut [f64],
        threads: usize,
    ) {
        assert!(col0 + xr <= self.cols, "column range");
        assert_eq!(x_slice.len(), xr * nh, "slice shape");
        assert_eq!(out.len(), self.rows * nh, "out shape");
        let hi_col = (col0 + xr) as u32;
        let run = |band: &mut [f64], r0: usize, r1: usize| {
            for i in r0..r1 {
                let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                let cols = &self.col_idx[s..e];
                debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "CSR columns ascending");
                let lo = s + cols.partition_point(|&c| c < col0 as u32);
                let hi = s + cols.partition_point(|&c| c < hi_col);
                if nh == 1 {
                    let mut acc = 0.0;
                    for idx in lo..hi {
                        acc += self.vals[idx] * x_slice[self.col_idx[idx] as usize - col0];
                    }
                    band[i - r0] += acc;
                } else {
                    let orow = &mut band[(i - r0) * nh..(i - r0 + 1) * nh];
                    for idx in lo..hi {
                        let v = self.vals[idx];
                        let k = self.col_idx[idx] as usize - col0;
                        let xrow = &x_slice[k * nh..(k + 1) * nh];
                        for (o, &xv) in orow.iter_mut().zip(xrow) {
                            *o += v * xv;
                        }
                    }
                }
            }
        };
        super::dense::band_rows(out, self.rows, nh, threads, run);
    }

    /// Incremental column-delta fold for greedy coordinate updates:
    /// `out += self[:, changed] · dx`, where `changed` is a strictly
    /// increasing set of column indices and `dx` is the packed `k×N`
    /// delta block (`dx[p]` belongs to column `changed[p]`). A two-
    /// pointer merge walks each row's (ascending) stored columns
    /// against `changed`, so a k-column update costs
    /// `O(Σ_i (min(nnz_i, k) + merge))` instead of a full `O(nnz)`
    /// product — the compute half of the greedy exchange bargain.
    /// Banded over rows like every other kernel: bit-identical at any
    /// thread count.
    pub fn matmul_delta_cols(
        &self,
        changed: &[u32],
        dx: &[f64],
        nh: usize,
        out: &mut [f64],
        threads: usize,
    ) {
        debug_assert!(changed.windows(2).all(|w| w[0] < w[1]), "changed ascending");
        assert!(changed.last().is_none_or(|&c| (c as usize) < self.cols), "column range");
        assert_eq!(dx.len(), changed.len() * nh, "delta shape");
        assert_eq!(out.len(), self.rows * nh, "out shape");
        if changed.is_empty() {
            return;
        }
        let run = |band: &mut [f64], r0: usize, r1: usize| {
            for i in r0..r1 {
                let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                let cols = &self.col_idx[s..e];
                // Skip straight to the changed window within this row.
                let mut idx = s + cols.partition_point(|&c| c < changed[0]);
                let mut p = 0usize;
                let orow = &mut band[(i - r0) * nh..(i - r0 + 1) * nh];
                while idx < e && p < changed.len() {
                    let c = self.col_idx[idx];
                    let t = changed[p];
                    if c == t {
                        let v = self.vals[idx];
                        let drow = &dx[p * nh..(p + 1) * nh];
                        for (o, &d) in orow.iter_mut().zip(drow) {
                            *o += v * d;
                        }
                        idx += 1;
                        p += 1;
                    } else if c < t {
                        idx += 1;
                    } else {
                        p += 1;
                    }
                }
            }
        };
        super::dense::band_rows(out, self.rows, nh, threads, run);
    }

    /// Row-subset product: `out[p] = self[rows_sel[p], :] · x`, with
    /// `out` the packed `k×N` block of the selected rows (strictly
    /// increasing indices). Banded over the *subset index space*, so a
    /// k-row product costs `O(Σ_{i∈sel} nnz_i)` and stays bit-identical
    /// at every thread count (each selected row is summed serially by
    /// exactly one band).
    pub fn matmul_select_rows(
        &self,
        rows_sel: &[u32],
        x: &Mat,
        out: &mut [f64],
        threads: usize,
    ) {
        debug_assert!(rows_sel.windows(2).all(|w| w[0] < w[1]), "rows ascending");
        assert!(rows_sel.last().is_none_or(|&r| (r as usize) < self.rows), "row range");
        assert_eq!(self.cols, x.rows(), "inner dims");
        let nh = x.cols();
        assert_eq!(out.len(), rows_sel.len() * nh, "out shape");
        out.fill(0.0);
        let xs = x.as_slice();
        let run = |band: &mut [f64], s0: usize, s1: usize| {
            for (p, &ri) in rows_sel[s0..s1].iter().enumerate() {
                let i = ri as usize;
                let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                if nh == 1 {
                    // Same four-lane unrolled reduction as the full
                    // GEMV path, so selected rows match it bit for bit.
                    let len = e - s;
                    let chunks = s + len / 4 * 4;
                    let (mut s0a, mut s1a, mut s2a, mut s3a) = (0.0, 0.0, 0.0, 0.0);
                    let mut idx = s;
                    while idx < chunks {
                        s0a += self.vals[idx] * xs[self.col_idx[idx] as usize];
                        s1a += self.vals[idx + 1] * xs[self.col_idx[idx + 1] as usize];
                        s2a += self.vals[idx + 2] * xs[self.col_idx[idx + 2] as usize];
                        s3a += self.vals[idx + 3] * xs[self.col_idx[idx + 3] as usize];
                        idx += 4;
                    }
                    let mut acc = 0.0;
                    while idx < e {
                        acc += self.vals[idx] * xs[self.col_idx[idx] as usize];
                        idx += 1;
                    }
                    band[p] = acc + ((s0a + s1a) + (s2a + s3a));
                    continue;
                }
                let orow = &mut band[p * nh..(p + 1) * nh];
                for idx in s..e {
                    let k = self.col_idx[idx] as usize;
                    let v = self.vals[idx];
                    let xrow = &xs[k * nh..(k + 1) * nh];
                    for (o, &xv) in orow.iter_mut().zip(xrow) {
                        *o += v * xv;
                    }
                }
            }
        };
        super::dense::band_rows(out, rows_sel.len(), nh, threads, run);
    }

    /// `out = self · x`, multi-RHS; `threads > 1` splits rows.
    pub fn matmul_into(&self, x: &Mat, out: &mut Mat, threads: usize) {
        assert_eq!(self.cols, x.rows());
        assert_eq!(out.rows(), self.rows);
        assert_eq!(out.cols(), x.cols());
        let nh = x.cols();
        out.as_mut_slice().fill(0.0);

        let run = |band: &mut [f64], r0: usize, r1: usize| {
            if nh == 1 {
                // GEMV fast path (parity with `Mat::matmul_into`):
                // four-lane unrolled dot product over the stored entries.
                let xs = x.as_slice();
                for i in r0..r1 {
                    let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                    let len = e - s;
                    let chunks = s + len / 4 * 4;
                    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                    let mut idx = s;
                    while idx < chunks {
                        s0 += self.vals[idx] * xs[self.col_idx[idx] as usize];
                        s1 += self.vals[idx + 1] * xs[self.col_idx[idx + 1] as usize];
                        s2 += self.vals[idx + 2] * xs[self.col_idx[idx + 2] as usize];
                        s3 += self.vals[idx + 3] * xs[self.col_idx[idx + 3] as usize];
                        idx += 4;
                    }
                    let mut acc = 0.0;
                    while idx < e {
                        acc += self.vals[idx] * xs[self.col_idx[idx] as usize];
                        idx += 1;
                    }
                    band[i - r0] = acc + ((s0 + s1) + (s2 + s3));
                }
                return;
            }
            for i in r0..r1 {
                let orow = &mut band[(i - r0) * nh..(i - r0 + 1) * nh];
                for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                    let k = self.col_idx[idx] as usize;
                    let v = self.vals[idx];
                    let xrow = &x.as_slice()[k * nh..(k + 1) * nh];
                    for (o, &xv) in orow.iter_mut().zip(xrow) {
                        *o += v * xv;
                    }
                }
            }
        };

        super::dense::band_rows(out.as_mut_slice(), self.rows, nh, threads, run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn density_of_identity() {
        let mut eye = Mat::zeros(8, 8);
        for i in 0..8 {
            eye[(i, i)] = 1.0;
        }
        let c = Csr::from_dense(&eye, 0.0);
        assert_eq!(c.nnz(), 8);
        assert!((c.density() - 0.125).abs() < 1e-15);
    }

    #[test]
    fn empty_rows_are_fine() {
        let mut m = Mat::zeros(4, 3);
        m[(2, 1)] = 5.0;
        let c = Csr::from_dense(&m, 0.0);
        let x = Mat::ones(3, 2);
        let mut out = Mat::zeros(4, 2);
        c.matmul_into(&x, &mut out, 2);
        assert_eq!(out[(2, 0)], 5.0);
        assert_eq!(out[(0, 0)], 0.0);
    }

    #[test]
    fn gemv_fast_path_matches_dense() {
        // nh == 1 takes the unrolled dot-product path; it must agree
        // with the dense GEMV on the same kernel, serial and threaded.
        let mut rng = Rng::seed_from(17);
        let mut d = Mat::rand_uniform(61, 43, 0.1, 1.0, &mut rng);
        for i in 0..61 {
            for j in 0..43 {
                if rng.uniform() < 0.75 {
                    d[(i, j)] = 0.0;
                }
            }
        }
        let c = Csr::from_dense(&d, 0.0);
        let x = Mat::rand_uniform(43, 1, 0.1, 1.0, &mut rng);
        let want = d.matmul(&x, 1);
        let mut got = Mat::zeros(61, 1);
        c.matmul_into(&x, &mut got, 1);
        assert!(got.allclose(&want, 1e-12));
        let mut par = Mat::zeros(61, 1);
        c.matmul_into(&x, &mut par, 3);
        assert!(par.allclose(&got, 0.0));
    }

    #[test]
    fn range_folds_reassemble_the_full_product() {
        // Folding a column partition slice by slice — in a scrambled
        // order — must reproduce the one-shot product.
        let mut rng = Rng::seed_from(23);
        let (m, n, nh) = (37, 24, 3);
        let mut d = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.6 {
                    d[(i, j)] = 0.0;
                }
            }
        }
        let c = Csr::from_dense(&d, 0.0);
        let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let want = d.matmul(&x, 1);
        let mut acc = vec![0.0; m * nh];
        for &j in &[2usize, 0, 3, 1] {
            let (c0, xr) = (j * 6, 6);
            let slice = &x.as_slice()[c0 * nh..(c0 + xr) * nh];
            c.matmul_fold(c0, xr, slice, nh, &mut acc, 1);
        }
        let got = Mat::from_vec(m, nh, acc);
        assert!(got.allclose(&want, 1e-12));
        // Threaded folds agree exactly with serial folds.
        let mut par = vec![0.0; m * nh];
        for &j in &[2usize, 0, 3, 1] {
            let (c0, xr) = (j * 6, 6);
            let slice = &x.as_slice()[c0 * nh..(c0 + xr) * nh];
            c.matmul_fold(c0, xr, slice, nh, &mut par, 3);
        }
        assert_eq!(par, got.as_slice().to_vec());
    }

    #[test]
    fn delta_cols_fold_matches_the_recomputed_product() {
        // Perturb a scattered column subset: folding the delta into the
        // stale product must match recomputing from scratch ≤ 1e-12,
        // and the fold must be bit-identical at thread counts {1, 2, 8}.
        let mut rng = Rng::seed_from(41);
        let (m, n, nh) = (53, 40, 3);
        let mut d = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.6 {
                    d[(i, j)] = 0.0;
                }
            }
        }
        let c = Csr::from_dense(&d, 0.0);
        let x0 = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
        let changed: Vec<u32> = (0..n as u32).filter(|_| rng.uniform() < 0.2).collect();
        assert!(!changed.is_empty());
        let mut x1 = x0.clone();
        let mut dx = vec![0.0; changed.len() * nh];
        for (p, &j) in changed.iter().enumerate() {
            for h in 0..nh {
                let delta = rng.uniform_range(-0.5, 0.5);
                x1[(j as usize, h)] += delta;
                dx[p * nh + h] = x1[(j as usize, h)] - x0[(j as usize, h)];
            }
        }
        let base = d.matmul(&x0, 1);
        let want = d.matmul(&x1, 1);
        let mut acc = base.as_slice().to_vec();
        c.matmul_delta_cols(&changed, &dx, nh, &mut acc, 1);
        for (i, (&g, &w)) in acc.iter().zip(want.as_slice()).enumerate() {
            assert!((g - w).abs() <= 1e-12 * w.abs().max(1.0), "lane {i}: {g} vs {w}");
        }
        for threads in [2usize, 8] {
            let mut par = base.as_slice().to_vec();
            c.matmul_delta_cols(&changed, &dx, nh, &mut par, threads);
            assert_eq!(par, acc, "threads={threads} must be bit-identical");
        }
        // Empty selection is a no-op.
        let mut untouched = base.as_slice().to_vec();
        c.matmul_delta_cols(&[], &[], nh, &mut untouched, 2);
        assert_eq!(untouched, base.as_slice().to_vec());
    }

    #[test]
    fn select_rows_is_bit_identical_to_the_full_product() {
        // The packed row-subset product must equal the matching rows of
        // the full product bit for bit (same stored-order reductions,
        // same unrolled nh==1 lane) at thread counts {1, 2, 8}.
        let mut rng = Rng::seed_from(42);
        for nh in [1usize, 3] {
            let (m, n) = (47, 31);
            let mut d = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
            for i in 0..m {
                for j in 0..n {
                    if rng.uniform() < 0.7 {
                        d[(i, j)] = 0.0;
                    }
                }
            }
            let c = Csr::from_dense(&d, 0.0);
            let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
            let mut full = Mat::zeros(m, nh);
            c.matmul_into(&x, &mut full, 1);
            let sel: Vec<u32> = (0..m as u32).filter(|_| rng.uniform() < 0.3).collect();
            let mut got = vec![0.0; sel.len() * nh];
            c.matmul_select_rows(&sel, &x, &mut got, 1);
            for (p, &ri) in sel.iter().enumerate() {
                for h in 0..nh {
                    assert_eq!(
                        got[p * nh + h],
                        full[(ri as usize, h)],
                        "nh={nh} row {ri} h {h}"
                    );
                }
            }
            for threads in [2usize, 8] {
                let mut par = vec![0.0; sel.len() * nh];
                c.matmul_select_rows(&sel, &x, &mut par, threads);
                assert_eq!(par, got, "nh={nh} threads={threads}");
            }
        }
    }

    #[test]
    fn threaded_equals_serial() {
        let mut rng = Rng::seed_from(8);
        let mut d = Mat::rand_uniform(57, 33, 0.0, 1.0, &mut rng);
        for i in 0..57 {
            for j in 0..33 {
                if rng.uniform() < 0.8 {
                    d[(i, j)] = 0.0;
                }
            }
        }
        let c = Csr::from_dense(&d, 0.0);
        let x = Mat::rand_uniform(33, 4, 0.0, 1.0, &mut rng);
        let mut a = Mat::zeros(57, 4);
        let mut b = Mat::zeros(57, 4);
        c.matmul_into(&x, &mut a, 1);
        c.matmul_into(&x, &mut b, 3);
        assert!(a.allclose(&b, 0.0));
    }
}
