//! Numerics-representation domain: linear vs. log-stabilized.
//!
//! The Sinkhorn fixed point can be iterated on linear scalings
//! `u = a/(K v)` or on log-scalings `log u = log a − LSE(log K + log v)`
//! (Schmitzer's stabilized scaling; PAPERS.md). The linear form is a
//! GEMV — fast, but `K = exp(−C/ε)` underflows f64 once `max C / ε`
//! exceeds ~745. The log form replaces the product with a row-wise
//! logsumexp whose running maximum is absorbed into the exponent, so
//! every `exp()` argument is ≤ 0 and the small-ε regime stays exact.
//!
//! Everything above this module (runtime block operators, solvers,
//! coordinators, CLI) is generic over [`Domain`]: the same protocol code
//! exchanges either linear scalings or log-scalings — the latter being
//! exactly the quantity the paper's privacy layer instruments.

/// Which representation the scaling state and kernel use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Scalings `u, v`; kernel `K = exp(−C/ε)`; products are GEMV/GEMM.
    Linear,
    /// Log-scalings `log u, log v`; kernel `log K = −C/ε`; products are
    /// row-wise logsumexp with max absorption.
    Log,
}

impl Domain {
    /// The multiplicative identity in this representation: the all-ones
    /// scaling vector is `1` linearly and `0` in the log domain.
    #[inline]
    pub fn one(self) -> f64 {
        match self {
            Domain::Linear => 1.0,
            Domain::Log => 0.0,
        }
    }

    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "linear" | "lin" => Some(Domain::Linear),
            "log" => Some(Domain::Log),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Domain::Linear => "linear",
            Domain::Log => "log",
        }
    }
}

/// Tuning for the stabilized sparse/hybrid log-domain engine
/// (Schmitzer's sparse scaling + kernel absorption; PAPERS.md
/// 1610.06519). All of it is advisory: a backend without a sparse or
/// hybrid operator simply ignores it and runs the dense logsumexp path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stabilization {
    /// Row-relative truncation threshold `θ` (log space, < 0): a kernel
    /// entry whose exponent sits more than `|θ|` below its row maximum
    /// is dropped as zero mass. The default −60 is far below f64's
    /// relative resolution of a logsumexp (ln ε_machine ≈ −36), so
    /// truncation error is invisible next to round-off.
    pub truncation_theta: f64,
    /// Re-absorption threshold `τ` (> 0) for the hybrid schedule: linear
    /// GEMV iterations run on the dual-absorbed kernel until the
    /// exchanged log-scalings drift more than `τ` from the absorbed
    /// point, then the kernel is re-absorbed + re-truncated. `+∞`
    /// disables the hybrid (pure logsumexp iterations).
    pub absorb_threshold: f64,
    /// Dispatch the sparse logsumexp operator when the truncated
    /// kernel's density falls below this fraction (1 = always sparse,
    /// 0 = never).
    pub sparse_density_cutoff: f64,
    /// Fleet-synchronized absorption (`--fleet-absorb`): hybrid
    /// operators stop deciding re-absorption on their own (beyond the
    /// emergency drift guard) and instead obey coordinator-broadcast
    /// reference-dual commands, so every node of a federated run
    /// re-absorbs the same reference in lock-step and shard supports
    /// stay mutually consistent. No effect on centralized solves or
    /// non-hybrid operators.
    pub fleet_absorb: bool,
}

impl Default for Stabilization {
    fn default() -> Self {
        Self {
            truncation_theta: -60.0,
            absorb_threshold: 15.0,
            sparse_density_cutoff: 0.25,
            fleet_absorb: false,
        }
    }
}

impl Stabilization {
    /// No truncation, no absorption, no sparse dispatch — the pure
    /// dense log-domain path of PR 1 (the oracle the hybrid is pinned
    /// against in the property tests).
    pub fn disabled() -> Self {
        Self {
            truncation_theta: f64::NEG_INFINITY,
            absorb_threshold: f64::INFINITY,
            sparse_density_cutoff: 0.0,
            fleet_absorb: false,
        }
    }

    /// Whether the absorption-hybrid schedule is active.
    pub fn hybrid_enabled(&self) -> bool {
        self.absorb_threshold.is_finite()
    }
}
