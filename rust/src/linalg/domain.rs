//! Numerics-representation domain: linear vs. log-stabilized.
//!
//! The Sinkhorn fixed point can be iterated on linear scalings
//! `u = a/(K v)` or on log-scalings `log u = log a − LSE(log K + log v)`
//! (Schmitzer's stabilized scaling; PAPERS.md). The linear form is a
//! GEMV — fast, but `K = exp(−C/ε)` underflows f64 once `max C / ε`
//! exceeds ~745. The log form replaces the product with a row-wise
//! logsumexp whose running maximum is absorbed into the exponent, so
//! every `exp()` argument is ≤ 0 and the small-ε regime stays exact.
//!
//! Everything above this module (runtime block operators, solvers,
//! coordinators, CLI) is generic over [`Domain`]: the same protocol code
//! exchanges either linear scalings or log-scalings — the latter being
//! exactly the quantity the paper's privacy layer instruments.

/// Which representation the scaling state and kernel use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Scalings `u, v`; kernel `K = exp(−C/ε)`; products are GEMV/GEMM.
    Linear,
    /// Log-scalings `log u, log v`; kernel `log K = −C/ε`; products are
    /// row-wise logsumexp with max absorption.
    Log,
}

impl Domain {
    /// The multiplicative identity in this representation: the all-ones
    /// scaling vector is `1` linearly and `0` in the log domain.
    #[inline]
    pub fn one(self) -> f64 {
        match self {
            Domain::Linear => 1.0,
            Domain::Log => 0.0,
        }
    }

    pub fn parse(s: &str) -> Option<Domain> {
        match s {
            "linear" | "lin" => Some(Domain::Linear),
            "log" => Some(Domain::Log),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Domain::Linear => "linear",
            Domain::Log => "log",
        }
    }
}
