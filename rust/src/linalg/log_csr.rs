//! `−∞`-aware compressed sparse row twin of [`Csr`] for log-domain
//! kernels (Schmitzer's stabilized *sparse* scaling; PAPERS.md
//! 1610.06519).
//!
//! A log-kernel entry `log K[i,j] = −C[i,j]/ε` is dropped when its
//! exponent, shifted by the row maximum, falls below a threshold `θ`:
//! the entry would contribute at most `e^θ` of the row's logsumexp mass.
//! Dropped entries behave exactly like `−∞` in the dense logsumexp
//! kernels — zero mass — so at `θ = −∞` the truncation is a pure
//! compression of hard-masked (`−∞`) entries and the sparse product is
//! bit-identical to the dense one.
//!
//! [`Csr`]: super::Csr

use super::Mat;

/// Sparse log-domain matrix: stored entries are finite log-kernel
/// values; every absent entry is `−∞` (zero mass).
#[derive(Clone, Debug)]
pub struct LogCsr {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f64>,
    theta: f64,
}

impl LogCsr {
    /// Truncate a dense log-kernel at row-relative threshold `theta`:
    /// keep `a[i,j]` iff it is finite and `a[i,j] − row_max_i ≥ theta`.
    /// `theta = −∞` keeps every finite entry (mask compression only);
    /// a fully `−∞` row stays empty and logsumexps to `−∞`.
    pub fn from_dense_log(m: &Mat, theta: f64) -> Self {
        let mut row_ptr = Vec::with_capacity(m.rows() + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..m.rows() {
            let row = m.row(i);
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if mx > f64::NEG_INFINITY {
                for (j, &x) in row.iter().enumerate() {
                    if x > f64::NEG_INFINITY && x - mx >= theta {
                        col_idx.push(j as u32);
                        vals.push(x);
                    }
                }
            }
            row_ptr.push(vals.len());
        }
        Self { rows: m.rows(), cols: m.cols(), row_ptr, col_idx, vals, theta }
    }

    /// Density the truncation *would* produce, without allocating the
    /// CSR arrays — the cheap probe that dispatch decisions run before
    /// committing to a build.
    pub fn density_of(m: &Mat, theta: f64) -> f64 {
        if m.rows() * m.cols() == 0 {
            return 0.0;
        }
        let mut kept = 0usize;
        for i in 0..m.rows() {
            let row = m.row(i);
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if mx > f64::NEG_INFINITY {
                kept += row
                    .iter()
                    .filter(|&&x| x > f64::NEG_INFINITY && x - mx >= theta)
                    .count();
            }
        }
        kept as f64 / (m.rows() * m.cols()) as f64
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Truncation threshold this matrix was built with.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Fill fraction (1 = dense) — the quantity the runtime's sparse
    /// dispatch cutoff is compared against.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Sparse log-domain product: `out[i,h] = log Σ_k exp(vals[i,k] +
    /// x[k,h])` over the stored entries only. Mirrors
    /// [`Mat::logsumexp_into`] — max absorption, `nh == 1` LSE-GEMV fast
    /// path, banded row split dispatched onto the persistent worker
    /// pool — but touches `nnz` entries instead of `rows × cols`.
    pub fn logsumexp_into(&self, x: &Mat, out: &mut Mat, threads: usize) {
        assert_eq!(self.cols, x.rows(), "inner dims");
        assert_eq!(out.rows(), self.rows, "out rows");
        assert_eq!(out.cols(), x.cols(), "out cols");
        let nh = x.cols();

        let run = |band: &mut [f64], r0: usize, r1: usize| {
            if nh == 1 {
                // LSE-GEMV fast path: two sweeps over the row's stored
                // entries — max, then the max-absorbed exponential sum.
                for i in r0..r1 {
                    let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                    let mut mx = f64::NEG_INFINITY;
                    for idx in s..e {
                        let v = self.vals[idx] + x.as_slice()[self.col_idx[idx] as usize];
                        if v > mx {
                            mx = v;
                        }
                    }
                    if mx == f64::NEG_INFINITY {
                        band[i - r0] = f64::NEG_INFINITY; // empty / all-masked row
                        continue;
                    }
                    let mut sum = 0.0;
                    for idx in s..e {
                        let v = self.vals[idx] + x.as_slice()[self.col_idx[idx] as usize];
                        sum += (v - mx).exp();
                    }
                    band[i - r0] = mx + sum.ln();
                }
                return;
            }
            // Multi-histogram path: per-column online max/sum
            // accumulators over the stored entries (O(N) scratch per
            // thread, reused across rows).
            let mut mx = vec![f64::NEG_INFINITY; nh];
            let mut sum = vec![0.0f64; nh];
            for i in r0..r1 {
                mx.fill(f64::NEG_INFINITY);
                sum.fill(0.0);
                for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                    let aik = self.vals[idx];
                    let k = self.col_idx[idx] as usize;
                    let xrow = &x.as_slice()[k * nh..(k + 1) * nh];
                    for h in 0..nh {
                        let v = aik + xrow[h];
                        if v == f64::NEG_INFINITY {
                            continue;
                        }
                        if v <= mx[h] {
                            sum[h] += (v - mx[h]).exp();
                        } else {
                            sum[h] = sum[h] * (mx[h] - v).exp() + 1.0;
                            mx[h] = v;
                        }
                    }
                }
                let orow = &mut band[(i - r0) * nh..(i - r0 + 1) * nh];
                for h in 0..nh {
                    orow[h] = if sum[h] > 0.0 { mx[h] + sum[h].ln() } else { f64::NEG_INFINITY };
                }
            }
        };

        super::dense::band_rows(out.as_mut_slice(), self.rows, nh, threads, run);
    }

    /// Row-subset exact logsumexp for greedy coordinate refresh:
    /// `out[p,h] = log Σ_k exp(vals[rows_sel[p],k] + x[k,h])` over the
    /// stored entries of the selected rows only (strictly increasing
    /// indices), `out` packed `k×N`. A k-row refresh costs
    /// `O(Σ_{i∈sel} nnz_i)` instead of the full product. Banded over
    /// the subset index space: each selected row is reduced serially by
    /// exactly one band, so results are bit-identical at every thread
    /// count — and bit-identical to the matching rows of
    /// [`LogCsr::logsumexp_into`], which walks each row in the same
    /// stored order.
    pub fn logsumexp_rows(&self, rows_sel: &[u32], x: &Mat, out: &mut [f64], threads: usize) {
        debug_assert!(rows_sel.windows(2).all(|w| w[0] < w[1]), "rows ascending");
        assert!(rows_sel.last().is_none_or(|&r| (r as usize) < self.rows), "row range");
        assert_eq!(self.cols, x.rows(), "inner dims");
        let nh = x.cols();
        assert_eq!(out.len(), rows_sel.len() * nh, "out shape");
        let xs = x.as_slice();
        let run = |band: &mut [f64], s0: usize, s1: usize| {
            if nh == 1 {
                for (p, &ri) in rows_sel[s0..s1].iter().enumerate() {
                    let i = ri as usize;
                    let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                    let mut mx = f64::NEG_INFINITY;
                    for idx in s..e {
                        let v = self.vals[idx] + xs[self.col_idx[idx] as usize];
                        if v > mx {
                            mx = v;
                        }
                    }
                    if mx == f64::NEG_INFINITY {
                        band[p] = f64::NEG_INFINITY;
                        continue;
                    }
                    let mut sum = 0.0;
                    for idx in s..e {
                        let v = self.vals[idx] + xs[self.col_idx[idx] as usize];
                        sum += (v - mx).exp();
                    }
                    band[p] = mx + sum.ln();
                }
                return;
            }
            let mut mx = vec![f64::NEG_INFINITY; nh];
            let mut sum = vec![0.0f64; nh];
            for (p, &ri) in rows_sel[s0..s1].iter().enumerate() {
                let i = ri as usize;
                mx.fill(f64::NEG_INFINITY);
                sum.fill(0.0);
                for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                    let aik = self.vals[idx];
                    let k = self.col_idx[idx] as usize;
                    let xrow = &xs[k * nh..(k + 1) * nh];
                    for h in 0..nh {
                        let v = aik + xrow[h];
                        if v == f64::NEG_INFINITY {
                            continue;
                        }
                        if v <= mx[h] {
                            sum[h] += (v - mx[h]).exp();
                        } else {
                            sum[h] = sum[h] * (mx[h] - v).exp() + 1.0;
                            mx[h] = v;
                        }
                    }
                }
                let orow = &mut band[p * nh..(p + 1) * nh];
                for h in 0..nh {
                    orow[h] = if sum[h] > 0.0 { mx[h] + sum[h].ln() } else { f64::NEG_INFINITY };
                }
            }
        };
        super::dense::band_rows(out, rows_sel.len(), nh, threads, run);
    }

    /// Convenience allocating sparse log-domain product.
    pub fn logsumexp(&self, x: &Mat, threads: usize) -> Mat {
        let mut out = Mat::zeros(self.rows, x.cols());
        self.logsumexp_into(x, &mut out, threads);
        out
    }

    /// Streamed online-logsumexp fold over stored entries with columns
    /// in `[col0, col0+xr)`, merging into running `(mx, sum)`
    /// accumulators (both `rows×N` flat, seeded `(−∞, 0)`): after every
    /// slice of a column partition has been folded, `mx + ln sum`
    /// equals the full [`LogCsr::logsumexp_into`] row. Stored columns
    /// are ascending per row, so the range bounds come from two binary
    /// searches per row.
    #[allow(clippy::too_many_arguments)]
    pub fn logsumexp_fold(
        &self,
        col0: usize,
        xr: usize,
        x_slice: &[f64],
        nh: usize,
        mx: &mut [f64],
        sum: &mut [f64],
        threads: usize,
    ) {
        assert!(col0 + xr <= self.cols, "column range");
        assert_eq!(x_slice.len(), xr * nh, "slice shape");
        assert_eq!(mx.len(), self.rows * nh, "mx shape");
        assert_eq!(sum.len(), self.rows * nh, "sum shape");
        let hi_col = (col0 + xr) as u32;
        let run = |mx_band: &mut [f64], sum_band: &mut [f64], r0: usize, r1: usize| {
            for i in r0..r1 {
                let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
                let cols = &self.col_idx[s..e];
                debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "CSR columns ascending");
                let lo = s + cols.partition_point(|&c| c < col0 as u32);
                let hi = s + cols.partition_point(|&c| c < hi_col);
                let mrow = &mut mx_band[(i - r0) * nh..(i - r0 + 1) * nh];
                let srow = &mut sum_band[(i - r0) * nh..(i - r0 + 1) * nh];
                for idx in lo..hi {
                    let aik = self.vals[idx];
                    let k = self.col_idx[idx] as usize - col0;
                    let xrow = &x_slice[k * nh..(k + 1) * nh];
                    for h in 0..nh {
                        super::dense::lse_merge(&mut mrow[h], &mut srow[h], aik + xrow[h]);
                    }
                }
            }
        };
        super::dense::band_rows2(mx, sum, self.rows, nh, threads, run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_entries_are_dropped() {
        let ni = f64::NEG_INFINITY;
        let a = Mat::from_vec(2, 3, vec![0.0, ni, -1.0, ni, ni, ni]);
        let lc = LogCsr::from_dense_log(&a, f64::NEG_INFINITY);
        assert_eq!(lc.nnz(), 2);
        assert!((lc.density() - 2.0 / 6.0).abs() < 1e-15);
        // Fully masked row → −∞ logsumexp, not NaN.
        let x = Mat::zeros(3, 1);
        let out = lc.logsumexp(&x, 1);
        assert!(out[(0, 0)].is_finite());
        assert_eq!(out[(1, 0)], f64::NEG_INFINITY);
    }

    #[test]
    fn truncation_is_row_relative() {
        // Row max 0, entries at −1 and −10: θ = −5 keeps the first two.
        let a = Mat::from_vec(1, 3, vec![0.0, -1.0, -10.0]);
        let lc = LogCsr::from_dense_log(&a, -5.0);
        assert_eq!(lc.nnz(), 2);
        assert_eq!(lc.theta(), -5.0);
    }

    #[test]
    fn matches_dense_logsumexp_when_untruncated() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from(11);
        let a = Mat::rand_uniform(13, 9, -4.0, 1.0, &mut rng);
        let x = Mat::rand_uniform(9, 3, -2.0, 2.0, &mut rng);
        let lc = LogCsr::from_dense_log(&a, f64::NEG_INFINITY);
        assert_eq!(lc.nnz(), 13 * 9);
        let want = a.logsumexp(&x, 1);
        let got = lc.logsumexp(&x, 1);
        assert!(got.allclose(&want, 1e-13));
    }

    #[test]
    fn range_folds_merge_into_the_full_logsumexp() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from(13);
        let (m, n, nh) = (29, 20, 2);
        let mut a = Mat::rand_uniform(m, n, -6.0, 0.0, &mut rng);
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.5 {
                    a[(i, j)] = f64::NEG_INFINITY;
                }
            }
        }
        let lc = LogCsr::from_dense_log(&a, f64::NEG_INFINITY);
        let x = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
        let want = lc.logsumexp(&x, 1);
        let mut mx = vec![f64::NEG_INFINITY; m * nh];
        let mut sum = vec![0.0; m * nh];
        // Out-of-order slices — the online merge must not care.
        for &j in &[3usize, 1, 0, 2] {
            let (c0, xr) = (j * 5, 5);
            let slice = &x.as_slice()[c0 * nh..(c0 + xr) * nh];
            lc.logsumexp_fold(c0, xr, slice, nh, &mut mx, &mut sum, 1);
        }
        for i in 0..m {
            for h in 0..nh {
                let got = if sum[i * nh + h] > 0.0 {
                    mx[i * nh + h] + sum[i * nh + h].ln()
                } else {
                    f64::NEG_INFINITY
                };
                let w = want[(i, h)];
                if w == f64::NEG_INFINITY {
                    assert_eq!(got, w, "({i},{h})");
                } else {
                    assert!((got - w).abs() <= 1e-12 * w.abs().max(1.0), "({i},{h}): {got} vs {w}");
                }
            }
        }
    }

    #[test]
    fn row_subset_logsumexp_is_bit_identical_to_the_full_product() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from(14);
        for nh in [1usize, 2] {
            let (m, n) = (45, 28);
            let mut a = Mat::rand_uniform(m, n, -6.0, 0.0, &mut rng);
            for i in 0..m {
                for j in 0..n {
                    if rng.uniform() < 0.5 {
                        a[(i, j)] = f64::NEG_INFINITY;
                    }
                }
            }
            let lc = LogCsr::from_dense_log(&a, f64::NEG_INFINITY);
            let x = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
            let full = lc.logsumexp(&x, 1);
            let sel: Vec<u32> = (0..m as u32).filter(|_| rng.uniform() < 0.3).collect();
            let mut got = vec![0.0; sel.len() * nh];
            lc.logsumexp_rows(&sel, &x, &mut got, 1);
            for (p, &ri) in sel.iter().enumerate() {
                for h in 0..nh {
                    // Same stored-order reduction → exact equality,
                    // including −∞ on fully masked rows.
                    assert_eq!(
                        got[p * nh + h].to_bits(),
                        full[(ri as usize, h)].to_bits(),
                        "nh={nh} row {ri} h {h}"
                    );
                }
            }
            for threads in [2usize, 8] {
                let mut par = vec![0.0; sel.len() * nh];
                lc.logsumexp_rows(&sel, &x, &mut par, threads);
                assert_eq!(
                    par.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "nh={nh} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn threaded_equals_serial() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from(12);
        let mut a = Mat::rand_uniform(57, 33, -5.0, 0.0, &mut rng);
        for i in 0..57 {
            for j in 0..33 {
                if rng.uniform() < 0.7 {
                    a[(i, j)] = f64::NEG_INFINITY;
                }
            }
        }
        let lc = LogCsr::from_dense_log(&a, f64::NEG_INFINITY);
        let x = Mat::rand_uniform(33, 2, -1.0, 1.0, &mut rng);
        let mut s = Mat::zeros(57, 2);
        let mut p = Mat::zeros(57, 2);
        lc.logsumexp_into(&x, &mut s, 1);
        lc.logsumexp_into(&x, &mut p, 3);
        assert!(s.allclose(&p, 0.0));
    }
}
