//! Dense row-major `f64` matrix with blocked, threaded GEMM.

use crate::rng::Rng;
use std::ops::{Index, IndexMut};

/// Cache-tile sizes for the blocked product: a (MC × KC) panel of `A`
/// against (KC × cols) of `x`. Tuned for ~32 KiB L1 / 1 MiB L2.
const MC: usize = 64;
const KC: usize = 256;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Constant-filled matrix — the domain-generic "all-ones scaling"
    /// (`1.0` linear, `0.0` log).
    pub fn full(rows: usize, cols: usize, value: f64) -> Self {
        Self { rows, cols, data: vec![value; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_from(v: &[f64]) -> Self {
        Self::from_vec(v.len(), 1, v.to_vec())
    }

    pub fn rand_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_range(lo, hi)).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of rows `[r0, r1)` — a client's marginal/kernel block.
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat::from_vec(r1 - r0, self.cols, self.data[r0 * self.cols..r1 * self.cols].to_vec())
    }

    /// Copy of columns `[c0, c1)`.
    pub fn col_block(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Mat::zeros(self.rows, w);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Gather an arbitrary column subset into a packed matrix:
    /// `out[:, k] = self[:, cols[k]]`. The batched-solve compaction
    /// primitive — freezing converged histogram columns packs the
    /// survivors left so subsequent N-RHS products shrink with them.
    pub fn select_cols(&self, cols: &[usize]) -> Mat {
        let w = cols.len();
        let mut out = Mat::zeros(self.rows, w);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (k, &c) in cols.iter().enumerate() {
                dst[k] = src[c];
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Tiled transpose to stay cache-friendly for big kernels.
        const T: usize = 32;
        for bi in (0..self.rows).step_by(T) {
            for bj in (0..self.cols).step_by(T) {
                for i in bi..(bi + T).min(self.rows) {
                    for j in bj..(bj + T).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn allclose(&self, other: &Mat, tol: f64) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= tol + tol * b.abs().max(1.0))
    }

    /// `out = self · x`, blocked over (MC, KC) tiles; `threads > 1` splits
    /// the row dimension into disjoint bands dispatched onto the
    /// persistent worker pool. `out` must be pre-shaped — the hot loop
    /// never allocates.
    pub fn matmul_into(&self, x: &Mat, out: &mut Mat, threads: usize) {
        assert_eq!(self.cols, x.rows, "inner dims");
        assert_eq!(out.rows, self.rows, "out rows");
        assert_eq!(out.cols, x.cols, "out cols");
        out.data.fill(0.0);

        let n = self.cols;
        let nh = x.cols;
        let a = &self.data;
        let xs = &x.data;
        band_rows(&mut out.data, self.rows, nh, threads, |band, r0, r1| {
            matmul_rows(a, n, xs, nh, band, r0, r1);
        });
    }

    /// Convenience allocating product.
    pub fn matmul(&self, x: &Mat, threads: usize) -> Mat {
        let mut out = Mat::zeros(self.rows, x.cols);
        self.matmul_into(x, &mut out, threads);
        out
    }

    /// Log-domain product: `out[i,h] = log Σ_k exp(self[i,k] + x[k,h])`,
    /// with `self` a log-kernel block (m×n) and `x` log-scalings (n×N).
    /// The row-wise running maximum is absorbed into the exponent à la
    /// Schmitzer's stabilized scaling, so every `exp` argument is ≤ 0 and
    /// the result is exact even when `exp(self[i,k])` would underflow.
    /// `−∞` entries (hard-sparsified kernel blocks) contribute zero mass.
    ///
    /// Threading mirrors [`Mat::matmul_into`]: the row dimension is split
    /// into disjoint bands dispatched onto the persistent worker pool;
    /// `out` must be pre-shaped and the per-row scratch is O(N).
    pub fn logsumexp_into(&self, x: &Mat, out: &mut Mat, threads: usize) {
        assert_eq!(self.cols, x.rows, "inner dims");
        assert_eq!(out.rows, self.rows, "out rows");
        assert_eq!(out.cols, x.cols, "out cols");

        let n = self.cols;
        let nh = x.cols;
        let a = &self.data;
        let xs = &x.data;
        band_rows(&mut out.data, self.rows, nh, threads, |band, r0, r1| {
            logsumexp_rows(a, n, xs, nh, band, r0, r1);
        });
    }

    /// Convenience allocating log-domain product.
    pub fn logsumexp(&self, x: &Mat, threads: usize) -> Mat {
        let mut out = Mat::zeros(self.rows, x.cols);
        self.logsumexp_into(x, &mut out, threads);
        out
    }

    /// Streamed partial-GEMM fold: `out += self[:, col0..col0+xr) ·
    /// x_slice` with `x_slice` an `xr×N` flat block and `out` a
    /// `rows×N` flat accumulator. Folding every column slice of a
    /// partition (any order) then reading `out` equals one
    /// [`Mat::matmul_into`] up to summation-order round-off — the
    /// slice-streaming exchange consumes peer slices this way as their
    /// frames become deliverable.
    pub fn matmul_fold(
        &self,
        col0: usize,
        xr: usize,
        x_slice: &[f64],
        nh: usize,
        out: &mut [f64],
        threads: usize,
    ) {
        assert!(col0 + xr <= self.cols, "column range");
        assert_eq!(x_slice.len(), xr * nh, "slice shape");
        assert_eq!(out.len(), self.rows * nh, "out shape");
        let run = |band: &mut [f64], r0: usize, r1: usize| {
            for i in r0..r1 {
                let arow = &self.data[i * self.cols + col0..i * self.cols + col0 + xr];
                if nh == 1 {
                    let mut acc = 0.0;
                    for (a, &x) in arow.iter().zip(x_slice) {
                        acc += a * x;
                    }
                    band[i - r0] += acc;
                } else {
                    let orow = &mut band[(i - r0) * nh..(i - r0 + 1) * nh];
                    for (k, &aik) in arow.iter().enumerate() {
                        let xrow = &x_slice[k * nh..(k + 1) * nh];
                        for (o, &xv) in orow.iter_mut().zip(xrow) {
                            *o += aik * xv;
                        }
                    }
                }
            }
        };
        band_rows(out, self.rows, nh, threads, run);
    }

    /// Sparse column-delta fold: `out += self[:, changed] · dx` with
    /// `changed` strictly increasing column indices and `dx` a packed
    /// `changed.len()×N` flat block — the dense counterpart of
    /// [`crate::linalg::Csr::matmul_delta_cols`]. Folding only the
    /// coordinates that moved maintains a cached product in
    /// O(rows·k·N) instead of a full GEMM.
    pub fn matmul_delta_cols(
        &self,
        changed: &[u32],
        dx: &[f64],
        nh: usize,
        out: &mut [f64],
        threads: usize,
    ) {
        assert_eq!(dx.len(), changed.len() * nh, "delta shape");
        assert_eq!(out.len(), self.rows * nh, "out shape");
        assert!(
            changed.windows(2).all(|w| w[0] < w[1]),
            "changed columns must be strictly increasing"
        );
        assert!(
            changed.last().is_none_or(|&c| (c as usize) < self.cols),
            "changed column out of range"
        );
        if changed.is_empty() {
            return;
        }
        let run = |band: &mut [f64], r0: usize, r1: usize| {
            for i in r0..r1 {
                let arow = &self.data[i * self.cols..(i + 1) * self.cols];
                if nh == 1 {
                    let mut acc = 0.0;
                    for (&j, &x) in changed.iter().zip(dx) {
                        acc += arow[j as usize] * x;
                    }
                    band[i - r0] += acc;
                } else {
                    let orow = &mut band[(i - r0) * nh..(i - r0 + 1) * nh];
                    for (&j, dxrow) in changed.iter().zip(dx.chunks_exact(nh)) {
                        let aij = arow[j as usize];
                        for (o, &xv) in orow.iter_mut().zip(dxrow) {
                            *o += aij * xv;
                        }
                    }
                }
            }
        };
        band_rows(out, self.rows, nh, threads, run);
    }

    /// Streamed online-logsumexp fold over the same column range into
    /// running `(mx, sum)` accumulators (both `rows×N` flat, seeded to
    /// `(−∞, 0)`): after folding every slice, `mx + ln sum` equals the
    /// full [`Mat::logsumexp_into`] row (−∞ where `sum` stayed 0). The
    /// running-max merge keeps every `exp` argument ≤ 0 regardless of
    /// the order slices arrive in.
    #[allow(clippy::too_many_arguments)]
    pub fn logsumexp_fold(
        &self,
        col0: usize,
        xr: usize,
        x_slice: &[f64],
        nh: usize,
        mx: &mut [f64],
        sum: &mut [f64],
        threads: usize,
    ) {
        assert!(col0 + xr <= self.cols, "column range");
        assert_eq!(x_slice.len(), xr * nh, "slice shape");
        assert_eq!(mx.len(), self.rows * nh, "mx shape");
        assert_eq!(sum.len(), self.rows * nh, "sum shape");
        let run = |mx_band: &mut [f64], sum_band: &mut [f64], r0: usize, r1: usize| {
            for i in r0..r1 {
                let arow = &self.data[i * self.cols + col0..i * self.cols + col0 + xr];
                let mrow = &mut mx_band[(i - r0) * nh..(i - r0 + 1) * nh];
                let srow = &mut sum_band[(i - r0) * nh..(i - r0 + 1) * nh];
                for (k, &aik) in arow.iter().enumerate() {
                    if aik == f64::NEG_INFINITY {
                        continue;
                    }
                    let xrow = &x_slice[k * nh..(k + 1) * nh];
                    for h in 0..nh {
                        lse_merge(&mut mrow[h], &mut srow[h], aik + xrow[h]);
                    }
                }
            }
        };
        band_rows2(mx, sum, self.rows, nh, threads, run);
    }
}

/// One step of the online running-max logsumexp merge: fold value `v`
/// into a `(mx, sum)` accumulator pair (`sum` is the exponential mass
/// scaled by `e^{−mx}`). The ONE copy of this arithmetic — the streamed
/// fold kernels in `dense.rs` and `log_csr.rs` must stay bit-identical
/// for the streamed ≡ barrier exactness pins, so neither may drift.
#[inline]
pub(crate) fn lse_merge(mx: &mut f64, sum: &mut f64, v: f64) {
    if v == f64::NEG_INFINITY {
        return;
    }
    if v <= *mx {
        *sum += (v - *mx).exp();
    } else {
        *sum = *sum * (*mx - v).exp() + 1.0;
        *mx = v;
    }
}

/// Band base pointer smuggled into the pool closure. Safety: the
/// closure only derives `&mut` bands for the disjoint `[r0, r1)` row
/// ranges [`crate::runtime::Pool::run_bands`] hands out, so no two
/// executors ever alias.
struct BandPtr(*mut f64);
unsafe impl Send for BandPtr {}
unsafe impl Sync for BandPtr {}

/// Split one `rows×nh` flat output into `threads` disjoint row bands
/// executed on the persistent worker pool (the shared threading shape
/// of every batch and fold kernel). `threads` is the band count — the
/// same `div_ceil` decomposition the old scoped-spawn sites used, so
/// results stay bit-identical at every thread count.
pub(crate) fn band_rows(
    out: &mut [f64],
    rows: usize,
    nh: usize,
    threads: usize,
    run: impl Fn(&mut [f64], usize, usize) + Sync,
) {
    let threads = threads.max(1).min(rows.max(1));
    if threads == 1 {
        run(out, 0, rows);
        return;
    }
    assert!(out.len() >= rows * nh, "band shape");
    let base = BandPtr(out.as_mut_ptr());
    let pool = crate::runtime::Pool::global().with_share(threads);
    pool.run_bands(rows, |_band, r0, r1| {
        // Safety: disjoint row ranges (see `BandPtr`), in bounds by the
        // shape assert above.
        let band = unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * nh), (r1 - r0) * nh) };
        run(band, r0, r1);
    });
}

/// [`band_rows`] for fold kernels with two row-aligned accumulators
/// (the online-logsumexp `mx`/`sum` pair).
pub(crate) fn band_rows2(
    a: &mut [f64],
    b: &mut [f64],
    rows: usize,
    nh: usize,
    threads: usize,
    run: impl Fn(&mut [f64], &mut [f64], usize, usize) + Sync,
) {
    let threads = threads.max(1).min(rows.max(1));
    if threads == 1 {
        run(a, b, 0, rows);
        return;
    }
    assert!(a.len() >= rows * nh && b.len() >= rows * nh, "band shape");
    let base_a = BandPtr(a.as_mut_ptr());
    let base_b = BandPtr(b.as_mut_ptr());
    let pool = crate::runtime::Pool::global().with_share(threads);
    pool.run_bands(rows, |_band, r0, r1| {
        // Safety: disjoint row ranges (see `BandPtr`), in bounds by the
        // shape assert above.
        let (band_a, band_b) = unsafe {
            (
                std::slice::from_raw_parts_mut(base_a.0.add(r0 * nh), (r1 - r0) * nh),
                std::slice::from_raw_parts_mut(base_b.0.add(r0 * nh), (r1 - r0) * nh),
            )
        };
        run(band_a, band_b, r0, r1);
    });
}

/// Compute rows `[r0, r1)` of `A·x` into `out` (which holds those rows
/// only, starting at its origin). Blocked ikj loops vectorize well.
fn matmul_rows(
    a: &[f64],
    n: usize,
    x: &[f64],
    nh: usize,
    out: &mut [f64],
    r0: usize,
    r1: usize,
) {
    if nh == 1 {
        // GEMV fast path: accumulate a dot product per row.
        for i in r0..r1 {
            let arow = &a[i * n..(i + 1) * n];
            let mut acc = 0.0;
            // Four-lane unroll; LLVM vectorizes this cleanly.
            let mut k = 0;
            let chunks = n / 4 * 4;
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            while k < chunks {
                s0 += arow[k] * x[k];
                s1 += arow[k + 1] * x[k + 1];
                s2 += arow[k + 2] * x[k + 2];
                s3 += arow[k + 3] * x[k + 3];
                k += 4;
            }
            while k < n {
                acc += arow[k] * x[k];
                k += 1;
            }
            out[i - r0] = acc + ((s0 + s1) + (s2 + s3));
        }
        return;
    }
    for bi in (r0..r1).step_by(MC) {
        let bi_end = (bi + MC).min(r1);
        for bk in (0..n).step_by(KC) {
            let bk_end = (bk + KC).min(n);
            for i in bi..bi_end {
                let orow = &mut out[(i - r0) * nh..(i - r0 + 1) * nh];
                let arow = &a[i * n..(i + 1) * n];
                for k in bk..bk_end {
                    let aik = arow[k];
                    let xrow = &x[k * nh..(k + 1) * nh];
                    for (o, &xv) in orow.iter_mut().zip(xrow) {
                        *o += aik * xv;
                    }
                }
            }
        }
    }
}

/// Compute rows `[r0, r1)` of the row-wise logsumexp product into `out`
/// (which holds those rows only, starting at its origin).
fn logsumexp_rows(
    a: &[f64],
    n: usize,
    x: &[f64],
    nh: usize,
    out: &mut [f64],
    r0: usize,
    r1: usize,
) {
    if nh == 1 {
        // LSE-GEMV fast path: two sweeps per row — max, then the
        // max-absorbed exponential sum (both vectorize cleanly).
        for i in r0..r1 {
            let arow = &a[i * n..(i + 1) * n];
            let mut mx = f64::NEG_INFINITY;
            for k in 0..n {
                let v = arow[k] + x[k];
                if v > mx {
                    mx = v;
                }
            }
            if mx == f64::NEG_INFINITY {
                out[i - r0] = f64::NEG_INFINITY; // fully masked row
                continue;
            }
            let mut s = 0.0;
            for k in 0..n {
                s += (arow[k] + x[k] - mx).exp();
            }
            out[i - r0] = mx + s.ln();
        }
        return;
    }

    // Multi-histogram path: one streaming pass per row with per-column
    // online max/sum accumulators (O(N) scratch, reused across rows).
    let mut mx = vec![f64::NEG_INFINITY; nh];
    let mut sum = vec![0.0f64; nh];
    for i in r0..r1 {
        let arow = &a[i * n..(i + 1) * n];
        mx.fill(f64::NEG_INFINITY);
        sum.fill(0.0);
        for k in 0..n {
            let aik = arow[k];
            if aik == f64::NEG_INFINITY {
                continue; // masked kernel entry: zero mass for every histogram
            }
            let xrow = &x[k * nh..(k + 1) * nh];
            for h in 0..nh {
                let v = aik + xrow[h];
                if v == f64::NEG_INFINITY {
                    continue;
                }
                if v <= mx[h] {
                    sum[h] += (v - mx[h]).exp();
                } else {
                    // New running max: absorb it, rescale the old sum.
                    sum[h] = sum[h] * (mx[h] - v).exp() + 1.0;
                    mx[h] = v;
                }
            }
        }
        let orow = &mut out[(i - r0) * nh..(i - r0 + 1) * nh];
        for h in 0..nh {
            orow[h] = if sum[h] > 0.0 { mx[h] + sum[h].ln() } else { f64::NEG_INFINITY };
        }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}
