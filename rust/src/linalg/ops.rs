//! Element-wise operations of the Sinkhorn iteration (native backend).

use super::Mat;

/// Damped scaling update: `u = α · t/q + (1−α) · u_old`, writing into
/// `u_out`. `t` is either a length-`m` vector (broadcast across histogram
/// columns) or a full `m×N` matrix — pass `t_stride = 0` for broadcast,
/// `t_stride = N` for per-histogram targets.
pub fn scale_divide_into(
    t: &[f64],
    t_stride: usize,
    q: &Mat,
    u_old: &Mat,
    alpha: f64,
    u_out: &mut Mat,
) {
    let (m, nh) = (q.rows(), q.cols());
    assert_eq!(u_old.rows(), m);
    assert_eq!(u_old.cols(), nh);
    assert_eq!(u_out.rows(), m);
    assert_eq!(u_out.cols(), nh);
    let beta = 1.0 - alpha;
    for i in 0..m {
        let qrow = q.row(i);
        let urow = u_old.row(i);
        let orow = u_out.row_mut(i);
        if t_stride == 0 {
            let ti = t[i];
            for j in 0..nh {
                orow[j] = alpha * (ti / qrow[j]) + beta * urow[j];
            }
        } else {
            let trow = &t[i * t_stride..(i + 1) * t_stride];
            for j in 0..nh {
                orow[j] = alpha * (trow[j] / qrow[j]) + beta * urow[j];
            }
        }
    }
}

/// Stable `log Σ exp(xs)` over a slice (max-absorbed two-pass form).
/// Returns `−∞` for an empty or all-`−∞` input.
pub fn logsumexp_slice(xs: &[f64]) -> f64 {
    let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if mx == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let s: f64 = xs.iter().map(|&v| (v - mx).exp()).sum();
    mx + s.ln()
}

/// `y = a·x + b·y` (vectors).
pub fn axpby(a: f64, x: &[f64], b: f64, y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// Σ|x − y| over slices — the L1 marginal error reduction.
pub fn l1_diff(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum()
}

/// `P = diag(u) · K · diag(v)` — the transport-plan assembly.
pub fn scale_rows_cols(k: &Mat, u: &[f64], v: &[f64]) -> Mat {
    assert_eq!(u.len(), k.rows());
    assert_eq!(v.len(), k.cols());
    let mut out = k.clone();
    for i in 0..k.rows() {
        let ui = u[i];
        for (o, &vj) in out.row_mut(i).iter_mut().zip(v) {
            *o *= ui * vj;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_divide_broadcast_and_matrix_targets() {
        let q = Mat::from_vec(2, 2, vec![2.0, 4.0, 8.0, 16.0]);
        let u_old = Mat::ones(2, 2);
        let mut out = Mat::zeros(2, 2);
        // broadcast target
        scale_divide_into(&[4.0, 16.0], 0, &q, &u_old, 0.5, &mut out);
        assert_eq!(out.as_slice(), &[1.5, 1.0, 1.5, 1.0]);
        // per-histogram target
        scale_divide_into(&[2.0, 4.0, 8.0, 16.0], 2, &q, &u_old, 1.0, &mut out);
        assert_eq!(out.as_slice(), &[1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn axpby_works() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpby(2.0, &x, 0.5, &mut y);
        assert_eq!(y, [7.0, 14.0]);
    }

    #[test]
    fn l1_diff_basic() {
        assert_eq!(l1_diff(&[1.0, -2.0], &[0.0, 1.0]), 4.0);
    }

    #[test]
    fn plan_assembly() {
        let k = Mat::ones(2, 2);
        let p = scale_rows_cols(&k, &[2.0, 3.0], &[5.0, 7.0]);
        assert_eq!(p.as_slice(), &[10.0, 14.0, 15.0, 21.0]);
    }
}
