//! Dense/sparse linear-algebra substrate.
//!
//! The Sinkhorn hot path is `q = A · x` with `A (m×n)` a block of the
//! Gibbs kernel and `x (n×N)` the scaling state over `N` histograms,
//! followed by element-wise scaling. We provide:
//!
//! * [`Mat`] — dense row-major `f64` matrices with blocked, cache-tiled,
//!   optionally multi-threaded GEMM (`matmul_into`) and the log-domain
//!   twin `logsumexp_into` (row-wise max-absorbed logsumexp);
//! * [`Csr`] — compressed-sparse-row kernels for the paper's off-diagonal
//!   block-sparsity parameter `s` (§IV-D);
//! * [`LogCsr`] — the `−∞`-aware CSR twin for log-domain kernels,
//!   built by truncating entries whose shifted exponent falls below a
//!   threshold `θ` (Schmitzer's stabilized sparse scaling);
//! * [`AbsorbedLogCsr`] — the shared-support *absorbed* sparse kernel of
//!   the multi-histogram hybrid schedule: one reference dual is absorbed
//!   and truncated once, per-histogram products run as batched sparse
//!   GEMMs with per-column scaling corrections, and re-absorption has a
//!   cheap `O(nnz)` partial tier next to the full re-truncation;
//! * [`Domain`] — the linear vs. log-stabilized representation switch the
//!   whole stack is generic over, plus the [`Stabilization`] tuning for
//!   the truncated/absorption-hybrid log path;
//! * element-wise helpers (`scale_divide_into`, `logsumexp_slice`, …)
//!   used by the native compute backend.
//!
//! The XLA artifacts are the default backend; these routines are the
//! reference implementation, the arbitrary-shape fallback, and the
//! "CPU-speed compute" stand-in for the paper's §IV-E study.

mod absorbed;
mod csr;
mod dense;
mod domain;
mod log_csr;
mod ops;

pub use absorbed::{AbsorbedLogCsr, THETA_SUPPORT_FLOOR};
pub use csr::Csr;
pub use dense::Mat;
pub use domain::{Domain, Stabilization};
pub use log_csr::LogCsr;
pub use ops::{axpby, l1_diff, logsumexp_slice, scale_divide_into, scale_rows_cols};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Mat, x: &Mat) -> Mat {
        let (m, n) = (a.rows(), a.cols());
        let nh = x.cols();
        let mut out = Mat::zeros(m, nh);
        for i in 0..m {
            for k in 0..n {
                let aik = a[(i, k)];
                for j in 0..nh {
                    out[(i, j)] += aik * x[(k, j)];
                }
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, n, nh) in &[(1, 1, 1), (7, 5, 3), (64, 64, 1), (130, 57, 9)] {
            let a = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
            let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
            let mut got = Mat::zeros(m, nh);
            a.matmul_into(&x, &mut got, 1);
            let want = naive_matmul(&a, &x);
            assert!(got.allclose(&want, 1e-12), "({m},{n},{nh})");
        }
    }

    #[test]
    fn threaded_matmul_matches_serial() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::rand_uniform(213, 187, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(187, 11, 0.1, 1.0, &mut rng);
        let mut serial = Mat::zeros(213, 11);
        let mut par = Mat::zeros(213, 11);
        a.matmul_into(&x, &mut serial, 1);
        a.matmul_into(&x, &mut par, 4);
        assert!(par.allclose(&serial, 0.0), "threaded result differs");
    }

    #[test]
    fn csr_matmul_matches_dense() {
        let mut rng = Rng::seed_from(3);
        let mut dense = Mat::rand_uniform(40, 30, 0.1, 1.0, &mut rng);
        // Zero ~70% of entries.
        for i in 0..40 {
            for j in 0..30 {
                if rng.uniform() < 0.7 {
                    dense[(i, j)] = 0.0;
                }
            }
        }
        let csr = Csr::from_dense(&dense, 0.0);
        let x = Mat::rand_uniform(30, 5, 0.1, 1.0, &mut rng);
        let mut got = Mat::zeros(40, 5);
        csr.matmul_into(&x, &mut got, 1);
        let want = naive_matmul(&dense, &x);
        assert!(got.allclose(&want, 1e-12));
        assert!(csr.nnz() < 40 * 30);
    }

    fn naive_logsumexp(a: &Mat, x: &Mat) -> Mat {
        let (m, n) = (a.rows(), a.cols());
        let nh = x.cols();
        let mut out = Mat::zeros(m, nh);
        for i in 0..m {
            for j in 0..nh {
                let mut s = 0.0;
                for k in 0..n {
                    s += (a[(i, k)] + x[(k, j)]).exp();
                }
                out[(i, j)] = s.ln();
            }
        }
        out
    }

    #[test]
    fn logsumexp_matches_naive_ln_sum_exp() {
        let mut rng = Rng::seed_from(6);
        for &(m, n, nh) in &[(1, 1, 1), (7, 5, 3), (64, 64, 1), (33, 57, 9)] {
            let a = Mat::rand_uniform(m, n, -3.0, 1.0, &mut rng);
            let x = Mat::rand_uniform(n, nh, -2.0, 2.0, &mut rng);
            let got = a.logsumexp(&x, 1);
            let want = naive_logsumexp(&a, &x);
            assert!(got.allclose(&want, 1e-12), "({m},{n},{nh})");
        }
    }

    #[test]
    fn logsumexp_survives_extreme_shifts() {
        // Entries around −2000: naive ln(Σ exp) underflows to ln 0 = −∞,
        // the max-absorbed kernel keeps full relative precision.
        let a = Mat::from_vec(2, 3, vec![-2000.0, -2001.0, -2000.5, -3000.0, -3000.0, -3000.0]);
        let x = Mat::from_vec(3, 1, vec![0.5, 1.0, 0.0]);
        let got = a.logsumexp(&x, 1);
        // Row 0: max is −2000 + 1 = −1999.5... compute directly.
        let want0 = logsumexp_slice(&[-1999.5, -2000.0, -2000.5]);
        let want1 = logsumexp_slice(&[-2999.5, -2999.0, -3000.0]);
        assert!((got[(0, 0)] - want0).abs() < 1e-10, "{} vs {want0}", got[(0, 0)]);
        assert!((got[(1, 0)] - want1).abs() < 1e-10);
        assert!(got[(0, 0)].is_finite());
    }

    #[test]
    fn logsumexp_handles_masked_rows() {
        // −∞ kernel entries (sparsified blocks) carry zero mass; a fully
        // masked row yields −∞, not NaN.
        let a = Mat::from_vec(
            2,
            2,
            vec![f64::NEG_INFINITY, 0.0, f64::NEG_INFINITY, f64::NEG_INFINITY],
        );
        let x = Mat::from_vec(2, 2, vec![0.1, 0.2, 0.3, 0.4]);
        let got = a.logsumexp(&x, 1);
        assert!((got[(0, 0)] - 0.3).abs() < 1e-12);
        assert!((got[(0, 1)] - 0.4).abs() < 1e-12);
        assert_eq!(got[(1, 0)], f64::NEG_INFINITY);
        assert_eq!(got[(1, 1)], f64::NEG_INFINITY);
        assert!(!got.as_slice().iter().any(|v| v.is_nan()));
    }

    #[test]
    fn threaded_logsumexp_matches_serial() {
        let mut rng = Rng::seed_from(7);
        let a = Mat::rand_uniform(213, 187, -5.0, 0.0, &mut rng);
        let x = Mat::rand_uniform(187, 11, -1.0, 1.0, &mut rng);
        let mut serial = Mat::zeros(213, 11);
        let mut par = Mat::zeros(213, 11);
        a.logsumexp_into(&x, &mut serial, 1);
        a.logsumexp_into(&x, &mut par, 4);
        assert!(par.allclose(&serial, 0.0), "threaded logsumexp differs");
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::rand_uniform(13, 29, 0.0, 1.0, &mut rng);
        assert!(a.transpose().transpose().allclose(&a, 0.0));
    }

    #[test]
    fn row_block_views() {
        let mut rng = Rng::seed_from(5);
        let a = Mat::rand_uniform(12, 6, 0.0, 1.0, &mut rng);
        let blk = a.row_block(4, 8);
        assert_eq!(blk.rows(), 4);
        for i in 0..4 {
            for j in 0..6 {
                assert_eq!(blk[(i, j)], a[(4 + i, j)]);
            }
        }
    }
}
