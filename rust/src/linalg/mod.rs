//! Dense/sparse linear-algebra substrate.
//!
//! The Sinkhorn hot path is `q = A · x` with `A (m×n)` a block of the
//! Gibbs kernel and `x (n×N)` the scaling state over `N` histograms,
//! followed by element-wise scaling. We provide:
//!
//! * [`Mat`] — dense row-major `f64` matrices with blocked, cache-tiled,
//!   optionally multi-threaded GEMM (`matmul_into`);
//! * [`Csr`] — compressed-sparse-row kernels for the paper's off-diagonal
//!   block-sparsity parameter `s` (§IV-D);
//! * element-wise helpers (`scale_divide_into`, …) used by the native
//!   compute backend.
//!
//! The XLA artifacts are the default backend; these routines are the
//! reference implementation, the arbitrary-shape fallback, and the
//! "CPU-speed compute" stand-in for the paper's §IV-E study.

mod csr;
mod dense;
mod ops;

pub use csr::Csr;
pub use dense::Mat;
pub use ops::{axpby, l1_diff, scale_divide_into, scale_rows_cols};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Mat, x: &Mat) -> Mat {
        let (m, n) = (a.rows(), a.cols());
        let nh = x.cols();
        let mut out = Mat::zeros(m, nh);
        for i in 0..m {
            for k in 0..n {
                let aik = a[(i, k)];
                for j in 0..nh {
                    out[(i, j)] += aik * x[(k, j)];
                }
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, n, nh) in &[(1, 1, 1), (7, 5, 3), (64, 64, 1), (130, 57, 9)] {
            let a = Mat::rand_uniform(m, n, 0.1, 1.0, &mut rng);
            let x = Mat::rand_uniform(n, nh, 0.1, 1.0, &mut rng);
            let mut got = Mat::zeros(m, nh);
            a.matmul_into(&x, &mut got, 1);
            let want = naive_matmul(&a, &x);
            assert!(got.allclose(&want, 1e-12), "({m},{n},{nh})");
        }
    }

    #[test]
    fn threaded_matmul_matches_serial() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::rand_uniform(213, 187, 0.1, 1.0, &mut rng);
        let x = Mat::rand_uniform(187, 11, 0.1, 1.0, &mut rng);
        let mut serial = Mat::zeros(213, 11);
        let mut par = Mat::zeros(213, 11);
        a.matmul_into(&x, &mut serial, 1);
        a.matmul_into(&x, &mut par, 4);
        assert!(par.allclose(&serial, 0.0), "threaded result differs");
    }

    #[test]
    fn csr_matmul_matches_dense() {
        let mut rng = Rng::seed_from(3);
        let mut dense = Mat::rand_uniform(40, 30, 0.1, 1.0, &mut rng);
        // Zero ~70% of entries.
        for i in 0..40 {
            for j in 0..30 {
                if rng.uniform() < 0.7 {
                    dense[(i, j)] = 0.0;
                }
            }
        }
        let csr = Csr::from_dense(&dense, 0.0);
        let x = Mat::rand_uniform(30, 5, 0.1, 1.0, &mut rng);
        let mut got = Mat::zeros(40, 5);
        csr.matmul_into(&x, &mut got, 1);
        let want = naive_matmul(&dense, &x);
        assert!(got.allclose(&want, 1e-12));
        assert!(csr.nnz() < 40 * 30);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from(4);
        let a = Mat::rand_uniform(13, 29, 0.0, 1.0, &mut rng);
        assert!(a.transpose().transpose().allclose(&a, 0.0));
    }

    #[test]
    fn row_block_views() {
        let mut rng = Rng::seed_from(5);
        let a = Mat::rand_uniform(12, 6, 0.0, 1.0, &mut rng);
        let blk = a.row_block(4, 8);
        assert_eq!(blk.rows(), 4);
        for i in 0..4 {
            for j in 0..6 {
                assert_eq!(blk[(i, j)], a[(4 + i, j)]);
            }
        }
    }
}
