//! # Federated Sinkhorn
//!
//! Production-oriented reproduction of *"Federated Sinkhorn"* (Kulcsar,
//! Kungurtsev, Korpas, Giaconi, Shoosmith, 2025): entropy-regularized
//! discrete optimal transport solved by Sinkhorn–Knopp fixed-point
//! iterations, federated across clients that each own a block of the
//! marginals and of the Gibbs kernel.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L1/L2** — JAX + Pallas kernels, AOT-lowered at build time to HLO
//!   text (`artifacts/`), never on the request path.
//! * **L3** — this crate: the federation coordinator. Clients are OS
//!   threads, the network is the simulated fabric in [`net`], compute is
//!   dispatched through [`runtime`] (PJRT executables or the native
//!   fallback).
//!
//! Entry points:
//! * [`sinkhorn`] — centralized solver + block operations.
//! * [`coordinator`] — the four federated variants (sync/async ×
//!   all-to-all/star) plus local-iteration sweeps.
//! * [`finance`] — the Blanchet–Murthy worst-case-loss application.
//! * [`experiments`] — drivers regenerating every paper table/figure.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod finance;
pub mod jsonio;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod sinkhorn;
pub mod testkit;
pub mod workload;
