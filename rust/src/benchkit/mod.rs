//! Micro-benchmark harness (no `criterion` in the offline image).
//!
//! `cargo bench` targets use `harness = false` mains built on this:
//! warmup, timed repetitions, outlier-robust summaries, and a stable
//! one-line-per-case output format that `EXPERIMENTS.md` records.

use crate::metrics::Summary;
use std::time::Instant;

/// One benchmark case result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub secs: Summary,
    /// Stable identity annotation carried into the `BENCH_*.json`
    /// document. Display names encode parameters and get reworded;
    /// `tools/bench_diff.py` falls back to matching baseline↔fresh
    /// cases by note, so annotated cases stay comparable across
    /// renames (and `--write-baseline` preserves hand-added notes).
    pub note: Option<String>,
}

impl BenchResult {
    /// Attach a stable identity note (builder style).
    pub fn with_note(mut self, note: &str) -> Self {
        self.note = Some(note.to_string());
        self
    }

    pub fn line(&self) -> String {
        format!(
            "{:<56} reps={:<3} mean={:>10.4}ms median={:>10.4}ms std={:>8.4}ms min={:>10.4}ms",
            self.name,
            self.reps,
            self.secs.mean * 1e3,
            self.secs.median * 1e3,
            self.secs.std * 1e3,
            self.secs.min * 1e3,
        )
    }
}

/// Harness configuration; `quick()` honors `FEDSINK_BENCH_QUICK=1` so CI
/// smoke runs stay fast.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup: usize,
    pub reps: usize,
    /// Soft wall-clock budget per case; reps stop early once exceeded.
    pub budget_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        if Bench::quick() {
            Self { warmup: 1, reps: 3, budget_secs: 2.0 }
        } else {
            Self { warmup: 2, reps: 10, budget_secs: 20.0 }
        }
    }
}

impl Bench {
    /// Whether this run is the CI quick mode (`FEDSINK_BENCH_QUICK=1`).
    /// Benches pin their case lists and RNG seeds on it so the
    /// perf-gate diff (`tools/bench_diff.py`) is deterministic
    /// run-to-run: quick-mode case names are a stable subset of the
    /// full-mode names.
    pub fn quick() -> bool {
        std::env::var("FEDSINK_BENCH_QUICK").as_deref() == Ok("1")
    }
}

impl Bench {
    /// Time `f` (called once per rep) and print + return the summary.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.reps);
        let budget_start = Instant::now();
        for _ in 0..self.reps {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
            if budget_start.elapsed().as_secs_f64() > self.budget_secs && times.len() >= 3 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            reps: times.len(),
            secs: Summary::of(&times),
            note: None,
        };
        println!("{}", res.line());
        res
    }
}

/// Section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Serialize results into the `BENCH_*.json` perf-trajectory document:
/// one entry per case with the robust timing summary in milliseconds.
/// Future PRs diff these baselines to catch hot-path regressions.
pub fn results_json(results: &[BenchResult]) -> crate::jsonio::Json {
    use crate::jsonio::Json;
    Json::obj(vec![
        (
            "cases",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("name", r.name.as_str().into()),
                            ("reps", r.reps.into()),
                            ("mean_ms", (r.secs.mean * 1e3).into()),
                            ("median_ms", (r.secs.median * 1e3).into()),
                            ("std_ms", (r.secs.std * 1e3).into()),
                            ("min_ms", (r.secs.min * 1e3).into()),
                        ];
                        if let Some(note) = &r.note {
                            fields.push(("note", note.as_str().into()));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write a `BENCH_*.json` baseline next to the bench's working dir.
pub fn write_baseline(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    std::fs::write(path, crate::jsonio::to_string_pretty(&results_json(results)))?;
    println!("\nwrote {path} ({} cases)", results.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let b = Bench { warmup: 1, reps: 5, budget_secs: 10.0 };
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.reps, 5);
        assert!(r.secs.mean >= 0.0);
    }

    #[test]
    fn budget_stops_early() {
        let b = Bench { warmup: 0, reps: 1000, budget_secs: 0.05 };
        let r = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(r.reps < 1000);
        assert!(r.reps >= 3);
    }
}
