//! L3 — the paper's coordination contribution: Federated Sinkhorn.
//!
//! Six topologies over the simulated fabric ([`crate::net`]), one OS
//! thread per node, all driven by the protocol core in [`engine`]:
//!
//! * [`sync_a2a`] — Alg. 1: peer-to-peer, lock-step AllGather of the
//!   `u`/`v` slices every `w` iterations.
//! * [`async_a2a`] — Alg. 2: peer-to-peer, inconsistent broadcast +
//!   latest-wins reads, damping `α`, staleness (τ) tracking.
//! * [`star`] (sync) — Alg. 3: clients own `a_j`/`b_j`; the server owns
//!   `K`, does the heavy products, scatters the intermediates.
//! * [`star`] (async) — the star topology without lock-step (the fourth
//!   cell of the paper's synchrony × topology matrix).
//! * [`ring`] — lock-step neighbor-pair slice rotation: c−1 hops per
//!   half-iteration give full coverage with only degree-1 links.
//! * [`gossip`] — seeded push-style dissemination with per-slice
//!   freshness stamps (peer choice pure in `(seed, iter, rank)`).
//!
//! The run context lives in [`ctx`], the outcome types in [`outcome`],
//! and the shared per-iteration machinery (exchange + streamed folds,
//! strike-based peer death, fleet-absorption routing) in [`engine`] —
//! a topology implements [`engine::Topology`] and inherits all of it.
//!
//! Every node accounts its wall time into the computation/communication
//! buckets the paper reports, and async nodes feed the shared
//! [`crate::net::DelayTracker`].

mod async_a2a;
mod ctx;
pub mod engine;
pub mod fleet;
mod gossip;
mod outcome;
mod ring;
mod runner;
mod star;
mod sync_a2a;

pub use ctx::RunCtx;
pub use gossip::gossip_peer;
pub use outcome::{
    aggregate_stop, slowest_node, FederatedOutcome, NodeOutcome, NodeStats, TracePoint,
};
pub use runner::run_federated;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, SolveConfig, Variant};
    use crate::net::LatencyModel;
    use crate::runtime::make_backend;
    use crate::sinkhorn::{CentralizedSolver, StopPolicy};
    use crate::workload::{Problem, ProblemSpec};

    fn cfg(variant: Variant, clients: usize) -> SolveConfig {
        SolveConfig {
            variant,
            backend: BackendKind::Native,
            clients,
            net: LatencyModel::zero(),
            ..Default::default()
        }
    }

    fn policy() -> StopPolicy {
        StopPolicy { threshold: 1e-11, max_iters: 3000, ..Default::default() }
    }

    fn solve_central(p: &Problem) -> crate::sinkhorn::SolveOutcome {
        let be = make_backend(BackendKind::Native, "", 1).unwrap();
        CentralizedSolver::new(be).solve(p, policy(), 1.0)
    }

    /// Prop. 1: synchronous federation generates the centralized iterate
    /// sequence — final states must agree to fp round-off.
    #[test]
    fn sync_a2a_matches_centralized_exactly() {
        let p = ProblemSpec::new(24).with_eps(0.5).build(3);
        let central = solve_central(&p);
        for c in [1, 2, 4] {
            let out = run_federated(&p, &cfg(Variant::SyncA2A, c), policy(), false);
            assert!(out.converged, "c={c}");
            assert!(
                out.state.u.allclose(&central.state.u, 1e-9),
                "u mismatch at c={c}"
            );
            assert!(out.state.v.allclose(&central.state.v, 1e-9));
        }
    }

    #[test]
    fn sync_star_matches_centralized_exactly() {
        let p = ProblemSpec::new(24).with_eps(0.5).build(4);
        let central = solve_central(&p);
        for c in [2, 3] {
            let out = run_federated(&p, &cfg(Variant::SyncStar, c), policy(), false);
            assert!(out.converged, "c={c}");
            assert!(out.state.u.allclose(&central.state.u, 1e-9));
            assert!(out.state.v.allclose(&central.state.v, 1e-9));
        }
    }

    /// Prop. 1 in the log domain, in the regime the linear kernel cannot
    /// represent at all: ε = 1e-3 on the 4×4 worked example puts every
    /// off-diagonal Gibbs entry below exp(−1000). Both synchronous
    /// protocols must reproduce the log-domain centralized iterates.
    #[test]
    fn log_domain_sync_variants_match_centralized_at_tiny_eps() {
        use crate::config::DomainChoice;
        use crate::linalg::Domain;
        let p = Problem::paper_4x4(1e-3);
        let pol = StopPolicy {
            threshold: 1e-10,
            max_iters: 50_000,
            check_every: 10,
            ..Default::default()
        };
        let be = make_backend(BackendKind::Native, "", 1).unwrap();
        let central = CentralizedSolver::new(be).solve_in(&p, pol, 1.0, Domain::Log);
        assert!(central.converged(), "centralized log solve: {:?}", central.stop);
        for variant in [Variant::SyncA2A, Variant::SyncStar] {
            for c in [2usize, 4] {
                let mut fcfg = cfg(variant, c);
                fcfg.domain = DomainChoice::Log;
                let out = run_federated(&p, &fcfg, pol, false);
                assert!(out.converged, "{} c={c}: {:?}", variant.name(), out.stop);
                assert_eq!(out.state.domain, Domain::Log);
                // Log-scalings are duals/ε — O(1000) here — so compare
                // with an absolute 1e-9 tolerance on the log values
                // (allclose's relative term only loosens this).
                assert!(
                    out.state.u.allclose(&central.state.u, 1e-9),
                    "{} c={c}: u mismatch",
                    variant.name()
                );
                assert!(
                    out.state.v.allclose(&central.state.v, 1e-9),
                    "{} c={c}: v mismatch",
                    variant.name()
                );
            }
        }
    }

    /// `--domain auto` flips to log exactly when the kernel underflows,
    /// without the caller doing anything: same tiny-ε problem, default
    /// Auto choice, native backend.
    #[test]
    fn auto_domain_rescues_tiny_eps_federated_solve() {
        use crate::linalg::Domain;
        let p = Problem::paper_4x4(1e-3);
        let pol = StopPolicy {
            threshold: 1e-10,
            max_iters: 50_000,
            check_every: 10,
            ..Default::default()
        };
        let out = run_federated(&p, &cfg(Variant::SyncA2A, 2), pol, false);
        assert!(out.converged, "auto-domain run: {:?}", out.stop);
        assert_eq!(out.state.domain, Domain::Log);
        let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &out.state, 0);
        assert!(ea < 1e-8 && eb < 1e-8, "({ea}, {eb})");
    }

    /// Log-domain federated runs surface the absorption-hybrid counters:
    /// every a2a client (and the star server) reports per-operator stats,
    /// merged into the outcome with per-histogram trigger slots.
    #[test]
    fn federated_log_runs_report_stab_stats() {
        use crate::config::DomainChoice;
        let p = ProblemSpec::new(24).with_hists(2).with_eps(0.01).build(77);
        let pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 20_000,
            check_every: 10,
            ..Default::default()
        };
        for variant in [Variant::SyncA2A, Variant::SyncStar] {
            let mut fcfg = cfg(variant, 2);
            fcfg.domain = DomainChoice::Log;
            let out = run_federated(&p, &fcfg, pol, false);
            assert!(out.converged, "{}: {:?}", variant.name(), out.stop);
            let st = out.stab.as_ref().unwrap_or_else(|| {
                panic!("{}: log run must report hybrid stats", variant.name())
            });
            assert!(st.updates > 0);
            assert_eq!(st.absorb_triggers.len(), 2, "per-histogram slots");
            // a2a: every client carries stats; star: exactly the server.
            let with_stats = out.node_stats.iter().filter(|s| s.stab.is_some()).count();
            match variant {
                Variant::SyncA2A => assert_eq!(with_stats, 2),
                Variant::SyncStar => {
                    assert_eq!(with_stats, 1);
                    assert!(out.node_stats.iter().any(|s| s.role == "server" && s.stab.is_some()));
                }
                _ => unreachable!(),
            }
        }
        // Linear-domain runs carry no stabilized counters. (Pinned
        // explicitly — `cfg()`'s Default domain resolves from
        // FEDSINK_DOMAIN, so this must not depend on the environment.)
        let mut lin_cfg = cfg(Variant::SyncA2A, 2);
        lin_cfg.domain = DomainChoice::Linear;
        let out = run_federated(&p, &lin_cfg, policy(), false);
        assert!(out.stab.is_none());
    }

    /// Fleet-synchronized absorption must not change what the solvers
    /// compute. Synchronous variants with `--fleet-absorb` still
    /// generate the centralized hybrid iterate sequence (Prop. 1 under
    /// shared absorption), the coordinator's commands drive the
    /// re-absorptions (fleet counters populated), and the fleet's total
    /// retruncation count never exceeds the per-node baseline's on the
    /// same workload.
    #[test]
    fn fleet_absorb_sync_variants_match_centralized_hybrid() {
        use crate::config::DomainChoice;
        use crate::linalg::Domain;
        let p = ProblemSpec::new(24)
            .with_hists(2)
            .with_eps(0.01)
            .with_condition(crate::workload::CondClass::Medium)
            .build(91);
        let pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 30_000,
            check_every: 10,
            ..Default::default()
        };
        // τ small enough that the drifting duals force several
        // re-absorptions (and full retruncations) mid-solve.
        let tau = 0.5;
        let be = make_backend(BackendKind::Native, "", 1).unwrap();
        let stab = crate::linalg::Stabilization { absorb_threshold: tau, ..Default::default() };
        let central = CentralizedSolver::new(be)
            .with_stabilization(stab)
            .solve_in(&p, pol, 1.0, Domain::Log);
        assert!(central.converged(), "centralized hybrid: {:?}", central.stop);
        for variant in [Variant::SyncA2A, Variant::SyncStar] {
            for clients in [2usize, 4] {
                let mut base_cfg = cfg(variant, clients);
                base_cfg.domain = DomainChoice::Log;
                base_cfg.stab.absorb_threshold = tau;
                let base = run_federated(&p, &base_cfg, pol, false);
                assert!(base.converged, "{} c={clients} baseline", variant.name());
                let mut fcfg = base_cfg.clone();
                fcfg.stab.fleet_absorb = true;
                let out = run_federated(&p, &fcfg, pol, false);
                assert!(out.converged, "{} c={clients} fleet: {:?}", variant.name(), out.stop);
                assert!(
                    out.state.u.allclose(&central.state.u, 1e-10),
                    "{} c={clients}: u mismatch vs centralized hybrid",
                    variant.name()
                );
                assert!(
                    out.state.v.allclose(&central.state.v, 1e-10),
                    "{} c={clients}: v mismatch vs centralized hybrid",
                    variant.name()
                );
                let st = out.stab.as_ref().expect("fleet run reports hybrid stats");
                let bst = base.stab.as_ref().expect("baseline reports hybrid stats");
                assert!(st.fleet_commands > 0, "{} c={clients}: no fleet commands", variant.name());
                assert!(
                    st.fleet_rebuilds >= 1,
                    "{} c={clients}: forced retruncation must be fleet-driven",
                    variant.name()
                );
                // The acceptance bar: fleet-total retruncations (summed
                // over nodes by the merge) never exceed the per-node
                // baseline's total on the same workload.
                assert!(
                    st.rebuilds <= bst.rebuilds,
                    "{} c={clients}: fleet rebuilds {} > baseline {}",
                    variant.name(),
                    st.rebuilds,
                    bst.rebuilds
                );
            }
        }
    }

    /// Fleet absorption on the asynchronous variants: convergence to the
    /// same fixed point (marginals satisfied), hybrid counters present,
    /// and the async-star server — where the coordinator owns the
    /// kernel — drives its re-absorptions through fleet commands.
    #[test]
    fn fleet_absorb_async_variants_converge() {
        use crate::config::DomainChoice;
        let p = ProblemSpec::new(16)
            .with_hists(2)
            .with_eps(0.01)
            .with_condition(crate::workload::CondClass::Medium)
            .build(92);
        let pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 40_000,
            check_every: 10,
            ..Default::default()
        };
        for variant in [Variant::AsyncA2A, Variant::AsyncStar] {
            let mut fcfg = cfg(variant, 2);
            fcfg.domain = DomainChoice::Log;
            fcfg.alpha = 0.5;
            fcfg.stab.absorb_threshold = 0.5;
            fcfg.stab.fleet_absorb = true;
            let out = run_federated(&p, &fcfg, pol, false);
            assert!(out.converged, "{}: {:?}", variant.name(), out.stop);
            let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &out.state, 0);
            assert!(ea < 1e-6 && eb < 1e-6, "{}: ({ea}, {eb})", variant.name());
            let st = out.stab.as_ref().expect("fleet run reports hybrid stats");
            assert!(st.updates > 0 && st.absorbs > 0, "{}", variant.name());
            if variant == Variant::AsyncStar {
                // The server decides locally — its commands are not
                // subject to message timing, so they must be present.
                assert!(st.fleet_commands > 0, "async-star server issues fleet commands");
            }
        }
    }

    /// A deliberately tiny drift budget forces repeated mid-solve fleet
    /// retruncations across a wider fleet; the iterates still match the
    /// centralized hybrid exactly.
    #[test]
    fn fleet_forced_retruncations_stay_exact() {
        use crate::config::DomainChoice;
        use crate::linalg::Domain;
        let p = ProblemSpec::new(32)
            .with_hists(2)
            .with_eps(0.01)
            .with_condition(crate::workload::CondClass::Medium)
            .build(93);
        let pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 30_000,
            check_every: 10,
            ..Default::default()
        };
        let stab = crate::linalg::Stabilization { absorb_threshold: 0.05, ..Default::default() };
        let be = make_backend(BackendKind::Native, "", 1).unwrap();
        let central = CentralizedSolver::new(be)
            .with_stabilization(stab)
            .solve_in(&p, pol, 1.0, Domain::Log);
        assert!(central.converged());
        let mut fcfg = cfg(Variant::SyncA2A, 4);
        fcfg.domain = DomainChoice::Log;
        fcfg.stab.absorb_threshold = 0.05;
        fcfg.stab.fleet_absorb = true;
        let out = run_federated(&p, &fcfg, pol, false);
        assert!(out.converged, "{:?}", out.stop);
        assert!(out.state.u.allclose(&central.state.u, 1e-10));
        assert!(out.state.v.allclose(&central.state.v, 1e-10));
        let st = out.stab.as_ref().unwrap();
        assert!(
            st.fleet_rebuilds >= 2,
            "tiny τ must force repeated fleet retruncations, got {}",
            st.fleet_rebuilds
        );
    }

    /// The exact-path streaming guarantee: `--stream-exchange` with the
    /// default F64 wire reproduces the barrier baseline to ≤ 1e-12 on
    /// both synchronous topologies, in the linear domain (partial-GEMM
    /// folds) and the log domain (online-LSE merge / absorbed folds).
    #[test]
    fn streamed_exchange_matches_barrier_baseline() {
        use crate::config::DomainChoice;
        let lin = ProblemSpec::new(24).with_eps(0.5).build(3);
        let log = ProblemSpec::new(24)
            .with_hists(2)
            .with_eps(0.01)
            .with_condition(crate::workload::CondClass::Medium)
            .build(91);
        let log_pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 30_000,
            check_every: 10,
            ..Default::default()
        };
        for variant in [Variant::SyncA2A, Variant::SyncStar] {
            for c in [2usize, 4] {
                for (p, dom, pol) in [
                    (&lin, DomainChoice::Linear, policy()),
                    (&log, DomainChoice::Log, log_pol),
                ] {
                    let mut base_cfg = cfg(variant, c);
                    base_cfg.domain = dom;
                    let base = run_federated(p, &base_cfg, pol, false);
                    assert!(base.converged, "{} c={c} {dom:?} barrier", variant.name());
                    let mut scfg = base_cfg.clone();
                    scfg.stream_exchange = true;
                    let out = run_federated(p, &scfg, pol, false);
                    assert!(out.converged, "{} c={c} {dom:?} streamed", variant.name());
                    assert_eq!(out.iterations, base.iterations, "{} c={c} {dom:?}", variant.name());
                    assert!(
                        out.state.u.allclose(&base.state.u, 1e-12),
                        "{} c={c} {dom:?}: streamed u diverged from barrier",
                        variant.name()
                    );
                    assert!(
                        out.state.v.allclose(&base.state.v, 1e-12),
                        "{} c={c} {dom:?}: streamed v diverged from barrier",
                        variant.name()
                    );
                }
            }
        }
    }

    /// Streaming composes with fleet absorption by deferring to it: the
    /// combined run still reproduces the centralized hybrid exactly
    /// (the fleet command must land before the product that consumes
    /// the exchanged state, so product folding is inert there).
    #[test]
    fn streaming_with_fleet_absorption_stays_exact() {
        use crate::config::DomainChoice;
        use crate::linalg::Domain;
        let p = ProblemSpec::new(24)
            .with_hists(2)
            .with_eps(0.01)
            .with_condition(crate::workload::CondClass::Medium)
            .build(91);
        let pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 30_000,
            check_every: 10,
            ..Default::default()
        };
        let tau = 0.5;
        let be = make_backend(BackendKind::Native, "", 1).unwrap();
        let stab = crate::linalg::Stabilization { absorb_threshold: tau, ..Default::default() };
        let central = CentralizedSolver::new(be)
            .with_stabilization(stab)
            .solve_in(&p, pol, 1.0, Domain::Log);
        assert!(central.converged());
        let mut fcfg = cfg(Variant::SyncA2A, 4);
        fcfg.domain = DomainChoice::Log;
        fcfg.stab.absorb_threshold = tau;
        fcfg.stab.fleet_absorb = true;
        fcfg.stream_exchange = true;
        let out = run_federated(&p, &fcfg, pol, false);
        assert!(out.converged, "{:?}", out.stop);
        assert!(out.state.u.allclose(&central.state.u, 1e-10));
        assert!(out.state.v.allclose(&central.state.v, 1e-10));
        assert!(out.stab.as_ref().unwrap().fleet_commands > 0);
    }

    /// Lossy wire formats: every coordinator still reaches the solver
    /// tolerance (DeltaF32 to a tight one — its quantization step
    /// shrinks with the iterate deltas; F32 to a tolerance above its
    /// slice-range noise floor), and the f32 frames halve the scaling-
    /// exchange bytes relative to f64.
    #[test]
    fn lossy_wire_formats_reach_the_solver_tolerance() {
        use crate::net::WireFormat;
        // m·N = 64 per slice keeps the frame bytes well above the fixed
        // per-message envelope, so the f32-vs-f64 ratio is readable.
        let p = ProblemSpec::new(32).with_hists(4).with_eps(0.5).build(3);
        let run = |wire: WireFormat, threshold: f64, stream: bool| {
            let mut c = cfg(Variant::SyncA2A, 2);
            c.wire = wire;
            c.stream_exchange = stream;
            let pol = StopPolicy { threshold, max_iters: 8000, ..Default::default() };
            run_federated(&p, &c, pol, false)
        };
        let base = run(WireFormat::F64, 1e-10, false);
        assert!(base.converged);
        for stream in [false, true] {
            let delta = run(WireFormat::DeltaF32, 1e-10, stream);
            assert!(delta.converged, "deltaf32 stream={stream}: {:?}", delta.stop);
            let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &delta.state, 0);
            assert!(ea < 1e-9 && eb < 1e-9, "deltaf32 stream={stream}: ({ea}, {eb})");
        }
        let f32_run = run(WireFormat::F32, 1e-6, false);
        assert!(f32_run.converged, "f32: {:?}", f32_run.stop);
        let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &f32_run.state, 0);
        assert!(ea < 1e-5 && eb < 1e-5, "f32: ({ea}, {eb})");
        // β-term check on the scaling exchange: same protocol, ~half
        // the U/V bytes (per-message envelope + scale header keep it
        // just above exactly half).
        let per_msg_f64 = base.traffic.bytes_of(crate::net::TagKind::U) as f64
            / base.traffic.by_kind.iter().find(|k| k.0 == "U").unwrap().2 as f64;
        let per_msg_f32 = f32_run.traffic.bytes_of(crate::net::TagKind::U) as f64
            / f32_run.traffic.by_kind.iter().find(|k| k.0 == "U").unwrap().2 as f64;
        assert!(
            per_msg_f32 < 0.65 * per_msg_f64,
            "per-message U bytes: f32 {per_msg_f32} vs f64 {per_msg_f64}"
        );
    }

    /// The per-TagKind counters cover every kind the protocol uses, and
    /// a fleet run attributes its probe/command traffic to `Gref`.
    #[test]
    fn traffic_counters_split_by_kind() {
        use crate::config::DomainChoice;
        use crate::net::TagKind;
        let p = ProblemSpec::new(16).with_eps(0.5).build(9);
        let out = run_federated(&p, &cfg(Variant::SyncStar, 4), policy(), false);
        assert!(out.traffic.bytes_of(TagKind::U) > 0);
        assert!(out.traffic.bytes_of(TagKind::V) > 0);
        assert!(out.traffic.bytes_of(TagKind::Ctl) > 0);
        assert_eq!(out.traffic.bytes_of(TagKind::Gref), 0);
        assert_eq!(
            out.traffic.total_bytes,
            out.traffic.by_kind.iter().map(|&(_, b, _)| b).sum::<u64>()
        );
        let p = ProblemSpec::new(24)
            .with_hists(2)
            .with_eps(0.01)
            .with_condition(crate::workload::CondClass::Medium)
            .build(91);
        let pol = StopPolicy {
            threshold: 1e-9,
            max_iters: 30_000,
            check_every: 10,
            ..Default::default()
        };
        let mut fcfg = cfg(Variant::SyncA2A, 2);
        fcfg.domain = DomainChoice::Log;
        fcfg.stab.absorb_threshold = 0.5;
        fcfg.stab.fleet_absorb = true;
        let out = run_federated(&p, &fcfg, pol, false);
        assert!(out.converged);
        assert!(out.traffic.bytes_of(TagKind::Gref) > 0, "fleet run must meter Gref traffic");
    }

    #[test]
    fn async_a2a_converges_with_damping() {
        let p = ProblemSpec::new(16).with_eps(0.5).build(5);
        let mut c = cfg(Variant::AsyncA2A, 4);
        c.alpha = 0.5;
        let pol = StopPolicy { threshold: 1e-9, max_iters: 8000, ..Default::default() };
        let out = run_federated(&p, &c, pol, false);
        assert!(out.converged, "stop {:?}", out.stop);
        // Final plan satisfies the marginals.
        let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &out.state, 0);
        assert!(ea < 1e-6 && eb < 1e-6, "({ea}, {eb})");
    }

    #[test]
    fn async_star_converges_with_damping() {
        let p = ProblemSpec::new(16).with_eps(0.5).build(6);
        let mut c = cfg(Variant::AsyncStar, 4);
        c.alpha = 0.5;
        let pol = StopPolicy { threshold: 1e-9, max_iters: 8000, ..Default::default() };
        let out = run_federated(&p, &c, pol, false);
        assert!(out.converged, "stop {:?}", out.stop);
        let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &out.state, 0);
        assert!(ea < 1e-6 && eb < 1e-6, "({ea}, {eb})");
    }

    #[test]
    fn async_records_delays() {
        let p = ProblemSpec::new(16).with_eps(0.5).build(7);
        let mut c = cfg(Variant::AsyncA2A, 2);
        c.alpha = 0.5;
        c.net = LatencyModel { base_secs: 2e-4, ..LatencyModel::zero() };
        let out = run_federated(&p, &c, policy(), false);
        assert!(!out.taus.is_empty(), "async run must record staleness");
    }

    #[test]
    fn sync_local_iterations_still_converge() {
        // App. A: w > 1 delays but does not break convergence.
        let p = ProblemSpec::new(16).with_eps(0.5).build(8);
        let mut c1 = cfg(Variant::SyncA2A, 4);
        c1.local_iters = 1;
        let mut c3 = c1.clone();
        c3.local_iters = 3;
        let o1 = run_federated(&p, &c1, policy(), false);
        let o3 = run_federated(&p, &c3, policy(), false);
        assert!(o1.converged && o3.converged);
        // Fig 26: more local iterations → never fewer total iterations.
        assert!(
            o3.iterations >= o1.iterations,
            "w=3 {} vs w=1 {}",
            o3.iterations,
            o1.iterations
        );
    }

    #[test]
    fn node_stats_cover_every_node() {
        let p = ProblemSpec::new(16).with_eps(0.5).build(9);
        let out = run_federated(&p, &cfg(Variant::SyncA2A, 4), policy(), false);
        assert_eq!(out.node_stats.len(), 4);
        // star: c clients + server
        let out = run_federated(&p, &cfg(Variant::SyncStar, 4), policy(), false);
        assert_eq!(out.node_stats.len(), 5);
        assert!(out.node_stats.iter().all(|s| s.total_secs() >= 0.0));
        assert!(slowest_node(&out.node_stats).total_secs() >= 0.0);
    }

    #[test]
    fn traced_runs_record_error_decay() {
        let p = ProblemSpec::new(16).with_eps(0.5).build(10);
        let out = run_federated(&p, &cfg(Variant::SyncA2A, 2), policy(), true);
        assert!(out.trace.len() >= 2);
        let first = out.trace.first().unwrap().err;
        let last = out.trace.last().unwrap().err;
        assert!(last < first);
    }

    #[test]
    fn multi_histogram_federated_solve() {
        let p = ProblemSpec::new(16).with_hists(4).with_eps(0.5).build(11);
        let central = solve_central(&p);
        let out = run_federated(&p, &cfg(Variant::SyncA2A, 4), policy(), false);
        assert!(out.converged);
        assert!(out.state.u.allclose(&central.state.u, 1e-9));
    }

    #[test]
    fn centralized_variant_dispatches() {
        let p = Problem::paper_4x4(0.5);
        let out = run_federated(&p, &cfg(Variant::Centralized, 1), policy(), false);
        assert!(out.converged);
        assert_eq!(out.node_stats.len(), 1);
        assert_eq!(aggregate_stop(&out.node_stats), StopReason::Converged);
    }

    /// `--exchange greedy` on every topology: the top-k violation
    /// schedule converges to the full-exchange solution at equal ε.
    /// Scalings may differ from the dense run by a per-histogram
    /// constant (greedy walks a different iterate path), so agreement
    /// is judged on the scaling-invariant entropic objective and the
    /// full marginals, not on `u`/`v` directly. Every run must also
    /// surface the merged selection telemetry.
    #[test]
    fn greedy_exchange_converges_on_every_topology() {
        use crate::config::ExchangeMode;
        let p = ProblemSpec::new(16).with_eps(0.5).build(13);
        let central = solve_central(&p);
        assert!(central.converged());
        let obj_full = crate::sinkhorn::objective(&p, &central.state, 0);
        for variant in [
            Variant::SyncA2A,
            Variant::SyncStar,
            Variant::AsyncA2A,
            Variant::AsyncStar,
            Variant::Ring,
            Variant::Gossip,
        ] {
            let mut c = cfg(variant, 4);
            c.exchange = ExchangeMode::Greedy;
            if matches!(variant, Variant::AsyncA2A | Variant::AsyncStar | Variant::Gossip) {
                c.alpha = 0.5;
            }
            let pol = StopPolicy { threshold: 1e-9, max_iters: 20_000, ..Default::default() };
            let out = run_federated(&p, &c, pol, false);
            assert!(out.converged, "{} greedy: {:?}", variant.name(), out.stop);
            let (ea, eb) = crate::sinkhorn::full_marginal_errors(&p, &out.state, 0);
            assert!(ea < 1e-6 && eb < 1e-6, "{} greedy: ({ea}, {eb})", variant.name());
            let obj = crate::sinkhorn::objective(&p, &out.state, 0);
            assert!(
                (obj - obj_full).abs() < 1e-6 * obj_full.abs().max(1.0),
                "{} greedy objective {obj} vs full {obj_full}",
                variant.name()
            );
            let g = out.greedy.as_ref().unwrap_or_else(|| {
                panic!("{}: greedy run must report selection stats", variant.name())
            });
            assert!(g.calls > 0, "{}", variant.name());
            assert!(
                g.row_fraction() > 0.0 && g.row_fraction() <= 1.0,
                "{}: row fraction {}",
                variant.name(),
                g.row_fraction()
            );
        }
    }

    /// Greedy on the decentralized ring vs the centralized solves (full
    /// and Greenkhorn-style greedy schedule): all three land on the
    /// same optimal plan, per histogram.
    #[test]
    fn greedy_ring_matches_centralized_solution() {
        use crate::config::ExchangeMode;
        let p = ProblemSpec::new(24).with_hists(2).with_eps(0.5).build(14);
        let central = solve_central(&p);
        assert!(central.converged());
        let pol = StopPolicy { threshold: 1e-10, max_iters: 20_000, ..Default::default() };
        let mut ring_cfg = cfg(Variant::Ring, 4);
        ring_cfg.exchange = ExchangeMode::Greedy;
        let ring = run_federated(&p, &ring_cfg, pol, false);
        assert!(ring.converged, "greedy ring: {:?}", ring.stop);
        let mut central_cfg = cfg(Variant::Centralized, 1);
        central_cfg.exchange = ExchangeMode::Greedy;
        let cg = run_federated(&p, &central_cfg, pol, false);
        assert!(cg.converged, "centralized greedy: {:?}", cg.stop);
        assert!(cg.greedy.is_some(), "centralized greedy reports selection stats");
        for h in 0..p.hists() {
            let reference = crate::sinkhorn::objective(&p, &central.state, h);
            for (name, st) in [("ring", &ring.state), ("centralized-greedy", &cg.state)] {
                let obj = crate::sinkhorn::objective(&p, st, h);
                assert!(
                    (obj - reference).abs() < 1e-6 * reference.abs().max(1.0),
                    "{name} h={h}: objective {obj} vs full {reference}"
                );
            }
        }
    }

    /// The acceptance bar of the greedy schedule: at equal ε and equal
    /// tolerance, the sparse coordinate frames move strictly fewer
    /// scaling-exchange bytes per iteration than the dense slices, for
    /// c ∈ {4, 8} — and a greedy run moves *no* dense scaling frames.
    #[test]
    fn greedy_moves_fewer_scaling_bytes_per_iteration_than_full() {
        use crate::config::ExchangeMode;
        use crate::net::TagKind;
        let p = ProblemSpec::new(32).with_hists(2).with_eps(0.5).build(15);
        for clients in [4usize, 8] {
            for variant in [Variant::SyncA2A, Variant::SyncStar] {
                let base = run_federated(&p, &cfg(variant, clients), policy(), false);
                assert!(base.converged, "{} c={clients} full", variant.name());
                let mut gcfg = cfg(variant, clients);
                gcfg.exchange = ExchangeMode::Greedy;
                let pol =
                    StopPolicy { threshold: 1e-11, max_iters: 20_000, ..Default::default() };
                let out = run_federated(&p, &gcfg, pol, false);
                assert!(out.converged, "{} c={clients} greedy: {:?}", variant.name(), out.stop);
                let dense = base.traffic.bytes_of(TagKind::U) + base.traffic.bytes_of(TagKind::V);
                let sparse = out.traffic.bytes_of(TagKind::SparseU)
                    + out.traffic.bytes_of(TagKind::SparseV);
                assert!(sparse > 0, "{} c={clients}: no sparse frames metered", variant.name());
                assert_eq!(
                    out.traffic.bytes_of(TagKind::U) + out.traffic.bytes_of(TagKind::V),
                    0,
                    "{} c={clients}: greedy run must not move dense scaling frames",
                    variant.name()
                );
                let per_iter_full = dense as f64 / base.iterations.max(1) as f64;
                let per_iter_greedy = sparse as f64 / out.iterations.max(1) as f64;
                assert!(
                    per_iter_greedy < per_iter_full,
                    "{} c={clients}: greedy {per_iter_greedy:.1} B/iter vs full \
                     {per_iter_full:.1} B/iter",
                    variant.name()
                );
            }
        }
    }

    #[test]
    fn undamped_async_may_or_may_not_converge_but_never_panics() {
        // α = 1 async is the paper's unstable regime (§IV-C1) — we only
        // require a clean run and a well-formed outcome.
        let p = ProblemSpec::new(16).with_eps(0.5).build(12);
        let mut c = cfg(Variant::AsyncA2A, 4);
        c.alpha = 1.0;
        let pol = StopPolicy { threshold: 1e-11, max_iters: 500, ..Default::default() };
        let out = run_federated(&p, &c, pol, false);
        assert_eq!(out.node_stats.len(), 4);
        assert!(out.iterations <= 500);
    }
}
