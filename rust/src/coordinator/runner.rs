//! Shared scaffolding: spawn node threads, collect per-node outcomes.

use super::{aggregate_stop, async_a2a, star, sync_a2a};
use crate::config::{DomainChoice, SolveConfig, Variant};
use crate::linalg::{Domain, Mat, Stabilization};
use crate::metrics::SplitTimer;
use crate::net::{DelayTracker, LatencyModel, NetTraffic, SimNet};
use crate::runtime::{make_backend, StabStats};
use crate::sinkhorn::{CentralizedSolver, State, StopPolicy, StopReason};
use crate::workload::{Partition, Problem};
use std::sync::Arc;

/// Per-node result.
#[derive(Clone, Debug)]
pub struct NodeStats {
    pub id: usize,
    pub role: &'static str,
    pub timer: SplitTimer,
    pub iterations: usize,
    pub stop: StopReason,
    pub final_err: f64,
    /// Absorption-hybrid counters of this node's operators (u-op + v-op,
    /// or the star server's two kernel ops); `None` when the node ran no
    /// stabilized schedule (linear domain, dense/sparse logsumexp, pure
    /// element-wise star clients).
    pub stab: Option<StabStats>,
    /// Peers this node declared dead under the recovery policy (empty on
    /// lossless runs and for nodes that saw every peer respond).
    pub lost_peers: Vec<usize>,
}

impl NodeStats {
    pub fn comp_secs(&self) -> f64 {
        self.timer.comp_secs()
    }

    pub fn comm_secs(&self) -> f64 {
        self.timer.comm_secs()
    }

    pub fn total_secs(&self) -> f64 {
        self.timer.total_secs()
    }
}

/// One point of a traced error curve (Figs 9–12, 19–22).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub secs: f64,
    /// Aggregated (sync) or node-0-estimated (async) a-marginal L1 error.
    pub err: f64,
}

/// Aggregate run outcome.
#[derive(Clone, Debug)]
pub struct FederatedOutcome {
    pub state: State,
    pub iterations: usize,
    pub converged: bool,
    pub stop: StopReason,
    pub node_stats: Vec<NodeStats>,
    /// Staleness samples (async variants only).
    pub taus: Vec<u64>,
    pub trace: Vec<TracePoint>,
    pub secs: f64,
    /// Absorption-hybrid counters merged across every node that ran the
    /// stabilized log schedule (`None` when none did).
    pub stab: Option<StabStats>,
    /// Per-[`crate::net::TagKind`] wire traffic (bytes priced on the
    /// encoded frames); default-empty for centralized runs, which have
    /// no fabric.
    pub traffic: NetTraffic,
    /// Whether the run lost a node: a crash injection fired or a peer
    /// was declared dead. A degraded outcome's `state` is partial —
    /// dead slices hold their last received value (`exclude`) or their
    /// abort-time value (`abort`).
    pub degraded: bool,
    /// The ids every node agrees are gone (crashed nodes plus the union
    /// of `NodeStats::lost_peers`), sorted.
    pub lost_nodes: Vec<usize>,
}

/// Everything a protocol implementation needs.
pub struct RunCtx<'a> {
    pub problem: &'a Problem,
    pub partition: &'a Partition,
    pub cfg: &'a SolveConfig,
    pub policy: StopPolicy,
    pub traced: bool,
    /// Resolved numerics domain (cfg.domain is a *choice*; this is the
    /// per-problem decision every node follows, so the whole run
    /// exchanges one kind of scaling slice).
    pub domain: Domain,
    /// Stabilized log-path tuning every node's operators share: the
    /// absorption-hybrid schedule keeps GEMV cost on most iterations
    /// while the wire still carries plain log-scaling slices.
    pub stab: Stabilization,
    pub backend: Arc<dyn crate::runtime::ComputeBackend>,
    pub net: Arc<SimNet>,
    pub delays: Arc<DelayTracker>,
}

impl RunCtx<'_> {
    /// Whether the fleet-synchronized absorption protocol is active for
    /// this run: the explicit `--fleet-absorb` toggle plus a log-domain
    /// hybrid schedule to synchronize. (Non-hybrid operators would only
    /// ever send degraded probes — skip the traffic entirely.)
    pub fn fleet_on(&self) -> bool {
        self.stab.fleet_absorb && self.domain == Domain::Log && self.stab.hybrid_enabled()
    }

    /// Whether the slice-streaming exchange is active
    /// (`--stream-exchange`): folds peer slices into the pending block
    /// product as frames land. Disabled under fleet absorption — the
    /// coordinator's re-absorption command must land *before* the
    /// product that consumes the exchanged state, which would
    /// invalidate partials folded against the pre-command kernel.
    pub fn stream_on(&self) -> bool {
        self.cfg.stream_exchange && !self.fleet_on()
    }
}

/// Per-node return value from protocol implementations.
pub struct NodeOutcome {
    pub stats: NodeStats,
    /// Final consistent slices (u_jj, v_jj) — (m × N) each; `None` for
    /// pure-relay nodes (the star server).
    pub slices: Option<(Mat, Mat)>,
    pub trace: Vec<TracePoint>,
}

/// Entry point: run `cfg.variant` on `p` and assemble the global state.
pub fn run_federated(
    p: &Problem,
    cfg: &SolveConfig,
    policy: StopPolicy,
    traced: bool,
) -> FederatedOutcome {
    let t0 = std::time::Instant::now();
    // A centralized run owns the whole worker pool; a federated run
    // splits it across simulated nodes (each node thread dispatches with
    // its share), so `c` nodes never oversubscribe the resident workers
    // the way `c × compute_threads` scoped spawns used to.
    let node_share = match cfg.variant {
        Variant::Centralized => cfg.compute_threads.max(1),
        Variant::SyncStar | Variant::AsyncStar => {
            cfg.compute_threads.div_ceil(cfg.clients + 1).max(1)
        }
        _ => cfg.compute_threads.div_ceil(cfg.clients.max(1)).max(1),
    };
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir, node_share)
        .expect("backend construction");

    // Resolve the numerics domain once for the whole run. An *automatic*
    // log pick degrades gracefully on a backend without a log operator;
    // an explicit `--domain log` is honored and fails in the backend with
    // its descriptive error (the CLI rejects that combination up front).
    let mut domain = cfg.domain.resolve(p);
    if domain == Domain::Log
        && cfg.domain == DomainChoice::Auto
        && !backend.supports_log()
    {
        eprintln!(
            "warning: auto-selected log domain is unsupported by the '{}' backend; \
             staying linear (expect underflow at this ε)",
            backend.name()
        );
        domain = Domain::Linear;
    }

    if cfg.variant == Variant::Centralized {
        let solver = CentralizedSolver::new(backend).with_stabilization(cfg.stab);
        let out = if traced {
            solver.solve_traced_in(p, policy, cfg.alpha, domain)
        } else {
            solver.solve_in(p, policy, cfg.alpha, domain)
        };
        let mut timer = SplitTimer::new();
        timer.add_comp(out.secs);
        return FederatedOutcome {
            iterations: out.iterations,
            converged: out.converged(),
            stop: out.stop,
            node_stats: vec![NodeStats {
                id: 0,
                role: "centralized",
                timer,
                iterations: out.iterations,
                stop: out.stop,
                final_err: out.final_err,
                stab: out.stab.clone(),
                lost_peers: Vec::new(),
            }],
            taus: Vec::new(),
            trace: out
                .history
                .iter()
                .map(|h| TracePoint { iter: h.iter, secs: h.secs, err: h.err_a })
                .collect(),
            stab: out.stab,
            state: out.state,
            secs: t0.elapsed().as_secs_f64(),
            traffic: NetTraffic::default(),
            degraded: false,
            lost_nodes: Vec::new(),
        };
    }

    let partition = Partition::new_in(p, cfg.clients, domain);
    let nodes = match cfg.variant {
        Variant::SyncStar | Variant::AsyncStar => cfg.clients + 1, // + server
        _ => cfg.clients,
    };
    let latency: LatencyModel = cfg.net;
    let net = Arc::new(
        SimNet::with_wire(nodes, latency, cfg.seed, cfg.wire)
            .with_keyframe_every(cfg.wire_keyframe_every)
            .with_faults(cfg.faults.clone()),
    );
    let delays = Arc::new(DelayTracker::new());

    let ctx = RunCtx {
        problem: p,
        partition: &partition,
        cfg,
        policy,
        traced,
        domain,
        stab: cfg.stab,
        backend,
        net: net.clone(),
        delays: delays.clone(),
    };

    let outcomes: Vec<NodeOutcome> = match cfg.variant {
        Variant::SyncA2A => sync_a2a::run(&ctx),
        Variant::AsyncA2A => async_a2a::run(&ctx),
        Variant::SyncStar => star::run(&ctx, false),
        Variant::AsyncStar => star::run(&ctx, true),
        Variant::Centralized => unreachable!(),
    };

    // Assemble the global state from client slices (paper: a consistent
    // broadcast at the end gives every node the full u, v).
    let nh = p.hists();
    let mut state = State::init(p.n, nh, domain);
    let m = partition.m();
    for out in &outcomes {
        if let Some((u_jj, v_jj)) = &out.slices {
            let j = out.stats.id;
            for i in 0..m {
                for h in 0..nh {
                    state.u[(j * m + i, h)] = u_jj[(i, h)];
                    state.v[(j * m + i, h)] = v_jj[(i, h)];
                }
            }
        }
    }

    let node_stats: Vec<NodeStats> = outcomes.iter().map(|o| o.stats.clone()).collect();
    let stab = node_stats
        .iter()
        .fold(None, |acc, s| StabStats::merged(acc, s.stab.clone()));
    let stop = aggregate_stop(&node_stats);
    // Node-loss bookkeeping: crashed nodes + every peer anyone struck
    // dead. Nonempty (or a PeerLoss abort) flags the outcome degraded.
    let mut lost_nodes: Vec<usize> = node_stats
        .iter()
        .filter(|s| s.stop == StopReason::Dead)
        .map(|s| s.id)
        .chain(node_stats.iter().flat_map(|s| s.lost_peers.iter().copied()))
        .collect();
    lost_nodes.sort_unstable();
    lost_nodes.dedup();
    let degraded = !lost_nodes.is_empty() || stop == StopReason::PeerLoss;
    let iterations = node_stats.iter().map(|s| s.iterations).max().unwrap_or(0);
    // Node 0's trace is the representative curve (paper plots "the first
    // node"); sync traces are identical across nodes anyway.
    let trace = outcomes
        .into_iter()
        .find(|o| o.stats.id == 0)
        .map(|o| o.trace)
        .unwrap_or_default();

    FederatedOutcome {
        state,
        iterations,
        converged: stop == StopReason::Converged,
        stop,
        node_stats,
        taus: delays.taus(),
        trace,
        secs: t0.elapsed().as_secs_f64(),
        stab,
        traffic: net.traffic(),
        degraded,
        lost_nodes,
    }
}

/// Spawn one thread per node and collect outcomes (ordered by node id).
pub fn spawn_nodes<F>(nodes: usize, f: F) -> Vec<NodeOutcome>
where
    F: Fn(usize) -> NodeOutcome + Sync,
{
    let mut outcomes: Vec<Option<NodeOutcome>> = Vec::new();
    crossbeam_utils::thread::scope(|s| {
        let handles: Vec<_> = (0..nodes)
            .map(|id| {
                let f = &f;
                s.spawn(move |_| f(id))
            })
            .collect();
        for h in handles {
            outcomes.push(Some(h.join().expect("node thread panicked")));
        }
    })
    .expect("node scope");
    let mut outcomes: Vec<NodeOutcome> = outcomes.into_iter().map(Option::unwrap).collect();
    outcomes.sort_by_key(|o| o.stats.id);
    outcomes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::workload::ProblemSpec;

    /// Build a minimal [`RunCtx`] over `cfg` and read back the
    /// exchange-mode precedence flags.
    fn probe(
        cfg: &SolveConfig,
        p: &Problem,
        partition: &Partition,
        domain: Domain,
    ) -> (bool, bool) {
        let net = Arc::new(SimNet::with_wire(cfg.clients, cfg.net, cfg.seed, cfg.wire));
        let ctx = RunCtx {
            problem: p,
            partition,
            cfg,
            policy: StopPolicy::default(),
            traced: false,
            domain,
            stab: cfg.stab,
            backend: make_backend(BackendKind::Native, "", 1).unwrap(),
            net,
            delays: Arc::new(DelayTracker::new()),
        };
        (ctx.fleet_on(), ctx.stream_on())
    }

    #[test]
    fn fleet_absorb_takes_precedence_over_stream_exchange() {
        let p = ProblemSpec::new(8).with_eps(0.5).build(9);
        let mut cfg = SolveConfig {
            backend: BackendKind::Native,
            clients: 2,
            stream_exchange: true,
            ..Default::default()
        };
        cfg.stab.fleet_absorb = true;
        let partition = Partition::new_in(&p, cfg.clients, Domain::Log);
        // Both flags set in the log domain: fleet wins, streaming
        // silently defers (the CLI warns about exactly this).
        let (fleet, stream) = probe(&cfg, &p, &partition, Domain::Log);
        assert!(fleet && !stream, "fleet must suppress streaming");
        // Fleet off again: streaming is honored.
        cfg.stab.fleet_absorb = false;
        let (fleet, stream) = probe(&cfg, &p, &partition, Domain::Log);
        assert!(!fleet && stream);
        // Fleet requested but the hybrid disabled (τ = ∞): there is no
        // absorption schedule to synchronize, so streaming stays on.
        cfg.stab.fleet_absorb = true;
        cfg.stab.absorb_threshold = f64::INFINITY;
        let (fleet, stream) = probe(&cfg, &p, &partition, Domain::Log);
        assert!(!fleet && stream);
    }
}
