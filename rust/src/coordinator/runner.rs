//! Shared scaffolding: spawn node threads, collect per-node outcomes.

use super::ctx::RunCtx;
use super::engine;
use super::outcome::{aggregate_stop, FederatedOutcome, NodeOutcome, NodeStats, TracePoint};
use crate::config::{DomainChoice, ExchangeMode, SolveConfig, Variant};
use crate::linalg::Domain;
use crate::metrics::SplitTimer;
use crate::net::{DelayTracker, LatencyModel, NetTraffic, SimNet};
use crate::runtime::{make_backend, GreedyStats, StabStats};
use crate::sinkhorn::{CentralizedSolver, State, StopPolicy, StopReason};
use crate::workload::{Partition, Problem};
use std::sync::Arc;

/// Entry point: run `cfg.variant` on `p` and assemble the global state.
pub fn run_federated(
    p: &Problem,
    cfg: &SolveConfig,
    policy: StopPolicy,
    traced: bool,
) -> FederatedOutcome {
    let t0 = std::time::Instant::now();
    // A centralized run owns the whole worker pool; a federated run
    // splits it across simulated nodes (each node thread dispatches with
    // its share), so `c` nodes never oversubscribe the resident workers
    // the way `c × compute_threads` scoped spawns used to.
    let node_share = match cfg.variant {
        Variant::Centralized => cfg.compute_threads.max(1),
        Variant::SyncStar | Variant::AsyncStar => {
            cfg.compute_threads.div_ceil(cfg.clients + 1).max(1)
        }
        _ => cfg.compute_threads.div_ceil(cfg.clients.max(1)).max(1),
    };
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir, node_share)
        .expect("backend construction");

    // Resolve the numerics domain once for the whole run. An *automatic*
    // log pick degrades gracefully on a backend without a log operator;
    // an explicit `--domain log` is honored and fails in the backend with
    // its descriptive error (the CLI rejects that combination up front).
    let mut domain = cfg.domain.resolve(p);
    if domain == Domain::Log
        && cfg.domain == DomainChoice::Auto
        && !backend.supports_log()
    {
        eprintln!(
            "warning: auto-selected log domain is unsupported by the '{}' backend; \
             staying linear (expect underflow at this ε)",
            backend.name()
        );
        domain = Domain::Linear;
    }

    if cfg.variant == Variant::Centralized {
        let solver = CentralizedSolver::new(backend).with_stabilization(cfg.stab);
        // `--exchange greedy` on the centralized baseline runs the
        // Greenkhorn-style top-k schedule — the reference iterate
        // sequence the federated greedy runs are compared against.
        let out = if cfg.exchange == ExchangeMode::Greedy {
            solver.solve_greedy_in(p, policy, cfg.alpha, domain, cfg.greedy_topk)
        } else if traced {
            solver.solve_traced_in(p, policy, cfg.alpha, domain)
        } else {
            solver.solve_in(p, policy, cfg.alpha, domain)
        };
        let mut timer = SplitTimer::new();
        timer.add_comp(out.secs);
        return FederatedOutcome {
            iterations: out.iterations,
            converged: out.converged(),
            stop: out.stop,
            node_stats: vec![NodeStats {
                id: 0,
                role: "centralized",
                timer,
                iterations: out.iterations,
                stop: out.stop,
                final_err: out.final_err,
                stab: out.stab.clone(),
                greedy: out.greedy.clone(),
                lost_peers: Vec::new(),
            }],
            taus: Vec::new(),
            trace: out
                .history
                .iter()
                .map(|h| TracePoint { iter: h.iter, secs: h.secs, err: h.err_a })
                .collect(),
            stab: out.stab,
            greedy: out.greedy,
            state: out.state,
            secs: t0.elapsed().as_secs_f64(),
            traffic: NetTraffic::default(),
            degraded: false,
            lost_nodes: Vec::new(),
        };
    }

    let partition = Partition::new_in(p, cfg.clients, domain);
    let nodes = match cfg.variant {
        Variant::SyncStar | Variant::AsyncStar => cfg.clients + 1, // + server
        _ => cfg.clients,
    };
    let latency: LatencyModel = cfg.net;
    let net = Arc::new(
        SimNet::with_wire(nodes, latency, cfg.seed, cfg.wire)
            .with_keyframe_every(cfg.wire_keyframe_every)
            .with_faults(cfg.faults.clone()),
    );
    let delays = Arc::new(DelayTracker::new());

    let ctx = RunCtx {
        problem: p,
        partition: &partition,
        cfg,
        policy,
        traced,
        domain,
        stab: cfg.stab,
        backend,
        net: net.clone(),
        delays: delays.clone(),
    };

    let outcomes: Vec<NodeOutcome> = engine::run_topology(&ctx);

    // Assemble the global state from client slices (paper: a consistent
    // broadcast at the end gives every node the full u, v).
    let nh = p.hists();
    let mut state = State::init(p.n, nh, domain);
    let m = partition.m();
    for out in &outcomes {
        if let Some((u_jj, v_jj)) = &out.slices {
            let j = out.stats.id;
            for i in 0..m {
                for h in 0..nh {
                    state.u[(j * m + i, h)] = u_jj[(i, h)];
                    state.v[(j * m + i, h)] = v_jj[(i, h)];
                }
            }
        }
    }

    let node_stats: Vec<NodeStats> = outcomes.iter().map(|o| o.stats.clone()).collect();
    let stab = node_stats
        .iter()
        .fold(None, |acc, s| StabStats::merged(acc, s.stab.clone()));
    let greedy = node_stats
        .iter()
        .fold(None, |acc, s| GreedyStats::merged(acc, s.greedy.clone()));
    let stop = aggregate_stop(&node_stats);
    // Node-loss bookkeeping: crashed nodes + every peer anyone struck
    // dead. Nonempty (or a PeerLoss abort) flags the outcome degraded.
    let mut lost_nodes: Vec<usize> = node_stats
        .iter()
        .filter(|s| s.stop == StopReason::Dead)
        .map(|s| s.id)
        .chain(node_stats.iter().flat_map(|s| s.lost_peers.iter().copied()))
        .collect();
    lost_nodes.sort_unstable();
    lost_nodes.dedup();
    let degraded = !lost_nodes.is_empty() || stop == StopReason::PeerLoss;
    let iterations = node_stats.iter().map(|s| s.iterations).max().unwrap_or(0);
    // Node 0's trace is the representative curve (paper plots "the first
    // node"); sync traces are identical across nodes anyway.
    let trace = outcomes
        .into_iter()
        .find(|o| o.stats.id == 0)
        .map(|o| o.trace)
        .unwrap_or_default();

    FederatedOutcome {
        state,
        iterations,
        converged: stop == StopReason::Converged,
        stop,
        node_stats,
        taus: delays.taus(),
        trace,
        secs: t0.elapsed().as_secs_f64(),
        stab,
        greedy,
        traffic: net.traffic(),
        degraded,
        lost_nodes,
    }
}

/// Spawn one thread per node and collect outcomes (ordered by node id).
pub fn spawn_nodes<F>(nodes: usize, f: F) -> Vec<NodeOutcome>
where
    F: Fn(usize) -> NodeOutcome + Sync,
{
    let mut outcomes: Vec<Option<NodeOutcome>> = Vec::new();
    crossbeam_utils::thread::scope(|s| {
        let handles: Vec<_> = (0..nodes)
            .map(|id| {
                let f = &f;
                s.spawn(move |_| f(id))
            })
            .collect();
        for h in handles {
            outcomes.push(Some(h.join().expect("node thread panicked")));
        }
    })
    .expect("node scope");
    let mut outcomes: Vec<NodeOutcome> = outcomes.into_iter().map(Option::unwrap).collect();
    outcomes.sort_by_key(|o| o.stats.id);
    outcomes
}
