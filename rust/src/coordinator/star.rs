//! Algorithm 3 — Federated Star-Network Sinkhorn (sync), plus the
//! asynchronous star variant.
//!
//! Topology: clients `0..c` own only their marginal slices `a_j`, `b_j`;
//! the server (node id `c`) owns the full Gibbs kernel `K` and performs
//! the heavy products `q = K·v`, `r = Kᵀ·u`, scattering the row chunks
//! back. Clients do O(m·N) element-wise scaling only — exactly the
//! paper's privacy regime 2 (the center "has the cost information").
//!
//! Synchronous: gather → product → scatter in lock-step; convergence is
//! decided from the gathered per-client block errors and broadcast, so
//! all nodes stop together (and the iterate sequence again equals the
//! centralized one, Prop. 1).
//!
//! Asynchronous: the server recomputes products from whatever slices
//! have arrived (latest-wins) and streams chunks back; clients fold in
//! the freshest chunk, apply the damped update, and stop independently.
//!
//! Both modes are generic over the run's numerics [`Domain`]: in the log
//! domain the server's products are row-wise logsumexps of
//! `log K + log v`, the scattered chunks are `log(K v)` rows, and every
//! exchanged slice is a log-scaling slice (the quantity the paper's
//! privacy layer instruments). Client updates divide in log space and
//! the convergence errors stay linear-domain L1, so the stopping rule is
//! identical across domains.
//!
//! Under `--exchange greedy` the clients damp only their top-k
//! most-violated rows per half-iteration and uplink just those
//! coordinates as sparse index+value frames (sync: reliable class,
//! gathered by [`super::engine::greedy_server_gather`]; async:
//! latest-wins with oldest-first drains). The downlink chunks stay
//! dense — the kernel couples every product row to every input row
//! regardless of how sparse the input moved — so greedy buys its
//! savings on the uplink α–β term and the clients' update compute.
//!
//! The generic machinery — strike-bounded receives, the streamed-fold
//! server product, element-wise client updates — lives in
//! [`super::engine`]; this module keeps only the four star node loops.

use super::engine::{
    block_err, chunk_of, count_alive, greedy_server_gather, lost_of, pack_rows, recv_chunk,
    scatter_sparse, server_product, write_block, ClientTargets,
};
use super::fleet;
use super::outcome::{NodeOutcome, NodeStats, TracePoint};
use super::RunCtx;
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{bcast, bcast_resilient, gather, gather_resilient, NodeLoss, TagKind};
use crate::runtime::{GreedyStats, StabStats, Target};
use crate::sinkhorn::StopReason;
use std::time::Instant;

/// Coded-stream ids (stable per logical stream — see
/// [`crate::net::wire`]): client scaling slices up to the server, and
/// the server's two product-chunk streams back down. Convergence votes
/// and stop decisions stay on the exact path.
const STREAM_SLICE: u64 = 0;
const STREAM_CHUNK_Q: u64 = 1;
const STREAM_CHUNK_R: u64 = 2;

pub fn run(ctx: &RunCtx<'_>, async_mode: bool) -> Vec<NodeOutcome> {
    let c = ctx.cfg.clients;
    super::runner::spawn_nodes(c + 1, |id| {
        if id == c {
            if async_mode {
                server_async(ctx)
            } else {
                server_sync(ctx)
            }
        } else if async_mode {
            client_async(ctx, id)
        } else {
            client_sync(ctx, id)
        }
    })
}

// --------------------------------------------------------------------------
// Synchronous star
// --------------------------------------------------------------------------

fn server_sync(ctx: &RunCtx<'_>) -> NodeOutcome {
    let p = ctx.problem;
    let (n, nh, c) = (p.n, p.hists(), ctx.cfg.clients);
    let m = n / c;
    let ep = ctx.net.endpoint(c);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // The server's two resident operators (only `matvec` is used; the
    // target is a placeholder — the server never sees a or b). Kernel
    // and its transpose come from the problem's shared cache in the
    // run's numerics domain; the stabilized dispatch lets the log-domain
    // products run on the absorption-hybrid / truncated-sparse schedule.
    let one = ctx.domain.one();
    let dummy = vec![1.0; n];
    let mut k_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            p.kernel_for(ctx.domain),
            Target::Vec(&dummy),
            Mat::full(n, nh, one),
            &ctx.stab,
        )
        .expect("k-op");
    let mut kt_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            p.kernel_t_for(ctx.domain),
            Target::Vec(&dummy),
            Mat::full(n, nh, one),
            &ctx.stab,
        )
        .expect("kt-op");

    let mut v_full = Mat::full(n, nh, one);
    let mut u_full = Mat::full(n, nh, one);
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;
    let mut round: u64 = 0;

    // Self-healing state (active fault plans only): `alive` spans every
    // node (clients 0..c, server at c). A client that stays silent
    // through the full strike budget inside a product gather is dead;
    // `abort` stops with a structured partial outcome, `exclude` freezes
    // its slice rows and keeps going degraded.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(c);
    let mut alive = vec![true; c + 1];

    // In the star topology the coordinator *owns* the kernel, so the
    // fleet-absorption round is local: same decision logic as the wire
    // protocol, zero extra messages (the Gref α–β term vanishes).
    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;
    // Streamed exchange: the server folds each client's slice into the
    // pending product as its frame becomes deliverable instead of
    // waiting out the whole gather (inert under fleet — the local
    // decide/apply must see the product after the re-absorption).
    let stream = ctx.stream_on();
    // Greedy top-k exchange: clients uplink only the coordinates their
    // damped update touched, scattered into the resident full state.
    // The downlink chunks stay dense — the product rows move wherever
    // the kernel couples them regardless of how sparse the input moved,
    // so greedy saves the uplink bytes and the clients' update compute.
    let greedy = ctx.greedy_on();

    'outer: for k in 1..=ctx.policy.max_iters {
        // Crash injection fires at an iteration boundary: the server
        // exits cleanly; clients discover the silence through their own
        // strike budgets and abort with PeerLoss.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break;
        }
        iterations = k;
        let k64 = k as u64;

        // Gather v slices → q = K v → scatter the q row chunks. (The
        // server holds no chunk of its own, so the scatter is explicit
        // per-client sends rather than the equal-split collective.)
        round += 1;
        let was_alive = count_alive(&alive);
        let q = if greedy {
            greedy_server_gather(
                &ep,
                TagKind::SparseV,
                round,
                &mut v_full,
                m,
                &mut timer,
                &mut alive[..c],
                resilient.then_some(&recovery),
            );
            if fleet {
                timer.comp(|| fleet::local_decide_apply(&mut *k_op, &v_full, tau));
            }
            timer.comp(|| k_op.matvec(&v_full).clone())
        } else {
            server_product(
                &ep,
                TagKind::V,
                round,
                &mut *k_op,
                &mut v_full,
                m,
                c,
                stream,
                fleet,
                tau,
                &mut timer,
                &mut alive[..c],
                resilient.then_some(&recovery),
            )
        };
        if resilient
            && count_alive(&alive) < was_alive
            && recovery.on_node_loss == NodeLoss::Abort
        {
            stop = StopReason::PeerLoss;
            break 'outer;
        }
        round += 1;
        timer.comm(|| {
            for j in 0..c {
                if alive[j] {
                    let chunk = chunk_of(&q, j, m).to_vec();
                    ep.send_coded(j, TagKind::Ctl, round, STREAM_CHUNK_Q, chunk, k64);
                }
            }
        });

        // Convergence decision happens here, *before* the u-update on
        // the clients: err_j = Σ|u_prev∘q − a_j| is the true marginal
        // error of the current state (checking after the update would
        // read identically zero at α = 1 since u = a/q by construction).
        if ctx.policy.check_at(k) {
            round += 1;
            let (total, mut any_timeout) = if resilient {
                // Dead clients' slots come back `None`: their frozen
                // rows contribute no marginal error and cast no votes.
                let parts = timer
                    .comm(|| {
                        gather_resilient(
                            &ep,
                            c,
                            TagKind::Ctl,
                            round,
                            None,
                            &[0.0, 0.0],
                            k64,
                            &mut alive,
                            &recovery,
                        )
                    })
                    .expect("the root always collects");
                let total: f64 = parts.iter().take(c).flatten().map(|e| e[0]).sum();
                let timed = parts.iter().take(c).flatten().any(|e| e[1] > 0.0);
                (total, timed)
            } else {
                let errs =
                    timer.comm(|| gather(&ep, c, TagKind::Ctl, round, &[0.0, 0.0], k64).unwrap());
                let total: f64 = errs.iter().take(c).map(|e| e[0]).sum();
                (total, errs.iter().take(c).any(|e| e[1] > 0.0))
            };
            any_timeout |=
                ctx.policy.timeout_secs > 0.0 && clock.now() > ctx.policy.timeout_secs;
            final_err = total;
            round += 1;
            let decision = [total, any_timeout as u8 as f64];
            if resilient {
                let _ = timer.comm(|| {
                    bcast_resilient(
                        &ep,
                        c,
                        TagKind::Ctl,
                        round,
                        None,
                        Some(&decision),
                        k64,
                        &mut alive,
                        &recovery,
                    )
                });
            } else {
                timer.comm(|| bcast(&ep, c, TagKind::Ctl, round, Some(&decision), k64));
            }
            if total < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
            if any_timeout {
                stop = StopReason::Timeout;
                break;
            }
        }

        // Gather u slices → r = Kᵀ u → scatter the r row chunks.
        round += 1;
        let was_alive = count_alive(&alive);
        let r = if greedy {
            greedy_server_gather(
                &ep,
                TagKind::SparseU,
                round,
                &mut u_full,
                m,
                &mut timer,
                &mut alive[..c],
                resilient.then_some(&recovery),
            );
            if fleet {
                timer.comp(|| fleet::local_decide_apply(&mut *kt_op, &u_full, tau));
            }
            timer.comp(|| kt_op.matvec(&u_full).clone())
        } else {
            server_product(
                &ep,
                TagKind::U,
                round,
                &mut *kt_op,
                &mut u_full,
                m,
                c,
                stream,
                fleet,
                tau,
                &mut timer,
                &mut alive[..c],
                resilient.then_some(&recovery),
            )
        };
        if resilient
            && count_alive(&alive) < was_alive
            && recovery.on_node_loss == NodeLoss::Abort
        {
            stop = StopReason::PeerLoss;
            break 'outer;
        }
        round += 1;
        timer.comm(|| {
            for j in 0..c {
                if alive[j] {
                    let chunk = chunk_of(&r, j, m).to_vec();
                    ep.send_coded(j, TagKind::Ctl, round, STREAM_CHUNK_R, chunk, k64);
                }
            }
        });
        // Dequantizing the received slice frames is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());
    }
    timer.add_comp(ep.take_decode_secs());

    NodeOutcome {
        stats: NodeStats {
            id: c,
            role: "server",
            timer,
            iterations,
            stop,
            final_err,
            stab: StabStats::merged(k_op.stab_stats(), kt_op.stab_stats()),
            // Row selection happens client-side; the server only
            // scatters the frames, so it keeps no greedy counters.
            greedy: None,
            lost_peers: lost_of(&alive),
        },
        slices: None,
        trace: Vec::new(),
    }
}

fn client_sync(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (m, nh, c) = (shard.m(), ctx.problem.hists(), ctx.cfg.clients);
    let alpha = ctx.cfg.alpha;
    let server = c;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    let domain = ctx.domain;
    // In the log domain the element-wise update divides by the product
    // in log space: log u ← α(log a − q) + (1−α) log u. Precompute the
    // log targets once.
    let targets = ClientTargets::new(shard, domain);
    let mut u_jj = Mat::full(m, nh, domain.one());
    let mut v_jj = Mat::full(m, nh, domain.one());
    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;
    let mut round: u64 = 0;

    // Greedy top-k exchange (`--exchange greedy`): damp only the rows
    // with the largest marginal violation and uplink just those
    // coordinates. `pending_*` holds the rows damped since the last
    // uplink — empty on the first frame, which is correct: the server's
    // resident state starts at the same all-ones init as ours.
    let greedy = ctx.greedy_on();
    let spec = ctx.cfg.greedy_topk;
    let mut gstats = GreedyStats::default();
    let mut pending_u: Vec<u32> = Vec::new();
    let mut pending_v: Vec<u32> = Vec::new();

    // Self-healing state (active fault plans only). A silent server is
    // always fatal — it owns the kernel, so there is nothing to exclude
    // down to: strike out → PeerLoss regardless of `--on-node-loss`.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut alive = vec![true; c + 1];

    for k in 1..=ctx.policy.max_iters {
        // Crash injection: exit cleanly at an iteration boundary; the
        // server's strike budget discovers the silence.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break;
        }
        iterations = k;
        let k64 = k as u64;

        // Send v slice (sparse coordinates under greedy); receive the
        // q = (K v) chunk for this block.
        round += 1;
        if greedy {
            let (idx, vals) = pack_rows(&v_jj, 0, &pending_v, nh);
            timer.comm(|| {
                ep.send_sparse_coded(
                    server,
                    TagKind::SparseV,
                    round,
                    STREAM_SLICE,
                    idx,
                    vals,
                    m * nh,
                    k64,
                )
            });
            pending_v.clear();
        } else {
            timer.comm(|| {
                ep.send_coded(
                    server,
                    TagKind::V,
                    round,
                    STREAM_SLICE,
                    v_jj.as_slice().to_vec(),
                    k64,
                )
            });
        }
        round += 1;
        let Some(q) = timer.comm(|| recv_chunk(&ep, server, round, resilient, &recovery)) else {
            alive[server] = false;
            stop = StopReason::PeerLoss;
            break;
        };

        // Convergence check *before* the u-update: err_j = Σ|u∘q − a_j|
        // is the true marginal error of the current (u, v); checking
        // post-update would read 0 identically at α = 1. Timeout flags
        // ride along so stopping stays lock-step with the server.
        if ctx.policy.check_at(k) {
            let local = timer.comp(|| block_err(&u_jj, &q, &shard.a, m, nh, domain));
            let timed_out = ctx.policy.timeout_secs > 0.0
                && clock.now() > ctx.policy.timeout_secs;
            let vote = [local, timed_out as u8 as f64];
            round += 1;
            let decision = if resilient {
                timer.comm(|| {
                    let _ = gather_resilient(
                        &ep,
                        server,
                        TagKind::Ctl,
                        round,
                        None,
                        &vote,
                        k64,
                        &mut alive,
                        &recovery,
                    );
                    round += 1;
                    bcast_resilient(
                        &ep,
                        server,
                        TagKind::Ctl,
                        round,
                        None,
                        None,
                        k64,
                        &mut alive,
                        &recovery,
                    )
                })
            } else {
                timer.comm(|| gather(&ep, server, TagKind::Ctl, round, &vote, k64));
                round += 1;
                Some(timer.comm(|| bcast(&ep, server, TagKind::Ctl, round, None, k64)))
            };
            let Some(decision) = decision else {
                // The server never answered the decision broadcast.
                stop = StopReason::PeerLoss;
                break;
            };
            let total = decision[0];
            final_err = total;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err: total });
            }
            if total < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
            if decision[1] > 0.0 {
                stop = StopReason::Timeout;
                break;
            }
        }

        // u_jj ← α a⊘q + (1−α) u_jj (division is a log-subtraction in
        // the log domain). Greedy damps only the top-k violated rows;
        // the untouched rows stay put, so the next uplink skips them.
        if greedy {
            let viol = timer.comp(|| targets.row_violations_u(&u_jj, &q));
            let o = spec.select(&viol);
            timer.comp(|| targets.damped_u_update_rows(&mut u_jj, &q, alpha, &o.rows));
            gstats.record(&o, m);
            pending_u = o.rows;
        } else {
            timer.comp(|| targets.damped_u_update(&mut u_jj, &q, alpha));
        }

        // Send u slice; receive r chunk; v_jj ← α b⊘r + (1−α) v_jj.
        round += 1;
        if greedy {
            let (idx, vals) = pack_rows(&u_jj, 0, &pending_u, nh);
            timer.comm(|| {
                ep.send_sparse_coded(
                    server,
                    TagKind::SparseU,
                    round,
                    STREAM_SLICE,
                    idx,
                    vals,
                    m * nh,
                    k64,
                )
            });
            pending_u.clear();
        } else {
            timer.comm(|| {
                ep.send_coded(
                    server,
                    TagKind::U,
                    round,
                    STREAM_SLICE,
                    u_jj.as_slice().to_vec(),
                    k64,
                )
            });
        }
        round += 1;
        let Some(r) = timer.comm(|| recv_chunk(&ep, server, round, resilient, &recovery)) else {
            alive[server] = false;
            stop = StopReason::PeerLoss;
            break;
        };
        if greedy {
            let viol = timer.comp(|| targets.row_violations_v(&v_jj, &r));
            let o = spec.select(&viol);
            timer.comp(|| targets.damped_v_update_rows(&mut v_jj, &r, alpha, &o.rows));
            gstats.record(&o, m);
            pending_v = o.rows;
        } else {
            timer.comp(|| targets.damped_v_update(&mut v_jj, &r, alpha));
        }
        // Decode cost of the chunks received this iteration.
        timer.add_comp(ep.take_decode_secs());
    }
    timer.add_comp(ep.take_decode_secs());

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            // Star clients run element-wise updates only — the server
            // owns the kernel operators and their hybrid counters.
            stab: None,
            greedy: if greedy { Some(gstats) } else { None },
            lost_peers: lost_of(&alive),
        },
        slices: Some((u_jj, v_jj)),
        trace,
    }
}

// --------------------------------------------------------------------------
// Asynchronous star
// --------------------------------------------------------------------------

const A_TAG: u64 = 0;

fn server_async(ctx: &RunCtx<'_>) -> NodeOutcome {
    let p = ctx.problem;
    let (n, nh, c) = (p.n, p.hists(), ctx.cfg.clients);
    let m = n / c;
    let ep = ctx.net.endpoint(c);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    let one = ctx.domain.one();
    let dummy = vec![1.0; n];
    let mut k_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            p.kernel_for(ctx.domain),
            Target::Vec(&dummy),
            Mat::full(n, nh, one),
            &ctx.stab,
        )
        .expect("k-op");
    let mut kt_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            p.kernel_t_for(ctx.domain),
            Target::Vec(&dummy),
            Mat::full(n, nh, one),
            &ctx.stab,
        )
        .expect("kt-op");

    let mut v_full = Mat::full(n, nh, one);
    let mut u_full = Mat::full(n, nh, one);
    let mut done = vec![false; c];
    // Freshest client iteration seen per client (either kind) — used to
    // throttle fast clients: a client more than `bound` iterations ahead
    // of the slowest live client gets no fresh chunks until the gap
    // closes (the bounded-delay regime of Prop. 2; see async_a2a docs).
    let mut client_iter = vec![0u64; c];
    let greedy = ctx.greedy_on();
    let mut iterations = 0;

    // Self-healing state (active fault plans only): a client that is
    // wall-clock silent past the death budget is folded into the done
    // votes (its chunks stop, the staleness gate skips it) and recorded
    // lost — the async analogue of `--on-node-loss exclude`.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(c);
    let mut dead = vec![false; c];
    let mut last_heard: Vec<Instant> = vec![Instant::now(); c];
    let mut crashed = false;
    // A done vote widens the staleness gate (min_live skips the finished
    // client) without any fresh u/v arriving; the pass that observes it
    // must re-send the current products or a newly eligible, blocked
    // client would starve. The latch is sticky until a pass has honored
    // it — it must never be *overwritten* by a later vote-less pass
    // before the resend actually ran.
    let mut resend = false;

    // Star fleet absorption is server-local (see server_sync).
    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;

    // The server relays until every client reports done; the cap is a
    // safety net (clients are themselves capped at max_iters).
    for s in 1..=(4 * ctx.policy.max_iters) {
        // Crash injection: the relay goes silent at a pass boundary;
        // clients discover it through their own death budgets.
        if crash_at.is_some_and(|ci| s as u64 >= ci) {
            crashed = true;
            break;
        }
        iterations = s;
        let s64 = s as u64;
        // Arrival count *before* this pass's drains: if the whole pass
        // turns up nothing fresh, we park until the inbox moves past it.
        let inbox_seen = ep.inbox_seq();

        // Done votes first (control tag 2): a vote must take effect on
        // *this* pass's staleness gate and resend decision, not a full
        // relay pass later — a client whose vote lands during a stale
        // relay pass used to be starved for the whole window.
        timer.comm(|| {
            for j in 0..c {
                if ep.try_recv_latest(j, TagKind::Ctl, A_TAG + 2).is_some() {
                    done[j] = true;
                    last_heard[j] = Instant::now();
                    resend = true;
                }
            }
        });
        if resilient {
            // A client that is wall-clock silent past the death budget
            // has crashed: treat it as done so the relay stops waiting
            // on it, and remember the loss.
            for j in 0..c {
                if !done[j] && last_heard[j].elapsed().as_secs_f64() >= recovery.death_secs() {
                    done[j] = true;
                    dead[j] = true;
                    resend = true;
                }
            }
        }
        if done.iter().all(|&d| d) {
            break;
        }

        let mut fresh_v = false;
        timer.comm(|| {
            for j in 0..c {
                if greedy {
                    // Sparse frames ride the latest-wins class like all
                    // async scaling traffic, but each frame carries a
                    // *different* coordinate set, so every delivered one
                    // is drained oldest-first and scattered — only
                    // frames superseded in flight are lost, and those
                    // self-heal: values are absolute and the client's
                    // violation-driven selection re-ships any row the
                    // server's resident copy still has wrong.
                    for msg in ep.try_recv_all(j, TagKind::SparseV, A_TAG) {
                        scatter_sparse(&mut v_full, j * m, &msg.indices, &msg.payload, &mut None);
                        client_iter[j] = client_iter[j].max(msg.sent_iter);
                        last_heard[j] = Instant::now();
                        fresh_v = true;
                    }
                } else if let Some(msg) = ep.try_recv_latest(j, TagKind::V, A_TAG) {
                    write_block(&mut v_full, &msg.payload, j, m);
                    client_iter[j] = client_iter[j].max(msg.sent_iter);
                    last_heard[j] = Instant::now();
                    fresh_v = true;
                }
            }
        });
        let min_live = (0..c)
            .filter(|&j| !done[j])
            .map(|j| client_iter[j])
            .min()
            .unwrap_or(0);
        // Staleness gate for this pass, optionally SRTT-scaled
        // (`--srtt-staleness`): on a fabric whose measured round-trips
        // run hot, the same iteration gap represents less real drift,
        // so the bound widens with the slowest live uplink instead of
        // throttling fast clients against a nominal-latency yardstick.
        let srtt_max = (0..c)
            .filter(|&j| !done[j])
            .map(|j| ctx.net.link_rtt(j, c).srtt)
            .fold(0.0, f64::max);
        let bound = ctx.cfg.staleness_bound_for(srtt_max);
        // Products only run on fresh input (s == 1 primes the clients):
        // a stale pass would recompute — and, on the stabilized log
        // schedule, *count* — an identical product, burning compute and
        // inflating the hybrid's per-iteration counters with no-ops.
        if fresh_v || s == 1 || resend {
            if fleet {
                timer.comp(|| fleet::local_decide_apply(&mut *k_op, &v_full, tau));
            }
            let q = timer.comp(|| k_op.matvec(&v_full).clone());
            timer.comm(|| {
                for j in 0..c {
                    if !done[j] && client_iter[j].saturating_sub(min_live) <= bound {
                        // Latest-wins class: a dropped chunk is simply
                        // superseded by the next product's.
                        ep.send_coded_latest(
                            j,
                            TagKind::Ctl,
                            A_TAG,
                            STREAM_CHUNK_Q,
                            chunk_of(&q, j, m).to_vec(),
                            s64,
                        );
                    }
                }
            });
        }

        let mut fresh_u = false;
        timer.comm(|| {
            for j in 0..c {
                if greedy {
                    for msg in ep.try_recv_all(j, TagKind::SparseU, A_TAG) {
                        scatter_sparse(&mut u_full, j * m, &msg.indices, &msg.payload, &mut None);
                        client_iter[j] = client_iter[j].max(msg.sent_iter);
                        last_heard[j] = Instant::now();
                        fresh_u = true;
                    }
                } else if let Some(msg) = ep.try_recv_latest(j, TagKind::U, A_TAG) {
                    write_block(&mut u_full, &msg.payload, j, m);
                    client_iter[j] = client_iter[j].max(msg.sent_iter);
                    last_heard[j] = Instant::now();
                    fresh_u = true;
                }
            }
        });
        if fresh_u || s == 1 || resend {
            if fleet {
                timer.comp(|| fleet::local_decide_apply(&mut *kt_op, &u_full, tau));
            }
            let r = timer.comp(|| kt_op.matvec(&u_full).clone());
            timer.comm(|| {
                for j in 0..c {
                    if !done[j] && client_iter[j].saturating_sub(min_live) <= bound {
                        ep.send_coded_latest(
                            j,
                            TagKind::Ctl,
                            A_TAG + 1,
                            STREAM_CHUNK_R,
                            chunk_of(&r, j, m).to_vec(),
                            s64,
                        );
                    }
                }
            });
        }
        let any_fresh = fresh_v || fresh_u;
        // Any pending resend has now been honored by this pass's sends.
        resend = false;
        // Decode cost of every frame this pass consumed (latest-wins
        // drains included) is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());

        if !any_fresh {
            // Nothing new from any client: park on the inbox until
            // traffic moves past what this pass saw (or a queued frame
            // matures) instead of burning fixed busy-sleeps at a spin.
            ep.wait_traffic(inbox_seen, std::time::Duration::from_millis(1));
        }
        if ctx.policy.timeout_secs > 0.0 && clock.now() > 2.0 * ctx.policy.timeout_secs {
            break;
        }
    }
    timer.add_comp(ep.take_decode_secs());

    NodeOutcome {
        stats: NodeStats {
            id: c,
            role: "server",
            timer,
            iterations,
            // The relay has no convergence criterion of its own; a
            // crash injection is the one way it stops "for itself".
            stop: if crashed { StopReason::Dead } else { StopReason::Converged },
            final_err: 0.0,
            stab: StabStats::merged(k_op.stab_stats(), kt_op.stab_stats()),
            greedy: None, // selection is client-side (see server_sync)
            lost_peers: dead
                .iter()
                .enumerate()
                .filter_map(|(j, &d)| d.then_some(j))
                .collect(),
        },
        slices: None,
        trace: Vec::new(),
    }
}

fn client_async(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (m, nh, c) = (shard.m(), ctx.problem.hists(), ctx.cfg.clients);
    let alpha = ctx.cfg.alpha;
    let server = c;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    let domain = ctx.domain;
    let targets = ClientTargets::new(shard, domain);
    let mut u_jj = Mat::full(m, nh, domain.one());
    let mut v_jj = Mat::full(m, nh, domain.one());
    let mut q_latest = vec![domain.one(); m * nh];
    let mut r_latest = vec![domain.one(); m * nh];
    let mut stale_rounds: u64 = 0;
    let greedy = ctx.greedy_on();
    let spec = ctx.cfg.greedy_topk;
    let mut gstats = GreedyStats::default();
    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;

    // Self-healing state (active fault plans only). A server that stays
    // wall-clock silent while we are blocked on the staleness gate is
    // dead — and the kernel owner has no substitute, so it's PeerLoss.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut server_dead = false;

    // Prime the server with our initial v slice (latest-wins, like all
    // the async scaling traffic: a drop is superseded, never resent).
    // Under greedy the prime is an empty sparse frame — the server's
    // resident state starts at the same all-ones init as ours, so there
    // is nothing to ship yet and the frame just stamps the stream.
    if greedy {
        ep.send_sparse_coded_latest(
            server,
            TagKind::SparseV,
            A_TAG,
            STREAM_SLICE,
            Vec::new(),
            Vec::new(),
            m * nh,
            0,
        );
    } else {
        ep.send_coded_latest(server, TagKind::V, A_TAG, STREAM_SLICE, v_jj.as_slice().to_vec(), 0);
    }

    for k in 1..=ctx.policy.max_iters {
        // Crash injection: exit cleanly at an iteration boundary; the
        // server's death budget folds us into the done set.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break;
        }
        iterations = k;
        let k64 = k as u64;

        // Freshest q chunk (server's K·v rows for this block); if we
        // have outrun the server beyond the staleness bound, wait for a
        // fresh chunk (bounded-delay assumption, see async_a2a docs).
        // The bound is re-read per iteration: under `--srtt-staleness`
        // it scales with the measured server round-trip, so a congested
        // downlink widens the tolerated gap instead of stalling us.
        let bound = ctx.cfg.staleness_bound_for(ctx.net.link_rtt(server, id).srtt);
        timer.comm(|| {
            let mut got = false;
            let wait_start = Instant::now();
            loop {
                let seen = ep.inbox_seq();
                if let Some(msg) = ep.try_recv_latest(server, TagKind::Ctl, A_TAG) {
                    ctx.delays.record(msg.sent_iter, k64);
                    q_latest.copy_from_slice(&msg.payload);
                    got = true;
                }
                if got || stale_rounds < bound {
                    break;
                }
                if resilient && wait_start.elapsed().as_secs_f64() >= recovery.death_secs() {
                    server_dead = true;
                    break;
                }
                // Over the staleness bound with no fresh chunk: park on
                // the inbox until traffic moves (or a frame matures)
                // instead of a fixed busy-sleep.
                ep.wait_traffic(seen, std::time::Duration::from_millis(1));
            }
            stale_rounds = if got { 0 } else { stale_rounds + 1 };
        });
        if server_dead {
            stop = StopReason::PeerLoss;
            break;
        }

        // Marginal error of the *current* state against the freshest q
        // (before the u-update — post-update it is (1−α)-scaled and
        // reads 0 at α = 1).
        let pre_err = if ctx.policy.check_at(k) {
            Some(timer.comp(|| block_err(&u_jj, &q_latest, &shard.a, m, nh, domain)))
        } else {
            None
        };

        if greedy {
            let viol = timer.comp(|| targets.row_violations_u(&u_jj, &q_latest));
            let o = spec.select(&viol);
            timer.comp(|| targets.damped_u_update_rows(&mut u_jj, &q_latest, alpha, &o.rows));
            gstats.record(&o, m);
            let (idx, vals) = pack_rows(&u_jj, 0, &o.rows, nh);
            timer.comm(|| {
                ep.send_sparse_coded_latest(
                    server,
                    TagKind::SparseU,
                    A_TAG,
                    STREAM_SLICE,
                    idx,
                    vals,
                    m * nh,
                    k64,
                )
            });
        } else {
            timer.comp(|| targets.damped_u_update(&mut u_jj, &q_latest, alpha));
            timer.comm(|| {
                ep.send_coded_latest(
                    server,
                    TagKind::U,
                    A_TAG,
                    STREAM_SLICE,
                    u_jj.as_slice().to_vec(),
                    k64,
                )
            });
        }

        // Freshest r chunk, then the damped v update on it.
        timer.comm(|| {
            if let Some(msg) = ep.try_recv_latest(server, TagKind::Ctl, A_TAG + 1) {
                ctx.delays.record(msg.sent_iter, k64);
                r_latest.copy_from_slice(&msg.payload);
            }
        });
        if greedy {
            let viol = timer.comp(|| targets.row_violations_v(&v_jj, &r_latest));
            let o = spec.select(&viol);
            timer.comp(|| targets.damped_v_update_rows(&mut v_jj, &r_latest, alpha, &o.rows));
            gstats.record(&o, m);
            let (idx, vals) = pack_rows(&v_jj, 0, &o.rows, nh);
            timer.comm(|| {
                ep.send_sparse_coded_latest(
                    server,
                    TagKind::SparseV,
                    A_TAG,
                    STREAM_SLICE,
                    idx,
                    vals,
                    m * nh,
                    k64,
                )
            });
        } else {
            timer.comp(|| targets.damped_v_update(&mut v_jj, &r_latest, alpha));
            timer.comm(|| {
                ep.send_coded_latest(
                    server,
                    TagKind::V,
                    A_TAG,
                    STREAM_SLICE,
                    v_jj.as_slice().to_vec(),
                    k64,
                )
            });
        }
        // Dequantizing the chunks consumed this round is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());

        if let Some(local) = pre_err {
            let est = local * c as f64;
            final_err = est;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err: est });
            }
            if est < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
        }
        if ctx.policy.timeout_secs > 0.0 && clock.now() > ctx.policy.timeout_secs {
            stop = StopReason::Timeout;
            break;
        }
    }
    timer.add_comp(ep.take_decode_secs());

    // Tell the server we are finished — unless a crash injection took
    // us out, in which case we go silent and let the death budget talk.
    if stop != StopReason::Dead {
        ep.send(server, TagKind::Ctl, A_TAG + 2, vec![1.0], iterations as u64);
    }

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            stab: None, // element-wise only; the server owns the kernel ops
            greedy: if greedy { Some(gstats) } else { None },
            lost_peers: if server_dead { vec![server] } else { Vec::new() },
        },
        slices: Some((u_jj, v_jj)),
        trace,
    }
}
