//! The shared run context handed to every topology: problem, partition,
//! resolved numerics domain, backend handle, and the simulated fabric.

use crate::config::SolveConfig;
use crate::linalg::{Domain, Stabilization};
use crate::net::{DelayTracker, SimNet};
use crate::sinkhorn::StopPolicy;
use crate::workload::{Partition, Problem};
use std::sync::Arc;

/// Everything a protocol implementation needs.
pub struct RunCtx<'a> {
    pub problem: &'a Problem,
    pub partition: &'a Partition,
    pub cfg: &'a SolveConfig,
    pub policy: StopPolicy,
    pub traced: bool,
    /// Resolved numerics domain (cfg.domain is a *choice*; this is the
    /// per-problem decision every node follows, so the whole run
    /// exchanges one kind of scaling slice).
    pub domain: Domain,
    /// Stabilized log-path tuning every node's operators share: the
    /// absorption-hybrid schedule keeps GEMV cost on most iterations
    /// while the wire still carries plain log-scaling slices.
    pub stab: Stabilization,
    pub backend: Arc<dyn crate::runtime::ComputeBackend>,
    pub net: Arc<SimNet>,
    pub delays: Arc<DelayTracker>,
}

impl RunCtx<'_> {
    /// Whether the fleet-synchronized absorption protocol is active for
    /// this run: the explicit `--fleet-absorb` toggle plus a log-domain
    /// hybrid schedule to synchronize. (Non-hybrid operators would only
    /// ever send degraded probes — skip the traffic entirely.)
    pub fn fleet_on(&self) -> bool {
        self.stab.fleet_absorb && self.domain == Domain::Log && self.stab.hybrid_enabled()
    }

    /// Whether the slice-streaming exchange is active
    /// (`--stream-exchange`): folds peer slices into the pending block
    /// product as frames land. Disabled under fleet absorption — the
    /// coordinator's re-absorption command must land *before* the
    /// product that consumes the exchanged state, which would
    /// invalidate partials folded against the pre-command kernel.
    pub fn stream_on(&self) -> bool {
        self.cfg.stream_exchange && !self.fleet_on() && !self.greedy_on()
    }

    /// Whether the greedy top-k exchange is active (`--exchange
    /// greedy`). Takes precedence over slice streaming: greedy frames
    /// are sparse index+value sets, not the dense slices the streamed
    /// accumulation folds.
    pub fn greedy_on(&self) -> bool {
        self.cfg.exchange == crate::config::ExchangeMode::Greedy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::runtime::make_backend;
    use crate::workload::ProblemSpec;

    /// Build a minimal [`RunCtx`] over `cfg` and read back the
    /// exchange-mode precedence flags.
    fn probe(
        cfg: &SolveConfig,
        p: &Problem,
        partition: &Partition,
        domain: Domain,
    ) -> (bool, bool) {
        let net = Arc::new(SimNet::with_wire(cfg.clients, cfg.net, cfg.seed, cfg.wire));
        let ctx = RunCtx {
            problem: p,
            partition,
            cfg,
            policy: StopPolicy::default(),
            traced: false,
            domain,
            stab: cfg.stab,
            backend: make_backend(BackendKind::Native, "", 1).unwrap(),
            net,
            delays: Arc::new(DelayTracker::new()),
        };
        (ctx.fleet_on(), ctx.stream_on())
    }

    #[test]
    fn fleet_absorb_takes_precedence_over_stream_exchange() {
        let p = ProblemSpec::new(8).with_eps(0.5).build(9);
        let mut cfg = SolveConfig {
            backend: BackendKind::Native,
            clients: 2,
            stream_exchange: true,
            ..Default::default()
        };
        cfg.stab.fleet_absorb = true;
        let partition = Partition::new_in(&p, cfg.clients, Domain::Log);
        // Both flags set in the log domain: fleet wins, streaming
        // silently defers (the CLI warns about exactly this).
        let (fleet, stream) = probe(&cfg, &p, &partition, Domain::Log);
        assert!(fleet && !stream, "fleet must suppress streaming");
        // Fleet off again: streaming is honored.
        cfg.stab.fleet_absorb = false;
        let (fleet, stream) = probe(&cfg, &p, &partition, Domain::Log);
        assert!(!fleet && stream);
        // Fleet requested but the hybrid disabled (τ = ∞): there is no
        // absorption schedule to synchronize, so streaming stays on.
        cfg.stab.fleet_absorb = true;
        cfg.stab.absorb_threshold = f64::INFINITY;
        let (fleet, stream) = probe(&cfg, &p, &partition, Domain::Log);
        assert!(!fleet && stream);
    }
}
