//! Gossip topology: asynchronous Federated Sinkhorn by seeded push
//! dissemination over the lossy fabric.
//!
//! Each iteration a node picks ONE peer — [`gossip_peer`] is pure in
//! `(seed, iter, rank)`, so the schedule replays identically at every
//! thread count — and pushes its entire stamped view: a payload of
//! `c` per-slice freshness stamps (stamp\[j\] = the iteration at which
//! owner `j` produced the slice this view holds) followed by the full
//! n×N state. The receiver merges slice-by-slice, keeping whichever
//! copy carries the newer stamp, so information spreads epidemically:
//! O(log c) expected rounds to full coverage instead of the ring's
//! deterministic c−1 hops or All-to-All's c−1 messages per round.
//! Per half-iteration each node sends exactly one frame: `α +
//! β·B·(n·N + c)` — constant message *count* per node, the cheapest α
//! regime of all four exchange graphs, paid for with staleness.
//!
//! Views ride the latest-wins delivery class (a dropped push is
//! superseded by the next; the delta codec re-keys on loss). Stamps
//! travel as floats and are `.round()`ed on merge — same convention as
//! the fleet seq lane — so lossy wire formats only carry quantization
//! noise ≪ 0.5 into the integer stamp.
//!
//! **Bounded staleness.** Prop. 2's bounded-delay assumption is
//! enforced per *slice*: a node that has outrun any live owner's stamp
//! by more than `cfg.max_staleness` blocks until fresher state arrives.
//! While blocked it keeps re-pushing its own stamped view round-robin
//! (targets rotate through every peer) — a frozen push graph could
//! disconnect and livelock the gate; round-robin re-pushes guarantee
//! every peer hears from a blocked node within c−1 spins. The spin
//! count is wall-clock-dependent (like all async scheduling); only the
//! main k-indexed peer schedule is replay-deterministic.
//!
//! Stopping mirrors the async All-to-All: independent block-error
//! estimate ×c, done votes on the reliable control path, then the
//! engine's final consistent exchange assembles identical state
//! everywhere. Fleet absorption is not routed over gossip (there is no
//! rank-0 probe path on a randomized graph); requesting both warns and
//! runs with per-node emergency absorption only.
//!
//! **Greedy on gossip is compute-local.** `--exchange greedy` runs the
//! operators' incremental top-k schedule — damping only the
//! most-violated rows, with adopted owners' slices and own selected
//! rows feeding the incremental refresh — but the wire payload stays
//! the full stamped view: the merge rule adopts whole per-owner slices
//! by stamp, which is incompatible with sparse coordinate frames (a
//! partial slice under a newer stamp would clobber rows it does not
//! carry). Greedy here buys update compute, not gossip bytes.

use super::engine::{finish_consistent, merge_rows, write_block};
use super::outcome::{NodeOutcome, NodeStats, TracePoint};
use super::RunCtx;
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{Endpoint, TagKind};
use crate::rng::splitmix64;
use crate::runtime::{GreedyStats, StabStats, Target};
use crate::sinkhorn::StopReason;
use std::time::Instant;

/// One tag per kind for the whole run (doubles as the coded-stream id,
/// like the async protocol).
const GOSSIP_TAG: u64 = 0;
/// Control tag announcing "this node stopped".
const DONE_TAG: u64 = 1;

/// The push target for `rank` at iteration `iter`: uniform over the
/// other `c−1` nodes, pure in `(seed, iter, rank)` — no RNG state, no
/// wall clock — so any two runs with the same seed walk the same push
/// schedule regardless of thread interleaving.
pub fn gossip_peer(seed: u64, iter: u64, rank: usize, c: usize) -> usize {
    debug_assert!(c > 1, "gossip needs at least two nodes");
    let mut s = seed
        .wrapping_add(iter.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((rank as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
    let r = splitmix64(&mut s);
    let pick = (r % (c as u64 - 1)) as usize;
    // Skip self: map picks at or past our own rank up by one.
    if pick >= rank {
        pick + 1
    } else {
        pick
    }
}

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| client(ctx, id))
}

/// Stamped view of one scaling matrix: the full state plus, per owner,
/// the iteration its slice was produced at.
struct View {
    full: Mat,
    stamps: Vec<u64>,
    /// Wall-clock instant each owner's stamp last *advanced* — the
    /// liveness evidence behind the death budget (a crashed owner's
    /// stamp freezes fleet-wide).
    heard: Vec<Instant>,
}

impl View {
    fn new(n: usize, nh: usize, c: usize, one: f64) -> Self {
        Self {
            full: Mat::full(n, nh, one),
            stamps: vec![0; c],
            heard: vec![Instant::now(); c],
        }
    }

    /// The wire payload: `c` stamps then the flattened state.
    fn payload(&self) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.stamps.len() + self.full.as_slice().len());
        p.extend(self.stamps.iter().map(|&s| s as f64));
        p.extend_from_slice(self.full.as_slice());
        p
    }

    /// Merge a received stamped view slice-by-slice: adopt owner `j`'s
    /// rows iff the incoming stamp is strictly newer. Returns whether
    /// anything merged fresh. Adopted owners' full row ranges are
    /// merged into `changed` (when tracking is armed) — the adoption is
    /// whole-slice, so the conservative changed set is every row of it.
    #[allow(clippy::too_many_arguments)]
    fn merge(
        &mut self,
        payload: &[f64],
        m: usize,
        c: usize,
        k64: u64,
        ctx: &RunCtx<'_>,
        changed: &mut Option<Vec<u32>>,
    ) -> bool {
        let nh = self.full.cols();
        if payload.len() != c + self.full.as_slice().len() {
            return false; // malformed frame — latest-wins traffic, just skip
        }
        let mut fresh = false;
        for j in 0..c {
            // Stamps ride a possibly-lossy wire format: round off the
            // quantization noise (≪ 0.5, the fleet seq-lane convention).
            let stamp = payload[j].round().max(0.0) as u64;
            if stamp > self.stamps[j] {
                self.stamps[j] = stamp;
                self.heard[j] = Instant::now();
                ctx.delays.record(stamp, k64);
                let rows = &payload[c + j * m * nh..c + (j + 1) * m * nh];
                write_block(&mut self.full, rows, j, m);
                if let Some(ch) = changed.as_mut() {
                    ch.extend((j * m) as u32..((j + 1) * m) as u32);
                }
                fresh = true;
            }
        }
        if fresh {
            if let Some(ch) = changed.as_mut() {
                ch.sort_unstable();
                ch.dedup();
            }
        }
        fresh
    }
}

fn client(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let c = ctx.cfg.clients;
    let alpha = ctx.cfg.alpha;
    let seed = ctx.cfg.seed;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    if id == 0 && ctx.fleet_on() {
        eprintln!(
            "warning: fleet absorption is not routed over the gossip topology \
             (no coordinator path on a randomized push graph); relying on \
             per-node emergency absorption"
        );
    }

    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    let mut u_view = View::new(n, nh, c, one);
    let mut v_view = View::new(n, nh, c, one);
    let mut done = vec![false; c];
    let mut dead = vec![false; c];

    // Greedy bookkeeping (`--exchange greedy`, compute-local here — see
    // the module docs): rows of each view that moved since the
    // corresponding operator's last incremental refresh. `None` = the
    // op has not run yet and pays one full refresh.
    let greedy = ctx.greedy_on();
    let spec = ctx.cfg.greedy_topk;
    let mut gstats = GreedyStats::default();
    let mut changed_u: Option<Vec<u32>> = None;
    let mut changed_v: Option<Vec<u32>> = None;
    if greedy {
        assert!(
            u_op.supports_greedy() && v_op.supports_greedy(),
            "--exchange greedy needs operators with greedy support (use --backend native)"
        );
    }

    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;

    for k in 1..=ctx.policy.max_iters {
        // Crash injection: exit cleanly at an iteration boundary — no
        // done vote, no final exchange; peers watch our stamp freeze and
        // fold us into the done set through the death budget.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break;
        }
        iterations = k;
        let k64 = k as u64;

        // Drain every peer's freshest pushes and done votes, then
        // enforce the per-slice staleness bound. Under
        // `--srtt-staleness` the bound scales with the hottest measured
        // incoming link — stamps relay over arbitrary paths, so the
        // slowest link into this node is the per-owner worst case.
        timer.comm(|| {
            let srtt_max = (0..c)
                .filter(|&p| p != id)
                .map(|p| ctx.net.link_rtt(p, id).srtt)
                .fold(0.0, f64::max);
            let bound = ctx.cfg.staleness_bound_for(srtt_max);
            let mut seen = ep.inbox_seq();
            drain(
                &ep,
                ctx,
                id,
                c,
                m,
                k64,
                &mut u_view,
                &mut v_view,
                &mut done,
                &mut changed_u,
                &mut changed_v,
            );
            let mut spins: usize = 0;
            loop {
                let lagging = (0..c).any(|j| {
                    j != id
                        && !done[j]
                        && (k64.saturating_sub(u_view.stamps[j]) > bound
                            || k64.saturating_sub(v_view.stamps[j]) > bound)
                });
                if !lagging || c == 1 {
                    break;
                }
                if resilient {
                    // A lagging owner whose stamp has been frozen past
                    // the death budget has crashed: fold it into the
                    // done set so the gate releases, and note the loss.
                    for j in 0..c {
                        if j != id
                            && !done[j]
                            && (k64.saturating_sub(u_view.stamps[j]) > bound
                                || k64.saturating_sub(v_view.stamps[j]) > bound)
                            && u_view.heard[j].elapsed().as_secs_f64() >= recovery.death_secs()
                            && v_view.heard[j].elapsed().as_secs_f64() >= recovery.death_secs()
                        {
                            done[j] = true;
                            dead[j] = true;
                        }
                    }
                }
                // Re-push our stamped views round-robin while blocked: a
                // frozen push graph could disconnect (everyone blocked,
                // nobody's chosen target is anyone's missing source);
                // rotating targets reaches every peer within c−1 spins,
                // so some stamp somewhere always advances.
                let target = (id + 1 + (spins % (c - 1))) % c;
                if !dead[target] {
                    ep.send_coded_latest(
                        target,
                        TagKind::U,
                        GOSSIP_TAG,
                        GOSSIP_TAG,
                        u_view.payload(),
                        k64,
                    );
                    ep.send_coded_latest(
                        target,
                        TagKind::V,
                        GOSSIP_TAG,
                        GOSSIP_TAG,
                        v_view.payload(),
                        k64,
                    );
                }
                spins += 1;
                seen = ep.wait_traffic(seen, std::time::Duration::from_millis(1));
                drain(
                    &ep,
                    ctx,
                    id,
                    c,
                    m,
                    k64,
                    &mut u_view,
                    &mut v_view,
                    &mut done,
                    &mut changed_u,
                    &mut changed_v,
                );
            }
        });

        // Marginal error of the *current* state against the freshest v
        // view (pre-update, as everywhere else: post-update the block
        // error is identically zero at α = 1).
        let pre_err = if ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_view.full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            Some(local)
        } else {
            None
        };

        // u_jj = α a_j/(K_j v) + (1−α) u_jj; stamp, then push the whole
        // stamped view to this iteration's seeded peer. One frame per
        // half-iteration — the peer relays our slice onward for us.
        // Greedy damps only the top-k violated rows, but the push and
        // the stamp still cover the whole slice (the untouched rows are
        // simply unchanged values).
        let u_jj = if greedy {
            let o =
                timer.comp(|| u_op.greedy_update(&v_view.full, alpha, spec, changed_v.as_deref()));
            changed_v = Some(Vec::new());
            gstats.record(&o, m);
            if let Some(ch) = changed_u.as_mut() {
                let own: Vec<u32> = o.rows.iter().map(|&r| shard.r0 as u32 + r).collect();
                merge_rows(ch, &own);
            }
            u_op.state().clone()
        } else {
            timer.comp(|| u_op.update(&v_view.full, alpha).clone())
        };
        write_block(&mut u_view.full, u_jj.as_slice(), id, m);
        u_view.stamps[id] = k64;
        let peer = if c > 1 { gossip_peer(seed, k64, id, c) } else { id };
        if c > 1 && !dead[peer] {
            timer.comm(|| {
                ep.send_coded_latest(
                    peer,
                    TagKind::U,
                    GOSSIP_TAG,
                    GOSSIP_TAG,
                    u_view.payload(),
                    k64,
                )
            });
        }

        // v_jj = α b_j/(K_jᵀ u) + (1−α) v_jj, stamped + pushed to the
        // same peer (one seeded choice per iteration).
        let v_jj = if greedy {
            let o =
                timer.comp(|| v_op.greedy_update(&u_view.full, alpha, spec, changed_u.as_deref()));
            changed_u = Some(Vec::new());
            gstats.record(&o, m);
            if let Some(ch) = changed_v.as_mut() {
                let own: Vec<u32> = o.rows.iter().map(|&r| shard.r0 as u32 + r).collect();
                merge_rows(ch, &own);
            }
            v_op.state().clone()
        } else {
            timer.comp(|| v_op.update(&u_view.full, alpha).clone())
        };
        write_block(&mut v_view.full, v_jj.as_slice(), id, m);
        v_view.stamps[id] = k64;
        if c > 1 && !dead[peer] {
            timer.comm(|| {
                ep.send_coded_latest(
                    peer,
                    TagKind::V,
                    GOSSIP_TAG,
                    GOSSIP_TAG,
                    v_view.payload(),
                    k64,
                )
            });
        }

        // Dequantizing the frames this iteration consumed is receiver
        // CPU work.
        timer.add_comp(ep.take_decode_secs());

        // Independent convergence estimate, ×c like the async protocol.
        if let Some(local) = pre_err {
            let est = local * c as f64;
            final_err = est;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err: est });
            }
            if est < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
        }
        if ctx.policy.timeout_secs > 0.0 && clock.now() > ctx.policy.timeout_secs {
            stop = StopReason::Timeout;
            break;
        }
    }

    let u_fin = u_op.state().clone();
    let v_fin = v_op.state().clone();
    if stop != StopReason::Dead {
        finish_consistent(
            &ep,
            DONE_TAG,
            &u_fin,
            &v_fin,
            iterations,
            resilient,
            &recovery,
            &mut dead,
            &mut timer,
        );
    }

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            greedy: if greedy { Some(gstats) } else { None },
            lost_peers: dead
                .iter()
                .enumerate()
                .filter_map(|(p, &d)| d.then_some(p))
                .collect(),
        },
        slices: Some((u_fin, v_fin)),
        trace,
    }
}

/// Drain the freshest stamped view from every peer (both kinds) plus
/// done votes. Any peer's push may carry third-party slices newer than
/// what we hold — that relay is the whole point of the epidemic.
#[allow(clippy::too_many_arguments)]
fn drain(
    ep: &Endpoint,
    ctx: &RunCtx<'_>,
    id: usize,
    c: usize,
    m: usize,
    k64: u64,
    u_view: &mut View,
    v_view: &mut View,
    done: &mut [bool],
    changed_u: &mut Option<Vec<u32>>,
    changed_v: &mut Option<Vec<u32>>,
) {
    for peer in 0..c {
        if peer == id {
            continue;
        }
        if let Some(msg) = ep.try_recv_latest(peer, TagKind::U, GOSSIP_TAG) {
            u_view.merge(&msg.payload, m, c, k64, ctx, changed_u);
        }
        if let Some(msg) = ep.try_recv_latest(peer, TagKind::V, GOSSIP_TAG) {
            v_view.merge(&msg.payload, m, c, k64, ctx, changed_v);
        }
        if ep.try_recv_latest(peer, TagKind::Ctl, DONE_TAG).is_some() {
            done[peer] = true;
        }
    }
}
