//! Fleet-synchronized absorption — the coordination layer of the
//! absorption-hybrid engine (ROADMAP "Distributed shared-support
//! reuse"; pairs Schmitzer's absorption schedule, PAPERS.md 1610.06519,
//! with the shared-structure reuse of the greedy/stochastic scaling
//! variants, 1803.01347).
//!
//! Without it, every federated node's hybrid operator re-absorbs its
//! shard kernel on its own clock: the one `O(m·n)` re-truncation the
//! engine amortizes is decided `c` times, out of lock-step, and shard
//! supports drift apart. With it, the coordinator (rank 0 in the
//! all-to-all protocols, the server in the star topology) merges
//! slice-local drift probes, decides re-absorption **centrally**, and
//! broadcasts one reference dual `ḡ` — every node then performs the
//! same partial `O(nnz)` reference move or full re-truncation against
//! the same reference, in lock-step, so supports stay mutually
//! consistent and the rebuild is one fleet decision.
//!
//! Wire format (all on [`crate::net::TagKind::Gref`], priced by the
//! same α–β latency model as the scaling exchange — and riding the same
//! `--wire-format` codec, so probe/command payloads compress with the
//! scaling slices; quantizing `ḡ` is safe because absorption is an
//! exact re-parameterization for *any* reference, it only perturbs when
//! rebuilds trigger):
//!
//! * **probe** (node → coordinator, slice-aligned):
//!   `[seq, covered, spread, drift[0..N], ḡ_slice[0..m]]` — the node's
//!   per-histogram drift and column-mean reference candidate over the
//!   `m` state rows it already owns. A node whose operator has no live
//!   absorbed kernel sends the short *degraded* form `[seq, −1]`, which
//!   pauses fleet decisions (the emergency guard inside each operator
//!   keeps correctness).
//! * **command** (coordinator → nodes): `[seq, 1, needed, ḡ[0..n]]`,
//!   or the hold `[seq, 0]` in the lock-step variant where every round
//!   must carry a reply.
//!
//! `seq` counts issued commands: probes measured against a superseded
//! reference (async arrivals) are identified and dropped by the
//! coordinator, and a node never applies the same command twice.

use crate::linalg::Mat;
use crate::runtime::{BlockOp, FleetProbe};

/// Probe payload header length: `[seq, covered, spread]`.
pub const PROBE_HEADER: usize = 3;

/// Encode a slice probe: `[seq, covered, spread, drift[N], ḡ_slice[m]]`.
///
/// **The seq lane is a filter, not an input.** Only the *async*
/// coordinator consumes it — to drop probes measured against a
/// superseded reference before they reach [`decide`]. The lock-step
/// sync paths (`sync_a2a`, and the degraded substitution the resilient
/// gather makes for a dead node) hardcode `seq = 0` because their
/// gather/broadcast rounds already order frames; [`decide`] itself
/// never reads the lane, so the two framings can share one decoder and
/// the retransmit layer cannot confuse them. Pinned by
/// `decide_ignores_the_seq_lane` below.
pub fn probe_payload(seq: u64, probe: &FleetProbe) -> Vec<f64> {
    let mut out = Vec::with_capacity(PROBE_HEADER + probe.drift.len() + probe.gref_slice.len());
    out.push(seq as f64);
    out.push(probe.covered);
    out.push(probe.spread);
    out.extend_from_slice(&probe.drift);
    out.extend_from_slice(&probe.gref_slice);
    out
}

/// The "no live absorbed kernel on this node" probe. Its short length
/// is the marker: [`decide`] holds off on any round that contains one,
/// so a degraded node quietly pauses fleet decisions instead of
/// receiving commands it cannot obey. As with [`probe_payload`], the
/// seq lane is only a staleness filter for the async coordinator; the
/// sync paths pass `0` and [`decide`] ignores it (the length alone
/// carries the hold signal).
pub fn degraded_payload(seq: u64) -> Vec<f64> {
    vec![seq as f64, -1.0]
}

/// A fleet re-absorption decision: the capacity the rebuilt supports
/// must cover and the assembled full-length reference dual.
#[derive(Clone, Debug)]
pub struct FleetCommand {
    pub needed: f64,
    pub gref: Vec<f64>,
}

/// Merge node-ordered slice probes (each from [`probe_payload`], `m`
/// state rows and `nh` histograms per node) and decide whether the
/// fleet re-absorbs now.
///
/// Mirrors the hybrid operator's internal schedule exactly: trigger
/// when any histogram's merged drift exceeds the (minimum) covered
/// capacity; the new capacity is the merged inter-histogram spread plus
/// the drift budget `τ`. Per-slice column means concatenate into the
/// full reference, and per-slice spread maxima merge into the exact
/// full-input spread, because both are per-row quantities — so the
/// central decision equals the decision a single node would make from
/// the full state, at `O(m·N)` probe cost per node.
///
/// Returns `None` when no re-absorption is due, or when any probe is
/// degraded/malformed (the hold state).
pub fn decide(parts: &[&[f64]], nh: usize, m: usize, tau: f64) -> Option<FleetCommand> {
    let expect = PROBE_HEADER + nh + m;
    let mut covered = f64::INFINITY;
    let mut spread: f64 = 0.0;
    let mut drift_max = vec![0.0; nh];
    let mut gref = Vec::with_capacity(parts.len() * m);
    for part in parts {
        if part.len() != expect {
            return None;
        }
        covered = covered.min(part[1]);
        spread = spread.max(part[2]);
        for (d, &p) in drift_max.iter_mut().zip(&part[PROBE_HEADER..PROBE_HEADER + nh]) {
            if p > *d {
                *d = p;
            }
        }
        gref.extend_from_slice(&part[PROBE_HEADER + nh..]);
    }
    if parts.is_empty() || drift_max.iter().all(|&d| d <= covered) {
        return None;
    }
    Some(FleetCommand { needed: spread + tau, gref })
}

/// Encode a command broadcast: `[seq, 1, needed, ḡ[n]]`.
pub fn command_payload(seq: u64, cmd: &FleetCommand) -> Vec<f64> {
    let mut out = Vec::with_capacity(3 + cmd.gref.len());
    out.push(seq as f64);
    out.push(1.0);
    out.push(cmd.needed);
    out.extend_from_slice(&cmd.gref);
    out
}

/// The lock-step "no re-absorption this round" reply.
pub fn hold_payload(seq: u64) -> Vec<f64> {
    vec![seq as f64, 0.0]
}

/// Decode a command broadcast: `(seq, Some((needed, ḡ)))` for an absorb
/// command, `(seq, None)` for a hold. Robust to a lossy wire format:
/// the integer lanes (seq, absorb flag) may carry quantization noise
/// well under 0.5, so they are decoded by rounding — a plain `as u64`
/// truncation would read 6.9999 as 6 and re-apply a stale command.
pub fn parse_command(payload: &[f64]) -> (u64, Option<(f64, &[f64])>) {
    let seq = payload.first().copied().unwrap_or(0.0).round() as u64;
    if payload.len() > 2 && payload[1] > 0.5 {
        (seq, Some((payload[2], &payload[3..])))
    } else {
        (seq, None)
    }
}

/// The star topology's degenerate fleet round: the coordinator owns the
/// kernel, so probe → merge → decide → apply happens locally and the
/// `Gref` broadcast carries zero messages (its α–β term vanishes — see
/// the README cost table). Runs the *same* decision logic as the wire
/// protocol so the fleet counters stay comparable across topologies.
/// Returns whether an absorb command was applied.
pub fn local_decide_apply(op: &mut dyn BlockOp, x: &Mat, tau: f64) -> bool {
    let Some(probe) = op.fleet_probe(x, 0, x.rows()) else {
        return false;
    };
    let nh = probe.drift.len();
    let payload = probe_payload(0, &probe);
    let Some(cmd) = decide(&[&payload], nh, x.rows(), tau) else {
        return false;
    };
    op.fleet_absorb(&cmd.gref, cmd.needed);
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(covered: f64, spread: f64, drift: Vec<f64>, gref_slice: Vec<f64>) -> FleetProbe {
        FleetProbe { drift, spread, gref_slice, covered }
    }

    #[test]
    fn payload_roundtrip() {
        let p = probe(15.0, 2.5, vec![1.0, 3.0], vec![0.5, -0.5, 0.25]);
        let pay = probe_payload(7, &p);
        assert_eq!(pay.len(), PROBE_HEADER + 2 + 3);
        assert_eq!(pay[0] as u64, 7);
        let cmd = FleetCommand { needed: 9.0, gref: vec![1.0, 2.0] };
        let enc = command_payload(3, &cmd);
        let (seq, parsed) = parse_command(&enc);
        assert_eq!(seq, 3);
        let (needed, gref) = parsed.unwrap();
        assert_eq!(needed, 9.0);
        assert_eq!(gref, &[1.0, 2.0]);
        let (seq, parsed) = parse_command(&hold_payload(4));
        assert_eq!(seq, 4);
        assert!(parsed.is_none());
    }

    #[test]
    fn decide_merges_slices_like_a_full_scan() {
        let tau = 5.0;
        // Two nodes, 2 histograms, 2 rows each. Node 1's hist-0 drift
        // exceeds the (min) covered capacity → absorb with capacity
        // max-spread + τ and the concatenated reference.
        let a = probe_payload(0, &probe(10.0, 1.0, vec![2.0, 3.0], vec![0.1, 0.2]));
        let b = probe_payload(0, &probe(12.0, 4.0, vec![11.0, 0.5], vec![0.3, 0.4]));
        let cmd = decide(&[&a, &b], 2, 2, tau).expect("drift 11 > covered 10");
        assert_eq!(cmd.needed, 4.0 + tau);
        assert_eq!(cmd.gref, vec![0.1, 0.2, 0.3, 0.4]);
        // Below capacity everywhere → hold.
        let c = probe_payload(0, &probe(12.0, 4.0, vec![9.0, 0.5], vec![0.3, 0.4]));
        assert!(decide(&[&a, &c], 2, 2, tau).is_none());
        // Any degraded probe pauses decisions.
        let d = degraded_payload(0);
        assert!(decide(&[&a, &d], 2, 2, tau).is_none());
        assert!(decide(&[], 2, 2, tau).is_none());
    }

    #[test]
    fn decide_ignores_the_seq_lane() {
        // The sync-path contract: gather/broadcast rounds already order
        // frames, so sync coordinators stamp every probe (and the
        // degraded substitute for a dead node) with seq 0 while the
        // async path threads real command seqs through the same
        // encoding. `decide` must produce the identical command either
        // way — the seq lane is consumed only by the async coordinator's
        // staleness filter, never by the decision.
        let tau = 5.0;
        let p0 = probe(10.0, 1.0, vec![2.0, 3.0], vec![0.1, 0.2]);
        let p1 = probe(12.0, 4.0, vec![11.0, 0.5], vec![0.3, 0.4]);
        for seqs in [[0u64, 0u64], [7, 3], [u32::MAX as u64, 1]] {
            let a = probe_payload(seqs[0], &p0);
            let b = probe_payload(seqs[1], &p1);
            let cmd = decide(&[&a, &b], 2, 2, tau).expect("drift 11 > covered 10");
            assert_eq!(cmd.needed, 4.0 + tau);
            assert_eq!(cmd.gref, vec![0.1, 0.2, 0.3, 0.4]);
            // The degraded hold is seq-independent too.
            let d = degraded_payload(seqs[1]);
            assert!(decide(&[&a, &d], 2, 2, tau).is_none());
        }
    }
}
