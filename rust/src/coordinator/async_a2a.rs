//! Algorithm 2 — Asynchronous Federated Sinkhorn, All-to-All.
//!
//! No global lock-step: each client free-runs its damped update loop,
//! broadcasting its fresh slices (`Isend`) and folding in whatever peer
//! slices have *arrived* (latest-wins inconsistent read). Staleness per
//! received message (τ = receiver's local iteration − sender's iteration
//! at send time) feeds the shared [`crate::net::DelayTracker`] — the
//! data behind the paper's Figs 15–17 and Table V.
//!
//! **Bounded delay.** The convergence guarantee (Prop. 2, via the ARock
//! framework) assumes message delays are bounded. On a cluster the
//! roughly-equal per-node work enforces that naturally; with in-process
//! threads a node can be scheduled thousands of iterations ahead, so we
//! make the bound explicit: a node that has not heard from a live peer
//! for `cfg.max_staleness` of its own iterations waits for traffic
//! before proceeding. Nodes that stop announce it (control broadcast)
//! and are exempted.
//!
//! Stopping (paper §II-A2): each node meets its convergence criterion
//! independently — its *block* marginal error scaled ×c as the global
//! estimate — or gives up at the iteration cap / timeout. A final
//! consistent exchange then assembles identical `u`, `v` everywhere.

use super::runner::{NodeOutcome, NodeStats, RunCtx, TracePoint};
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{allgather, Endpoint, TagKind};
use crate::runtime::{StabStats, Target};
use crate::sinkhorn::StopReason;

/// The async protocol reuses one tag per kind for the whole run; rounds
/// are implicit in `sent_iter` and latest-wins reads keep only the
/// freshest slice per peer.
const ASYNC_TAG: u64 = 0;
/// Control tag announcing "this node stopped".
const DONE_TAG: u64 = 1;

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| client(ctx, id))
}

/// Tracks what we know about each peer.
struct PeerView {
    /// Freshest sender iteration seen (either kind).
    last_iter: u64,
    done: bool,
}

fn client(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let c = ctx.cfg.clients;
    let alpha = ctx.cfg.alpha;
    let bound = ctx.cfg.max_staleness.max(1);
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // Domain-generic block operators (log ops iterate log-scalings; the
    // broadcast slices below are then log-scaling slices). Stabilized
    // dispatch: log-domain nodes may run the absorption-hybrid / sparse
    // schedule without changing what goes on the wire.
    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    // Local (possibly stale) copies of the full scaling state.
    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    let mut peers: Vec<PeerView> = (0..c)
        .map(|_| PeerView { last_iter: 0, done: false })
        .collect();

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;

    for k in 1..=ctx.policy.max_iters {
        iterations = k;
        let k64 = k as u64;

        // Inconsistent reads + bounded-staleness wait.
        timer.comm(|| {
            drain(&ep, ctx, id, c, k64, &mut peers, &mut u_full, &mut v_full, m);
            // Wait for any peer we have outrun beyond the bound.
            loop {
                let lagging = (0..c).any(|p| {
                    p != id && !peers[p].done && k64.saturating_sub(peers[p].last_iter) > bound
                });
                if !lagging {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(50));
                drain(&ep, ctx, id, c, k64, &mut peers, &mut u_full, &mut v_full, m);
            }
        });

        // Marginal error of the *current* state against the freshest v
        // (before the u-update — post-update at α = 1 the block error is
        // identically zero by construction).
        let pre_err = if ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            Some(local)
        } else {
            None
        };

        // u_jj = α a_j/(K_j v) + (1−α) u_jj, then inconsistent broadcast.
        let u_jj = timer.comp(|| u_op.update(&v_full, alpha).clone());
        write_block(&mut u_full, u_jj.as_slice(), id, m);
        timer.comm(|| {
            for peer in 0..c {
                if peer != id {
                    ep.send(peer, TagKind::U, ASYNC_TAG, u_jj.as_slice().to_vec(), k64);
                }
            }
        });

        // v_jj = α b_j/(K_jᵀ u) + (1−α) v_jj, then broadcast.
        let v_jj = timer.comp(|| v_op.update(&u_full, alpha).clone());
        write_block(&mut v_full, v_jj.as_slice(), id, m);
        timer.comm(|| {
            for peer in 0..c {
                if peer != id {
                    ep.send(peer, TagKind::V, ASYNC_TAG, v_jj.as_slice().to_vec(), k64);
                }
            }
        });

        // Independent convergence check on the node's own block error,
        // scaled ×c as the global-magnitude estimate.
        if let Some(local) = pre_err {
            let est = local * c as f64;
            final_err = est;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err: est });
            }
            if est < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
        }
        if ctx.policy.timeout_secs > 0.0 && clock.now() > ctx.policy.timeout_secs {
            stop = StopReason::Timeout;
            break;
        }
    }

    // Announce we stopped, so lagging peers don't wait on us …
    for peer in 0..c {
        if peer != id {
            ep.send(peer, TagKind::Ctl, DONE_TAG, vec![1.0], iterations as u64);
        }
    }
    // … then the final consistent broadcast (paper: "a consistent
    // broadcast ensures that all nodes have the same fully updated u and
    // v").
    let u_fin = u_op.state().clone();
    let v_fin = v_op.state().clone();
    timer.comm(|| {
        let _ = allgather(&ep, TagKind::U, u64::MAX - 1, u_fin.as_slice(), iterations as u64);
        let _ = allgather(&ep, TagKind::V, u64::MAX, v_fin.as_slice(), iterations as u64);
    });

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
        },
        slices: Some((u_fin, v_fin)),
        trace,
    }
}

/// Drain every deliverable peer message: fold the freshest u/v slices
/// into the local state, record staleness, note done votes.
#[allow(clippy::too_many_arguments)]
fn drain(
    ep: &Endpoint,
    ctx: &RunCtx<'_>,
    id: usize,
    c: usize,
    k64: u64,
    peers: &mut [PeerView],
    u_full: &mut Mat,
    v_full: &mut Mat,
    m: usize,
) {
    for peer in 0..c {
        if peer == id {
            continue;
        }
        if let Some(msg) = ep.try_recv_latest(peer, TagKind::V, ASYNC_TAG) {
            ctx.delays.record(msg.sent_iter, k64);
            peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
            write_block(v_full, &msg.payload, peer, m);
        }
        if let Some(msg) = ep.try_recv_latest(peer, TagKind::U, ASYNC_TAG) {
            ctx.delays.record(msg.sent_iter, k64);
            peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
            write_block(u_full, &msg.payload, peer, m);
        }
        if ep.try_recv_latest(peer, TagKind::Ctl, DONE_TAG).is_some() {
            peers[peer].done = true;
        }
    }
}

/// Write peer `j`'s m×N flat block into the full state.
fn write_block(full: &mut Mat, block: &[f64], j: usize, m: usize) {
    let nh = full.cols();
    debug_assert_eq!(block.len(), m * nh);
    full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(block);
}
