//! Algorithm 2 — Asynchronous Federated Sinkhorn, All-to-All.
//!
//! No global lock-step: each client free-runs its damped update loop,
//! broadcasting its fresh slices (`Isend`) and folding in whatever peer
//! slices have *arrived* (latest-wins inconsistent read). Staleness per
//! received message (τ = receiver's local iteration − sender's iteration
//! at send time) feeds the shared [`crate::net::DelayTracker`] — the
//! data behind the paper's Figs 15–17 and Table V.
//!
//! **Bounded delay.** The convergence guarantee (Prop. 2, via the ARock
//! framework) assumes message delays are bounded. On a cluster the
//! roughly-equal per-node work enforces that naturally; with in-process
//! threads a node can be scheduled thousands of iterations ahead, so we
//! make the bound explicit: a node that has not heard from a live peer
//! for `cfg.max_staleness` of its own iterations waits for traffic
//! before proceeding. Nodes that stop announce it (control broadcast)
//! and are exempted.
//!
//! Stopping (paper §II-A2): each node meets its convergence criterion
//! independently — its *block* marginal error scaled ×c as the global
//! estimate — or gives up at the iteration cap / timeout. A final
//! consistent exchange ([`engine::finish_consistent`]) then assembles
//! identical `u`, `v` everywhere.
//!
//! Under `--exchange greedy` each free-running iteration damps only the
//! top-k most-violated rows (the operators' incremental
//! `greedy_update`) and broadcasts just those coordinates as sparse
//! latest-wins frames, drained oldest-first on the receive side. A
//! frame superseded in flight loses its coordinates at that receiver,
//! but the scheme self-heals: values are absolute and selection is
//! violation-driven, so any row a stale receiver still has wrong keeps
//! producing violation at the sender and is re-shipped.
//!
//! The fleet-absorption probe/command routing ([`engine::FleetCoord`],
//! [`engine::coordinate`], …) and the strike/death machinery live in
//! [`super::engine`]; this module keeps the free-running client loop.

use super::engine::{
    apply_fleet_command, coordinate, finish_consistent, merge_rows, pack_rows, scatter_sparse,
    send_fleet_probe, write_block, FleetCoord,
};
use super::outcome::{NodeOutcome, NodeStats, TracePoint};
use super::RunCtx;
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{Endpoint, TagKind};
use crate::runtime::{GreedyStats, StabStats, Target};
use crate::sinkhorn::StopReason;
use std::time::Instant;

/// The async protocol reuses one tag per kind for the whole run; rounds
/// are implicit in `sent_iter` and latest-wins reads keep only the
/// freshest slice per peer. The tag doubles as the coded-stream id:
/// tags are constant here, so `(dst, kind, tag)` is a stable stream
/// identity for the wire codec (see `crate::net::wire`). The final
/// consistent AllGather stays on the exact path so the assembled
/// outcome state is bit-true.
const ASYNC_TAG: u64 = 0;
/// Control tag announcing "this node stopped".
const DONE_TAG: u64 = 1;

/// Fleet-absorption sub-tags on [`TagKind::Gref`]: slice probes flow to
/// rank 0 (the absorption coordinator), reference-dual commands flow
/// back — one channel per product space (the u-ops' reference lives in
/// v-space and vice versa). All latest-wins, like the scaling traffic.
const FLEET_PROBE_U: u64 = 0;
const FLEET_PROBE_V: u64 = 1;
const FLEET_CMD_U: u64 = 2;
const FLEET_CMD_V: u64 = 3;

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| client(ctx, id))
}

/// Tracks what we know about each peer.
struct PeerView {
    /// Freshest sender iteration seen (either kind).
    last_iter: u64,
    done: bool,
}

fn client(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let c = ctx.cfg.clients;
    let alpha = ctx.cfg.alpha;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // Domain-generic block operators (log ops iterate log-scalings; the
    // broadcast slices below are then log-scaling slices). Stabilized
    // dispatch: log-domain nodes may run the absorption-hybrid / sparse
    // schedule without changing what goes on the wire.
    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    // Local (possibly stale) copies of the full scaling state.
    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    // Greedy bookkeeping (`--exchange greedy`): rows of the full mats
    // that have moved since the corresponding operator's last greedy
    // refresh — `changed_v` feeds the u-op (it reads `v_full`) and vice
    // versa. `None` = the op has not run yet and pays one full refresh.
    let greedy = ctx.greedy_on();
    let spec = ctx.cfg.greedy_topk;
    let mut gstats = GreedyStats::default();
    let mut changed_u: Option<Vec<u32>> = None;
    let mut changed_v: Option<Vec<u32>> = None;
    if greedy {
        assert!(
            u_op.supports_greedy() && v_op.supports_greedy(),
            "--exchange greedy needs operators with greedy support (use --backend native)"
        );
    }

    let mut peers: Vec<PeerView> = (0..c)
        .map(|_| PeerView { last_iter: 0, done: false })
        .collect();

    // Self-healing state, armed only under an active fault plan. Node
    // death folds into the existing done-vote path: a *lagging* peer
    // that has also been wall-clock silent past the recovery death
    // budget can only have crashed (reliable frames always get through,
    // and latest-wins slices flow every iteration), so it is marked
    // done-and-lost — the staleness gate releases and the final
    // consistent exchange skips it.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut dead = vec![false; c];
    let mut last_heard: Vec<Instant> = vec![Instant::now(); c];

    // Fleet-synchronized absorption (`--fleet-absorb`, log-domain hybrid
    // runs): rank 0 merges the latest slice probes and broadcasts
    // reference-dual commands; everyone else applies the freshest
    // command before using an operator. Between commands nobody
    // re-absorbs on their own — the emergency drift guard inside each
    // operator covers command latency, so correctness never depends on
    // delivery timing.
    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;
    let mut coord_u = FleetCoord::new(c);
    let mut coord_v = FleetCoord::new(c);
    let (mut applied_u, mut applied_v) = (0u64, 0u64);

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;

    for k in 1..=ctx.policy.max_iters {
        // Crash injection fires at an iteration boundary: the node
        // exits cleanly — no done vote, no final exchange — and peers
        // discover the silence through the death budget below.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break;
        }
        iterations = k;
        let k64 = k as u64;

        // Inconsistent reads + bounded-staleness wait. The arrival count
        // is read *before* each drain so a frame landing between the
        // drain and the park still wakes us immediately.
        timer.comm(|| {
            let mut seen = ep.inbox_seq();
            drain(
                &ep,
                ctx,
                id,
                c,
                k64,
                &mut peers,
                &mut u_full,
                &mut v_full,
                m,
                &mut last_heard,
                &mut changed_u,
                &mut changed_v,
            );
            // Wait for any peer we have outrun beyond the bound. The
            // bound is re-read per peer: under `--srtt-staleness` it
            // scales with that link's measured round-trip, so slow
            // links widen the tolerated gap instead of stalling us.
            let bound_for =
                |p: usize| ctx.cfg.staleness_bound_for(ctx.net.link_rtt(p, id).srtt);
            loop {
                let lagging = (0..c).any(|p| {
                    p != id
                        && !peers[p].done
                        && k64.saturating_sub(peers[p].last_iter) > bound_for(p)
                });
                if !lagging {
                    break;
                }
                if resilient {
                    // A lagging peer silent past the death budget has
                    // crashed: fold it into the done votes so the gate
                    // releases, and remember the loss.
                    for p in 0..c {
                        if p != id
                            && !peers[p].done
                            && k64.saturating_sub(peers[p].last_iter) > bound_for(p)
                            && last_heard[p].elapsed().as_secs_f64() >= recovery.death_secs()
                        {
                            peers[p].done = true;
                            dead[p] = true;
                        }
                    }
                }
                // Park on the inbox until traffic moves (or a queued
                // frame matures) instead of a fixed busy-sleep.
                seen = ep.wait_traffic(seen, std::time::Duration::from_millis(1));
                drain(
                    &ep,
                    ctx,
                    id,
                    c,
                    k64,
                    &mut peers,
                    &mut u_full,
                    &mut v_full,
                    m,
                    &mut last_heard,
                    &mut changed_u,
                    &mut changed_v,
                );
            }
        });

        // Fleet absorption housekeeping on the freshest drained state:
        // rank 0 coordinates (merge probes → maybe command + absorb),
        // everyone else applies the freshest commands before the ops
        // run their products below.
        if fleet {
            if id == 0 {
                let any_done = (1..c).any(|p| peers[p].done);
                coordinate(
                    &mut coord_u,
                    &ep,
                    c,
                    FLEET_PROBE_U,
                    FLEET_CMD_U,
                    &mut *u_op,
                    &v_full,
                    m,
                    nh,
                    tau,
                    any_done,
                    k64,
                    &mut timer,
                );
                coordinate(
                    &mut coord_v,
                    &ep,
                    c,
                    FLEET_PROBE_V,
                    FLEET_CMD_V,
                    &mut *v_op,
                    &u_full,
                    m,
                    nh,
                    tau,
                    any_done,
                    k64,
                    &mut timer,
                );
            } else {
                apply_fleet_command(&ep, &mut *u_op, FLEET_CMD_U, &mut applied_u, &mut timer);
                apply_fleet_command(&ep, &mut *v_op, FLEET_CMD_V, &mut applied_v, &mut timer);
            }
        }

        // Marginal error of the *current* state against the freshest v
        // (before the u-update — post-update at α = 1 the block error is
        // identically zero by construction).
        let pre_err = if ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            Some(local)
        } else {
            None
        };

        // u_jj = α a_j/(K_j v) + (1−α) u_jj, then inconsistent broadcast.
        // Latest-wins delivery class: a dropped slice is superseded by
        // next iteration's send rather than retransmitted (the codec
        // re-keys so reconstruction never diverges) — identical to
        // `send_coded` when the fault plan is inactive. Greedy damps
        // only the top-k violated rows and ships just those coordinates.
        if greedy {
            let o = timer.comp(|| u_op.greedy_update(&v_full, alpha, spec, changed_v.as_deref()));
            changed_v = Some(Vec::new());
            gstats.record(&o, m);
            let u_jj = u_op.state().clone();
            write_block(&mut u_full, u_jj.as_slice(), id, m);
            if let Some(ch) = changed_u.as_mut() {
                let own: Vec<u32> = o.rows.iter().map(|&r| shard.r0 as u32 + r).collect();
                merge_rows(ch, &own);
            }
            let (idx, vals) = pack_rows(&u_jj, 0, &o.rows, nh);
            timer.comm(|| {
                for peer in 0..c {
                    if peer != id && !dead[peer] {
                        ep.send_sparse_coded_latest(
                            peer,
                            TagKind::SparseU,
                            ASYNC_TAG,
                            ASYNC_TAG,
                            idx.clone(),
                            vals.clone(),
                            m * nh,
                            k64,
                        );
                    }
                }
            });
        } else {
            let u_jj = timer.comp(|| u_op.update(&v_full, alpha).clone());
            write_block(&mut u_full, u_jj.as_slice(), id, m);
            timer.comm(|| {
                for peer in 0..c {
                    if peer != id && !dead[peer] {
                        ep.send_coded_latest(
                            peer,
                            TagKind::U,
                            ASYNC_TAG,
                            ASYNC_TAG,
                            u_jj.as_slice().to_vec(),
                            k64,
                        );
                    }
                }
            });
        }

        // v_jj = α b_j/(K_jᵀ u) + (1−α) v_jj, then broadcast.
        if greedy {
            let o = timer.comp(|| v_op.greedy_update(&u_full, alpha, spec, changed_u.as_deref()));
            changed_u = Some(Vec::new());
            gstats.record(&o, m);
            let v_jj = v_op.state().clone();
            write_block(&mut v_full, v_jj.as_slice(), id, m);
            if let Some(ch) = changed_v.as_mut() {
                let own: Vec<u32> = o.rows.iter().map(|&r| shard.r0 as u32 + r).collect();
                merge_rows(ch, &own);
            }
            let (idx, vals) = pack_rows(&v_jj, 0, &o.rows, nh);
            timer.comm(|| {
                for peer in 0..c {
                    if peer != id && !dead[peer] {
                        ep.send_sparse_coded_latest(
                            peer,
                            TagKind::SparseV,
                            ASYNC_TAG,
                            ASYNC_TAG,
                            idx.clone(),
                            vals.clone(),
                            m * nh,
                            k64,
                        );
                    }
                }
            });
        } else {
            let v_jj = timer.comp(|| v_op.update(&u_full, alpha).clone());
            write_block(&mut v_full, v_jj.as_slice(), id, m);
            timer.comm(|| {
                for peer in 0..c {
                    if peer != id && !dead[peer] {
                        ep.send_coded_latest(
                            peer,
                            TagKind::V,
                            ASYNC_TAG,
                            ASYNC_TAG,
                            v_jj.as_slice().to_vec(),
                            k64,
                        );
                    }
                }
            });
        }

        // Non-coordinator nodes report their freshest slice-local drift
        // to rank 0 (stamped with the last applied command seq, so the
        // coordinator never acts on drift measured against a reference
        // it has already superseded).
        if fleet && id != 0 {
            send_fleet_probe(
                &ep,
                &*v_op,
                FLEET_PROBE_V,
                &u_full,
                shard.r0,
                m,
                applied_v,
                k64,
                &mut timer,
            );
            send_fleet_probe(
                &ep,
                &*u_op,
                FLEET_PROBE_U,
                &v_full,
                shard.r0,
                m,
                applied_u,
                k64,
                &mut timer,
            );
        }

        // Dequantizing the frames this iteration consumed (latest-wins
        // drains included) is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());

        // Independent convergence check on the node's own block error,
        // scaled ×c as the global-magnitude estimate.
        if let Some(local) = pre_err {
            let est = local * c as f64;
            final_err = est;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err: est });
            }
            if est < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
        }
        if ctx.policy.timeout_secs > 0.0 && clock.now() > ctx.policy.timeout_secs {
            stop = StopReason::Timeout;
            break;
        }
    }

    let u_fin = u_op.state().clone();
    let v_fin = v_op.state().clone();
    if stop != StopReason::Dead {
        finish_consistent(
            &ep,
            DONE_TAG,
            &u_fin,
            &v_fin,
            iterations,
            resilient,
            &recovery,
            &mut dead,
            &mut timer,
        );
    }

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            greedy: if greedy { Some(gstats) } else { None },
            lost_peers: dead
                .iter()
                .enumerate()
                .filter_map(|(p, &d)| d.then_some(p))
                .collect(),
        },
        slices: Some((u_fin, v_fin)),
        trace,
    }
}

/// Drain every deliverable peer message: fold the freshest u/v slices
/// into the local state, record staleness, note done votes, and stamp
/// `heard` (the wall-clock liveness evidence behind the death budget).
///
/// Under greedy the slices arrive as sparse coordinate frames; every
/// deliverable frame is drained oldest-first and scattered (each
/// carries a different coordinate set, so "latest" alone is not enough)
/// with the touched rows merged into the `changed_*` accumulators the
/// operators' incremental refresh consumes.
#[allow(clippy::too_many_arguments)]
fn drain(
    ep: &Endpoint,
    ctx: &RunCtx<'_>,
    id: usize,
    c: usize,
    k64: u64,
    peers: &mut [PeerView],
    u_full: &mut Mat,
    v_full: &mut Mat,
    m: usize,
    heard: &mut [Instant],
    changed_u: &mut Option<Vec<u32>>,
    changed_v: &mut Option<Vec<u32>>,
) {
    let greedy = ctx.greedy_on();
    for peer in 0..c {
        if peer == id {
            continue;
        }
        if greedy {
            for msg in ep.try_recv_all(peer, TagKind::SparseV, ASYNC_TAG) {
                ctx.delays.record(msg.sent_iter, k64);
                peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
                scatter_sparse(v_full, peer * m, &msg.indices, &msg.payload, changed_v);
                heard[peer] = Instant::now();
            }
            for msg in ep.try_recv_all(peer, TagKind::SparseU, ASYNC_TAG) {
                ctx.delays.record(msg.sent_iter, k64);
                peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
                scatter_sparse(u_full, peer * m, &msg.indices, &msg.payload, changed_u);
                heard[peer] = Instant::now();
            }
        } else {
            if let Some(msg) = ep.try_recv_latest(peer, TagKind::V, ASYNC_TAG) {
                ctx.delays.record(msg.sent_iter, k64);
                peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
                write_block(v_full, &msg.payload, peer, m);
                heard[peer] = Instant::now();
            }
            if let Some(msg) = ep.try_recv_latest(peer, TagKind::U, ASYNC_TAG) {
                ctx.delays.record(msg.sent_iter, k64);
                peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
                write_block(u_full, &msg.payload, peer, m);
                heard[peer] = Instant::now();
            }
        }
        if ep.try_recv_latest(peer, TagKind::Ctl, DONE_TAG).is_some() {
            peers[peer].done = true;
            heard[peer] = Instant::now();
        }
    }
}
