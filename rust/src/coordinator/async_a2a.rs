//! Algorithm 2 — Asynchronous Federated Sinkhorn, All-to-All.
//!
//! No global lock-step: each client free-runs its damped update loop,
//! broadcasting its fresh slices (`Isend`) and folding in whatever peer
//! slices have *arrived* (latest-wins inconsistent read). Staleness per
//! received message (τ = receiver's local iteration − sender's iteration
//! at send time) feeds the shared [`crate::net::DelayTracker`] — the
//! data behind the paper's Figs 15–17 and Table V.
//!
//! **Bounded delay.** The convergence guarantee (Prop. 2, via the ARock
//! framework) assumes message delays are bounded. On a cluster the
//! roughly-equal per-node work enforces that naturally; with in-process
//! threads a node can be scheduled thousands of iterations ahead, so we
//! make the bound explicit: a node that has not heard from a live peer
//! for `cfg.max_staleness` of its own iterations waits for traffic
//! before proceeding. Nodes that stop announce it (control broadcast)
//! and are exempted.
//!
//! Stopping (paper §II-A2): each node meets its convergence criterion
//! independently — its *block* marginal error scaled ×c as the global
//! estimate — or gives up at the iteration cap / timeout. A final
//! consistent exchange then assembles identical `u`, `v` everywhere.

use super::fleet;
use super::runner::{NodeOutcome, NodeStats, RunCtx, TracePoint};
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{allgather, allgather_resilient, Endpoint, Recovery, TagKind};
use crate::runtime::{BlockOp, StabStats, Target};
use crate::sinkhorn::StopReason;
use std::time::Instant;

/// The async protocol reuses one tag per kind for the whole run; rounds
/// are implicit in `sent_iter` and latest-wins reads keep only the
/// freshest slice per peer. The tag doubles as the coded-stream id:
/// tags are constant here, so `(dst, kind, tag)` is a stable stream
/// identity for the wire codec (see `crate::net::wire`). The final
/// consistent AllGather stays on the exact path so the assembled
/// outcome state is bit-true.
const ASYNC_TAG: u64 = 0;
/// Control tag announcing "this node stopped".
const DONE_TAG: u64 = 1;

/// Fleet-absorption sub-tags on [`TagKind::Gref`]: slice probes flow to
/// rank 0 (the absorption coordinator), reference-dual commands flow
/// back — one channel per product space (the u-ops' reference lives in
/// v-space and vice versa). All latest-wins, like the scaling traffic.
const FLEET_PROBE_U: u64 = 0;
const FLEET_PROBE_V: u64 = 1;
const FLEET_CMD_U: u64 = 2;
const FLEET_CMD_V: u64 = 3;

/// Rank 0's per-channel fleet-coordination state.
struct FleetCoord {
    /// Latest probe payload per node (rank 0's own at index 0).
    probes: Vec<Option<Vec<f64>>>,
    /// Issued-command count. A probe stamped with an older seq measured
    /// drift against a superseded reference and is held back until the
    /// node reports post-command state — this is what prevents a
    /// command storm from stale probes racing the broadcast.
    seq: u64,
}

impl FleetCoord {
    fn new(c: usize) -> Self {
        Self { probes: vec![None; c], seq: 0 }
    }
}

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| client(ctx, id))
}

/// Tracks what we know about each peer.
struct PeerView {
    /// Freshest sender iteration seen (either kind).
    last_iter: u64,
    done: bool,
}

fn client(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let c = ctx.cfg.clients;
    let alpha = ctx.cfg.alpha;
    let bound = ctx.cfg.staleness_bound();
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // Domain-generic block operators (log ops iterate log-scalings; the
    // broadcast slices below are then log-scaling slices). Stabilized
    // dispatch: log-domain nodes may run the absorption-hybrid / sparse
    // schedule without changing what goes on the wire.
    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    // Local (possibly stale) copies of the full scaling state.
    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    let mut peers: Vec<PeerView> = (0..c)
        .map(|_| PeerView { last_iter: 0, done: false })
        .collect();

    // Self-healing state, armed only under an active fault plan. Node
    // death folds into the existing done-vote path: a *lagging* peer
    // that has also been wall-clock silent past the recovery death
    // budget can only have crashed (reliable frames always get through,
    // and latest-wins slices flow every iteration), so it is marked
    // done-and-lost — the staleness gate releases and the final
    // consistent exchange skips it.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut dead = vec![false; c];
    let mut last_heard: Vec<Instant> = vec![Instant::now(); c];

    // Fleet-synchronized absorption (`--fleet-absorb`, log-domain hybrid
    // runs): rank 0 merges the latest slice probes and broadcasts
    // reference-dual commands; everyone else applies the freshest
    // command before using an operator. Between commands nobody
    // re-absorbs on their own — the emergency drift guard inside each
    // operator covers command latency, so correctness never depends on
    // delivery timing.
    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;
    let mut coord_u = FleetCoord::new(c);
    let mut coord_v = FleetCoord::new(c);
    let (mut applied_u, mut applied_v) = (0u64, 0u64);

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;

    for k in 1..=ctx.policy.max_iters {
        // Crash injection fires at an iteration boundary: the node
        // exits cleanly — no done vote, no final exchange — and peers
        // discover the silence through the death budget below.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break;
        }
        iterations = k;
        let k64 = k as u64;

        // Inconsistent reads + bounded-staleness wait. The arrival count
        // is read *before* each drain so a frame landing between the
        // drain and the park still wakes us immediately.
        timer.comm(|| {
            let mut seen = ep.inbox_seq();
            drain(
                &ep,
                ctx,
                id,
                c,
                k64,
                &mut peers,
                &mut u_full,
                &mut v_full,
                m,
                &mut last_heard,
            );
            // Wait for any peer we have outrun beyond the bound.
            loop {
                let lagging = (0..c).any(|p| {
                    p != id && !peers[p].done && k64.saturating_sub(peers[p].last_iter) > bound
                });
                if !lagging {
                    break;
                }
                if resilient {
                    // A lagging peer silent past the death budget has
                    // crashed: fold it into the done votes so the gate
                    // releases, and remember the loss.
                    for p in 0..c {
                        if p != id
                            && !peers[p].done
                            && k64.saturating_sub(peers[p].last_iter) > bound
                            && last_heard[p].elapsed().as_secs_f64() >= recovery.death_secs()
                        {
                            peers[p].done = true;
                            dead[p] = true;
                        }
                    }
                }
                // Park on the inbox until traffic moves (or a queued
                // frame matures) instead of a fixed busy-sleep.
                seen = ep.wait_traffic(seen, std::time::Duration::from_millis(1));
                drain(
                    &ep,
                    ctx,
                    id,
                    c,
                    k64,
                    &mut peers,
                    &mut u_full,
                    &mut v_full,
                    m,
                    &mut last_heard,
                );
            }
        });

        // Fleet absorption housekeeping on the freshest drained state:
        // rank 0 coordinates (merge probes → maybe command + absorb),
        // everyone else applies the freshest commands before the ops
        // run their products below.
        if fleet {
            if id == 0 {
                let any_done = (1..c).any(|p| peers[p].done);
                coordinate(
                    &mut coord_u,
                    &ep,
                    c,
                    FLEET_PROBE_U,
                    FLEET_CMD_U,
                    &mut *u_op,
                    &v_full,
                    m,
                    nh,
                    tau,
                    any_done,
                    k64,
                    &mut timer,
                );
                coordinate(
                    &mut coord_v,
                    &ep,
                    c,
                    FLEET_PROBE_V,
                    FLEET_CMD_V,
                    &mut *v_op,
                    &u_full,
                    m,
                    nh,
                    tau,
                    any_done,
                    k64,
                    &mut timer,
                );
            } else {
                apply_fleet_command(&ep, &mut *u_op, FLEET_CMD_U, &mut applied_u, &mut timer);
                apply_fleet_command(&ep, &mut *v_op, FLEET_CMD_V, &mut applied_v, &mut timer);
            }
        }

        // Marginal error of the *current* state against the freshest v
        // (before the u-update — post-update at α = 1 the block error is
        // identically zero by construction).
        let pre_err = if ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            Some(local)
        } else {
            None
        };

        // u_jj = α a_j/(K_j v) + (1−α) u_jj, then inconsistent broadcast.
        // Latest-wins delivery class: a dropped slice is superseded by
        // next iteration's send rather than retransmitted (the codec
        // re-keys so reconstruction never diverges) — identical to
        // `send_coded` when the fault plan is inactive.
        let u_jj = timer.comp(|| u_op.update(&v_full, alpha).clone());
        write_block(&mut u_full, u_jj.as_slice(), id, m);
        timer.comm(|| {
            for peer in 0..c {
                if peer != id && !dead[peer] {
                    ep.send_coded_latest(
                        peer,
                        TagKind::U,
                        ASYNC_TAG,
                        ASYNC_TAG,
                        u_jj.as_slice().to_vec(),
                        k64,
                    );
                }
            }
        });

        // v_jj = α b_j/(K_jᵀ u) + (1−α) v_jj, then broadcast.
        let v_jj = timer.comp(|| v_op.update(&u_full, alpha).clone());
        write_block(&mut v_full, v_jj.as_slice(), id, m);
        timer.comm(|| {
            for peer in 0..c {
                if peer != id && !dead[peer] {
                    ep.send_coded_latest(
                        peer,
                        TagKind::V,
                        ASYNC_TAG,
                        ASYNC_TAG,
                        v_jj.as_slice().to_vec(),
                        k64,
                    );
                }
            }
        });

        // Non-coordinator nodes report their freshest slice-local drift
        // to rank 0 (stamped with the last applied command seq, so the
        // coordinator never acts on drift measured against a reference
        // it has already superseded).
        if fleet && id != 0 {
            send_fleet_probe(
                &ep,
                &*v_op,
                FLEET_PROBE_V,
                &u_full,
                shard.r0,
                m,
                applied_v,
                k64,
                &mut timer,
            );
            send_fleet_probe(
                &ep,
                &*u_op,
                FLEET_PROBE_U,
                &v_full,
                shard.r0,
                m,
                applied_u,
                k64,
                &mut timer,
            );
        }

        // Dequantizing the frames this iteration consumed (latest-wins
        // drains included) is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());

        // Independent convergence check on the node's own block error,
        // scaled ×c as the global-magnitude estimate.
        if let Some(local) = pre_err {
            let est = local * c as f64;
            final_err = est;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err: est });
            }
            if est < ctx.policy.threshold {
                stop = StopReason::Converged;
                break;
            }
        }
        if ctx.policy.timeout_secs > 0.0 && clock.now() > ctx.policy.timeout_secs {
            stop = StopReason::Timeout;
            break;
        }
    }

    let u_fin = u_op.state().clone();
    let v_fin = v_op.state().clone();
    if stop != StopReason::Dead {
        // Announce we stopped, so lagging peers don't wait on us …
        for peer in 0..c {
            if peer != id {
                ep.send(peer, TagKind::Ctl, DONE_TAG, vec![1.0], iterations as u64);
            }
        }
        // … then the final consistent broadcast (paper: "a consistent
        // broadcast ensures that all nodes have the same fully updated u
        // and v"). Under an active fault plan the exchange is
        // crash-tolerant: peers already declared dead are skipped, and a
        // peer that never shows up within the stretched death budget is
        // struck dead here instead of hanging the run. (The runner
        // assembles the outcome from each node's own slices, so a struck
        // peer only costs us its copy, never correctness.)
        timer.comm(|| {
            if resilient {
                let fin = Recovery {
                    recv_timeout_secs: recovery.death_secs().max(1e-3),
                    ..recovery
                };
                let mut alive: Vec<bool> = dead.iter().map(|&d| !d).collect();
                let _ = allgather_resilient(
                    &ep,
                    TagKind::U,
                    u64::MAX - 1,
                    None,
                    u_fin.as_slice(),
                    iterations as u64,
                    &mut alive,
                    &fin,
                );
                let _ = allgather_resilient(
                    &ep,
                    TagKind::V,
                    u64::MAX,
                    None,
                    v_fin.as_slice(),
                    iterations as u64,
                    &mut alive,
                    &fin,
                );
                for (p, &a) in alive.iter().enumerate() {
                    if !a {
                        dead[p] = true;
                    }
                }
            } else {
                let _ =
                    allgather(&ep, TagKind::U, u64::MAX - 1, u_fin.as_slice(), iterations as u64);
                let _ = allgather(&ep, TagKind::V, u64::MAX, v_fin.as_slice(), iterations as u64);
            }
        });
        timer.add_comp(ep.take_decode_secs());
    }

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            lost_peers: dead
                .iter()
                .enumerate()
                .filter_map(|(p, &d)| d.then_some(p))
                .collect(),
        },
        slices: Some((u_fin, v_fin)),
        trace,
    }
}

/// Drain every deliverable peer message: fold the freshest u/v slices
/// into the local state, record staleness, note done votes, and stamp
/// `heard` (the wall-clock liveness evidence behind the death budget).
#[allow(clippy::too_many_arguments)]
fn drain(
    ep: &Endpoint,
    ctx: &RunCtx<'_>,
    id: usize,
    c: usize,
    k64: u64,
    peers: &mut [PeerView],
    u_full: &mut Mat,
    v_full: &mut Mat,
    m: usize,
    heard: &mut [Instant],
) {
    for peer in 0..c {
        if peer == id {
            continue;
        }
        if let Some(msg) = ep.try_recv_latest(peer, TagKind::V, ASYNC_TAG) {
            ctx.delays.record(msg.sent_iter, k64);
            peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
            write_block(v_full, &msg.payload, peer, m);
            heard[peer] = Instant::now();
        }
        if let Some(msg) = ep.try_recv_latest(peer, TagKind::U, ASYNC_TAG) {
            ctx.delays.record(msg.sent_iter, k64);
            peers[peer].last_iter = peers[peer].last_iter.max(msg.sent_iter);
            write_block(u_full, &msg.payload, peer, m);
            heard[peer] = Instant::now();
        }
        if ep.try_recv_latest(peer, TagKind::Ctl, DONE_TAG).is_some() {
            peers[peer].done = true;
            heard[peer] = Instant::now();
        }
    }
}

/// Write peer `j`'s m×N flat block into the full state.
fn write_block(full: &mut Mat, block: &[f64], j: usize, m: usize) {
    let nh = full.cols();
    debug_assert_eq!(block.len(), m * nh);
    full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(block);
}

/// Rank 0's fleet pass for one channel: refresh its own probe, drain
/// the latest peer probes, and — once every node has reported
/// current-seq state — merge, decide, broadcast the command and obey it
/// locally. `hold` freezes decisions once any peer announced done (its
/// slice probes stop; the remaining nodes keep their emergency guard).
#[allow(clippy::too_many_arguments)]
fn coordinate(
    coord: &mut FleetCoord,
    ep: &Endpoint,
    c: usize,
    probe_tag: u64,
    cmd_tag: u64,
    op: &mut dyn BlockOp,
    x_full: &Mat,
    m: usize,
    nh: usize,
    tau: f64,
    hold: bool,
    k64: u64,
    timer: &mut SplitTimer,
) {
    let seq = coord.seq;
    coord.probes[0] = timer.comp(|| {
        op.fleet_probe(x_full, 0, m)
            .map(|p| fleet::probe_payload(seq, &p))
    });
    timer.comm(|| {
        for j in 1..c {
            if let Some(msg) = ep.try_recv_latest(j, TagKind::Gref, probe_tag) {
                coord.probes[j] = Some(msg.payload);
            }
        }
    });
    if hold {
        return;
    }
    // Full, current-seq coverage required: a missing or stale probe
    // (degraded operator, command still in flight) holds the decision.
    let mut refs: Vec<&[f64]> = Vec::with_capacity(c);
    for probe in &coord.probes {
        match probe {
            // `.round()`: probe frames may ride a lossy wire format,
            // so the integer seq lane carries quantization noise ≪ 0.5.
            Some(pay) if pay.first().copied().unwrap_or(-1.0).round() as u64 == coord.seq => {
                refs.push(pay.as_slice());
            }
            _ => return,
        }
    }
    let Some(cmd) = timer.comp(|| fleet::decide(&refs, nh, m, tau)) else {
        return;
    };
    coord.seq += 1;
    let payload = fleet::command_payload(coord.seq, &cmd);
    timer.comm(|| {
        for j in 1..c {
            ep.send_coded(j, TagKind::Gref, cmd_tag, cmd_tag, payload.clone(), k64);
        }
    });
    timer.comp(|| op.fleet_absorb(&cmd.gref, cmd.needed));
    // Stored probes measured drift against the superseded reference.
    for probe in coord.probes.iter_mut() {
        *probe = None;
    }
}

/// Apply the freshest coordinator command (if any) to `op`, tracking
/// the applied sequence so a command is never obeyed twice.
fn apply_fleet_command(
    ep: &Endpoint,
    op: &mut dyn BlockOp,
    cmd_tag: u64,
    applied: &mut u64,
    timer: &mut SplitTimer,
) {
    let msg = timer.comm(|| ep.try_recv_latest(0, TagKind::Gref, cmd_tag));
    if let Some(msg) = msg {
        let (seq, cmd) = fleet::parse_command(&msg.payload);
        if seq > *applied {
            *applied = seq;
            if let Some((needed, gref)) = cmd {
                timer.comp(|| op.fleet_absorb(gref, needed));
            }
        }
    }
}

/// Send this node's slice-local drift probe to rank 0. A degraded
/// operator (dense fallback) stops probing, which silently pauses fleet
/// decisions at the coordinator — the intended degrade path. Probes
/// ride the latest-wins delivery class: a dropped probe is superseded
/// by next iteration's, and a stalled probe channel merely holds the
/// coordinator's decision (the same hold state).
#[allow(clippy::too_many_arguments)]
fn send_fleet_probe(
    ep: &Endpoint,
    op: &dyn BlockOp,
    probe_tag: u64,
    x_full: &Mat,
    r0: usize,
    m: usize,
    seq: u64,
    k64: u64,
    timer: &mut SplitTimer,
) {
    if let Some(p) = timer.comp(|| op.fleet_probe(x_full, r0, m)) {
        let payload = fleet::probe_payload(seq, &p);
        timer.comm(|| ep.send_coded_latest(0, TagKind::Gref, probe_tag, probe_tag, payload, k64));
    }
}
