//! Ring topology: synchronous Federated Sinkhorn over a neighbor-pair
//! ring, on the same lock-step engine as All-to-All.
//!
//! Each exchange leg is a rotation AllGather: at hop `h ∈ 1..c` every
//! node forwards the slice it received `h−1` hops ago to its right
//! neighbor `(me+1) mod c` and receives the slice originating `h` hops
//! to its left — after `c−1` hops every node holds all `c` slices.
//! Per half-iteration each node therefore pays `(c−1)·α` latency terms
//! and `(c−1)·β·B·m·N` bytes, the same total volume as flat All-to-All
//! but with constant per-node degree (2 links), which is the regime
//! where the α term dominates the cost model.
//!
//! Slices ride the *reliable* ARQ class on per-owner coded streams
//! (stream id = originating node), so each relay link carries `c−1`
//! coherent delta streams and a drop is retransmit-priced, never lost.
//! Because every slice transits every link, a dead neighbor partitions
//! the ring — there is no "exclude" degrade path: the plan reports
//! [`super::engine::LockstepPlan::loss_is_fatal`] and a strikeout
//! aborts the run with `PeerLoss` regardless of `--on-node-loss`.
//!
//! The assembled state per iteration is bit-identical to the sync
//! All-to-All assembly under the f64 wire format (values are only
//! copied); under lossy formats (deltaf32) each hop re-quantizes, so
//! parity is within wire tolerance only. Fleet-absorption rounds and
//! convergence votes reuse the engine's flat collectives unchanged.

use super::engine::{self, LockstepPlan};
use super::outcome::NodeOutcome;
use super::RunCtx;
use crate::linalg::Mat;
use crate::metrics::SplitTimer;
use crate::net::{Endpoint, Recovery, TagKind};
use crate::runtime::BlockOp;

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| {
        if ctx.greedy_on() {
            // Greedy top-k exchange: the same c−1 hop rotation relays
            // sparse index+value frames instead of dense slices (loss
            // stays fatal — every frame transits every link).
            engine::greedy_lockstep_client(ctx, id, true)
        } else {
            engine::lockstep_client(ctx, id, &RingPlan)
        }
    })
}

struct RingPlan;

impl LockstepPlan for RingPlan {
    fn loss_is_fatal(&self) -> bool {
        true // every slice transits every link: a dead neighbor partitions the ring
    }

    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &self,
        ep: &Endpoint,
        kind: TagKind,
        round: &mut u64,
        _stream_id: u64,
        full: &mut Mat,
        r0: usize,
        m: usize,
        iter: u64,
        op: &mut dyn BlockOp,
        timer: &mut SplitTimer,
        stream: bool,
        alive: &mut [bool],
        rec: Option<&Recovery>,
    ) -> bool {
        let me = ep.id();
        let c = ep.nodes();
        let nh = full.cols();
        let right = (me + 1) % c;
        let left = (me + c - 1) % c;

        // Streamed-fold admission: the ring is naturally streaming —
        // each hop's slice can fold into the pending product while the
        // next hop is still in flight. Own slice folds first, then
        // arrivals in hop order (deterministic — delivery order on a
        // ring *is* hop order).
        let mut live = stream && op.supports_streaming();
        if live {
            op.accum_begin();
            live = timer.comp(|| op.accum_fold(r0, m, engine::slice_of(full, r0, m)));
        }

        for h in 1..c {
            *round += 1;
            // The slice forwarded at hop h originated h−1 positions to
            // our left (h = 1 forwards our own); the one received
            // originated h positions to our left.
            let send_owner = (me + c - (h - 1)) % c;
            let recv_owner = (me + c - h) % c;
            let payload: Vec<f64> = engine::slice_of(full, send_owner * m, m).to_vec();
            // Per-owner stream id: each of the c−1 logical slice streams
            // crossing this link keeps its own coherent delta state.
            timer.comm(|| ep.send_coded(right, kind, *round, send_owner as u64, payload, iter));
            let msg = match rec {
                None => Some(timer.comm(|| ep.recv_blocking(left, kind, *round))),
                Some(rec) => timer.comm(|| engine::recv_bounded(ep, left, kind, *round, rec)),
            };
            let Some(msg) = msg else {
                // The left neighbor burned the whole death budget: the
                // ring is partitioned. Mark it dead; the engine's client
                // loop sees the fatal loss and aborts with PeerLoss.
                alive[left] = false;
                return false;
            };
            full.as_mut_slice()[recv_owner * m * nh..(recv_owner + 1) * m * nh]
                .copy_from_slice(&msg.payload);
            if live {
                live = timer.comp(|| op.accum_fold(recv_owner * m, m, &msg.payload));
            }
        }
        live
    }
}
