//! Per-node and aggregate run outcomes — the result types every
//! topology returns and every experiment driver consumes.

use crate::metrics::SplitTimer;
use crate::net::NetTraffic;
use crate::runtime::{GreedyStats, StabStats};
use crate::sinkhorn::{State, StopReason};

/// Per-node result.
#[derive(Clone, Debug)]
pub struct NodeStats {
    pub id: usize,
    pub role: &'static str,
    pub timer: SplitTimer,
    pub iterations: usize,
    pub stop: StopReason,
    pub final_err: f64,
    /// Absorption-hybrid counters of this node's operators (u-op + v-op,
    /// or the star server's two kernel ops); `None` when the node ran no
    /// stabilized schedule (linear domain, dense/sparse logsumexp, pure
    /// element-wise star clients).
    pub stab: Option<StabStats>,
    /// Greedy top-k counters of this node's operators (`--exchange
    /// greedy` only; `None` under the full dense exchange).
    pub greedy: Option<GreedyStats>,
    /// Peers this node declared dead under the recovery policy (empty on
    /// lossless runs and for nodes that saw every peer respond).
    pub lost_peers: Vec<usize>,
}

impl NodeStats {
    pub fn comp_secs(&self) -> f64 {
        self.timer.comp_secs()
    }

    pub fn comm_secs(&self) -> f64 {
        self.timer.comm_secs()
    }

    pub fn total_secs(&self) -> f64 {
        self.timer.total_secs()
    }
}

/// One point of a traced error curve (Figs 9–12, 19–22).
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iter: usize,
    pub secs: f64,
    /// Aggregated (sync) or node-0-estimated (async) a-marginal L1 error.
    pub err: f64,
}

/// Aggregate run outcome.
#[derive(Clone, Debug)]
pub struct FederatedOutcome {
    pub state: State,
    pub iterations: usize,
    pub converged: bool,
    pub stop: StopReason,
    pub node_stats: Vec<NodeStats>,
    /// Staleness samples (async variants only).
    pub taus: Vec<u64>,
    pub trace: Vec<TracePoint>,
    pub secs: f64,
    /// Absorption-hybrid counters merged across every node that ran the
    /// stabilized log schedule (`None` when none did).
    pub stab: Option<StabStats>,
    /// Greedy top-k counters merged across every node (`None` when the
    /// run used the full dense exchange).
    pub greedy: Option<GreedyStats>,
    /// Per-[`crate::net::TagKind`] wire traffic (bytes priced on the
    /// encoded frames); default-empty for centralized runs, which have
    /// no fabric.
    pub traffic: NetTraffic,
    /// Whether the run lost a node: a crash injection fired or a peer
    /// was declared dead. A degraded outcome's `state` is partial —
    /// dead slices hold their last received value (`exclude`) or their
    /// abort-time value (`abort`).
    pub degraded: bool,
    /// The ids every node agrees are gone (crashed nodes plus the union
    /// of `NodeStats::lost_peers`), sorted.
    pub lost_nodes: Vec<usize>,
}

/// Per-node return value from protocol implementations.
pub struct NodeOutcome {
    pub stats: NodeStats,
    /// Final consistent slices (u_jj, v_jj) — (m × N) each; `None` for
    /// pure-relay nodes (the star server).
    pub slices: Option<(Mat, Mat)>,
    pub trace: Vec<TracePoint>,
}

use crate::linalg::Mat;

/// The paper's summary-row convention: the slowest node defines the run
/// ("only the node with the highest total execution time was kept").
pub fn slowest_node(stats: &[NodeStats]) -> &NodeStats {
    stats
        .iter()
        .max_by(|a, b| a.total_secs().partial_cmp(&b.total_secs()).unwrap())
        .expect("at least one node")
}

/// Aggregate stop reason across nodes. Fault-plan runs: a crashed node
/// ([`StopReason::Dead`]) does not veto the survivors' verdict — an
/// `--on-node-loss exclude` run that converges over the live slice is
/// `Converged` (the outcome's `degraded` flag records the loss); a
/// recovery abort anywhere is `PeerLoss`; all nodes dead is `Dead`.
pub fn aggregate_stop(stats: &[NodeStats]) -> StopReason {
    if stats.iter().any(|s| s.stop == StopReason::PeerLoss) {
        StopReason::PeerLoss
    } else if stats.iter().all(|s| s.stop == StopReason::Dead) {
        StopReason::Dead
    } else if stats
        .iter()
        .filter(|s| s.stop != StopReason::Dead)
        .all(|s| s.stop == StopReason::Converged)
    {
        StopReason::Converged
    } else if stats.iter().any(|s| s.stop == StopReason::Timeout) {
        StopReason::Timeout
    } else {
        StopReason::MaxIters
    }
}
