//! Algorithm 1 — Synchronous Federated Sinkhorn, All-to-All.
//!
//! Peer-to-peer lock-step: every client updates its `u` slice from the
//! shared `v`, AllGathers the slices, updates its `v` slice from the
//! shared `u`, AllGathers again. With communication frequency `w > 1`
//! (App. A) the compute pair repeats `w` times on local state before
//! each exchange.
//!
//! Proposition 1: this generates exactly the centralized iterate
//! sequence, so the convergence check (an AllGather of per-block error
//! contributions) is an exact global marginal error and every node stops
//! at the same iteration.

use super::fleet;
use super::runner::{NodeOutcome, NodeStats, RunCtx, TracePoint};
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{
    allgather, allgather_coded, allgather_resilient, bcast_coded, bcast_resilient, gather_coded,
    gather_resilient, Endpoint, NodeLoss, Recovery, TagKind,
};
use crate::runtime::{BlockOp, StabStats, Target};
use crate::sinkhorn::StopReason;
use std::time::Duration;

/// Coded-stream ids: each logical stream carries the same quantity
/// round after round, so the wire codec's delta/error-feedback state
/// stays coherent (see [`crate::net::wire`]).
const STREAM_U: u64 = 0;
const STREAM_V: u64 = 1;
/// Fleet probe/command stream pairs, one per phase (the v-ops'
/// reference lives in u-space and vice versa — their probes are
/// different quantities and must not share a delta stream).
const STREAM_GREF_V_OPS: u64 = 2;
const STREAM_GREF_U_OPS: u64 = 4;

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| client(ctx, id))
}

fn client(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let w = ctx.cfg.local_iters.max(1);
    let alpha = ctx.cfg.alpha;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // Block operators: the client's two kernel blocks stay resident in
    // the backend (device memory for XLA) for the whole run. In the log
    // domain the blocks hold `log K` and the op iterates log-scalings —
    // the AllGathered slices below are then exactly the communicated
    // log-scalings the paper's privacy layer measures. The stabilized
    // dispatch may run them on the absorption-hybrid / truncated-sparse
    // schedule; the exchanged slices are identical either way.
    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    // Full scaling state, refreshed by AllGathers.
    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    // Fleet-synchronized absorption (`--fleet-absorb`, log-domain hybrid
    // runs): rank 0 merges slice probes and broadcasts one reference
    // dual per product space, so every node re-absorbs in lock-step.
    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;
    // Slice-streaming exchange (`--stream-exchange`): peer slices are
    // folded into the consuming operator's pending product as their
    // frames become deliverable, hiding decode + partial compute behind
    // the transfers still in flight. The U exchange feeds the v-op in
    // the same iteration; the V exchange feeds the u-op's *next*
    // update, across the loop boundary (nothing touches `v_full`
    // between the exchange and that update).
    let stream = ctx.stream_on();
    let mut v_accum_live = false;
    let mut u_accum_live = false;

    // Fault-plan resilience: only an *active* plan arms the recovery
    // timeouts — lossless runs keep the unbounded blocking paths
    // byte-for-byte. Under loss the reliable ARQ still delivers every
    // frame, so a strikeout can only mean the sender crashed.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut alive = vec![true; ctx.cfg.clients];

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;
    let mut round: u64 = 0;

    'outer: for k in 1..=ctx.policy.max_iters {
        // Crash injection: exit cleanly at the iteration boundary —
        // peers see the silence and strike this node dead.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break 'outer;
        }
        iterations = k;
        // Paper Alg. 1: communicate on iterations with mod(k, w) = 0;
        // in between, clients iterate on locally-refreshed state.
        let communicate = k % w == 0;

        let u_jj = timer.comp(|| {
            if u_accum_live {
                u_op.accum_update(alpha).clone()
            } else {
                u_op.update(&v_full, alpha).clone()
            }
        });
        u_accum_live = false;
        copy_slice(&mut u_full, &u_jj, shard.r0);
        if communicate {
            round += 1;
            let was_alive = count_alive(&alive);
            v_accum_live = exchange(
                &ep,
                TagKind::U,
                round,
                STREAM_U,
                &mut u_full,
                shard.r0,
                m,
                k as u64,
                &mut *v_op,
                &mut timer,
                stream,
                &mut alive,
                resilient.then_some(&recovery),
            );
            if resilient
                && count_alive(&alive) < was_alive
                && recovery.on_node_loss == NodeLoss::Abort
            {
                stop = StopReason::PeerLoss;
                break 'outer;
            }
            if fleet {
                // Fleet-synchronized absorption for the v-operators
                // (their reference lives in u-space): probes ride the
                // freshly assembled u state.
                round += 2;
                fleet_sync(
                    &ep,
                    round,
                    STREAM_GREF_V_OPS,
                    &mut *v_op,
                    &u_full,
                    shard.r0,
                    m,
                    nh,
                    tau,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                );
            }
        }

        let v_jj = timer.comp(|| {
            if v_accum_live {
                v_op.accum_update(alpha).clone()
            } else {
                v_op.update(&u_full, alpha).clone()
            }
        });
        v_accum_live = false;
        copy_slice(&mut v_full, &v_jj, shard.r0);
        if communicate {
            round += 1;
            let was_alive = count_alive(&alive);
            u_accum_live = exchange(
                &ep,
                TagKind::V,
                round,
                STREAM_V,
                &mut v_full,
                shard.r0,
                m,
                k as u64,
                &mut *u_op,
                &mut timer,
                stream,
                &mut alive,
                resilient.then_some(&recovery),
            );
            if resilient
                && count_alive(&alive) < was_alive
                && recovery.on_node_loss == NodeLoss::Abort
            {
                stop = StopReason::PeerLoss;
                break 'outer;
            }
            if fleet {
                // … and for the u-operators (v-space reference).
                round += 2;
                fleet_sync(
                    &ep,
                    round,
                    STREAM_GREF_U_OPS,
                    &mut *u_op,
                    &v_full,
                    shard.r0,
                    m,
                    nh,
                    tau,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                );
            }
        }

        // Convergence: exact global error via an error AllGather (only
        // on communication rounds — nodes must check in lock-step).
        // Timeout is part of the same exchange: a unilateral break would
        // deadlock the peers inside their blocking collectives, so each
        // node contributes a timed-out flag and everyone honors the OR.
        if communicate && ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            let timed_out = ctx.policy.timeout_secs > 0.0
                && clock.now() > ctx.policy.timeout_secs;
            round += 1;
            // Under `exclude`, dead blocks are frozen and drop out of
            // the vote — the error is over the surviving slice.
            let (err, any_timeout) = if resilient {
                let was_alive = count_alive(&alive);
                let parts = timer.comm(|| {
                    allgather_resilient(
                        &ep,
                        TagKind::Ctl,
                        round,
                        None,
                        &[local, timed_out as u8 as f64],
                        k as u64,
                        &mut alive,
                        &recovery,
                    )
                });
                if count_alive(&alive) < was_alive
                    && recovery.on_node_loss == NodeLoss::Abort
                {
                    stop = StopReason::PeerLoss;
                    break 'outer;
                }
                (
                    parts.iter().flatten().map(|p| p[0]).sum(),
                    parts.iter().flatten().any(|p| p[1] > 0.0),
                )
            } else {
                let parts = timer.comm(|| {
                    allgather(
                        &ep,
                        TagKind::Ctl,
                        round,
                        &[local, timed_out as u8 as f64],
                        k as u64,
                    )
                });
                (
                    parts.iter().map(|p| p[0]).sum(),
                    parts.iter().any(|p| p[1] > 0.0),
                )
            };
            final_err = err;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err });
            }
            if err < ctx.policy.threshold {
                stop = StopReason::Converged;
                break 'outer;
            }
            if any_timeout {
                stop = StopReason::Timeout;
                break 'outer;
            }
        }
        // Dequantizing this round's received frames is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());
    }
    timer.add_comp(ep.take_decode_secs());

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err, // the AllGathered global error — identical on all nodes
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            lost_peers: lost_of(&alive),
        },
        slices: Some((u_op.state().clone(), v_op.state().clone())),
        trace,
    }
}

/// Survivor count of a live mask.
fn count_alive(alive: &[bool]) -> usize {
    alive.iter().filter(|&&l| l).count()
}

/// The dead peer ids a live mask records.
fn lost_of(alive: &[bool]) -> Vec<usize> {
    alive
        .iter()
        .enumerate()
        .filter(|(_, &l)| !l)
        .map(|(j, _)| j)
        .collect()
}

/// One slice exchange: streamed fold, resilient barrier, or the exact
/// lossless barrier, depending on the run's flags. Returns whether a
/// streamed fold chain survived (caller finishes with `accum_update`);
/// barrier paths always return `false`. Under a recovery policy
/// (`rec = Some`), silent peers are struck dead in `alive` and their
/// rows of `full` stay frozen at the last received value.
#[allow(clippy::too_many_arguments)]
fn exchange(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    stream_id: u64,
    full: &mut Mat,
    r0: usize,
    m: usize,
    iter: u64,
    op: &mut dyn BlockOp,
    timer: &mut SplitTimer,
    stream: bool,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) -> bool {
    if stream {
        stream_exchange(ep, kind, round, stream_id, full, r0, m, iter, op, timer, alive, rec)
    } else if let Some(rec) = rec {
        let parts = timer.comm(|| {
            allgather_resilient(
                ep,
                kind,
                round,
                Some(stream_id),
                slice_of(full, r0, m),
                iter,
                alive,
                rec,
            )
        });
        assemble_opt(full, &parts, m);
        false
    } else {
        let parts = timer.comm(|| {
            allgather_coded(ep, kind, round, stream_id, slice_of(full, r0, m), iter)
        });
        assemble(full, &parts, m);
        false
    }
}

/// Streamed slice exchange (`--stream-exchange`): send this node's
/// slice of `full` (rows `[r0, r0+m)`) to every peer on the coded
/// stream, then consume peer slices *in delivery order* — each is
/// written into `full` and folded into `op`'s pending product while the
/// remaining transfers are still in flight. Returns whether the fold
/// chain survived (the caller then finishes with `accum_update`); a
/// `false` means the fully assembled `full` must go through the
/// ordinary barrier `update` instead — `full` is always completely
/// assembled on return either way (dead peers' rows frozen). With
/// `rec = Some`, the delivery-order receive is bounded: after `strikes`
/// consecutive empty windows every still-missing peer is declared dead
/// and the fold chain is abandoned (its slices never arrived).
#[allow(clippy::too_many_arguments)]
fn stream_exchange(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    stream: u64,
    full: &mut Mat,
    r0: usize,
    m: usize,
    iter: u64,
    op: &mut dyn BlockOp,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) -> bool {
    let me = ep.id();
    let c = ep.nodes();
    let nh = full.cols();
    let mine: Vec<f64> = slice_of(full, r0, m).to_vec();
    timer.comm(|| {
        for dst in 0..c {
            if dst != me && alive[dst] {
                ep.send_coded(dst, kind, round, stream, mine.clone(), iter);
            }
        }
    });
    let mut live = op.supports_streaming();
    if live {
        op.accum_begin();
        // Own slice folds immediately — free overlap while peers' frames
        // are still in flight.
        live = timer.comp(|| op.accum_fold(r0, m, &mine));
    }
    let mut pending = alive.to_vec();
    pending[me] = false;
    while pending.iter().any(|&p| p) {
        let msg = match rec {
            None => Some(timer.comm(|| ep.recv_any_blocking(&pending, kind, round))),
            Some(rec) => {
                let per_try = Duration::from_secs_f64(rec.recv_timeout_secs.max(1e-3));
                let mut got = None;
                for _ in 0..rec.strikes.max(1) {
                    if let Some(msg) =
                        timer.comm(|| ep.recv_any_timeout(&pending, kind, round, per_try))
                    {
                        got = Some(msg);
                        break;
                    }
                }
                got
            }
        };
        let Some(msg) = msg else {
            // Strikeout: every still-missing peer is dead. Their rows of
            // `full` stay frozen; the incomplete fold chain is abandoned
            // so the caller re-runs the product on the assembled state.
            for (j, p) in pending.iter_mut().enumerate() {
                if *p {
                    alive[j] = false;
                    *p = false;
                }
            }
            live = false;
            break;
        };
        pending[msg.src] = false;
        let peer_r0 = msg.src * m;
        full.as_mut_slice()[peer_r0 * nh..(peer_r0 + m) * nh].copy_from_slice(&msg.payload);
        if live {
            live = timer.comp(|| op.accum_fold(peer_r0, m, &msg.payload));
        }
    }
    live
}

/// One lock-step fleet-absorption round for `op` against the freshly
/// assembled full state `x_full`: every node probes the `m` rows it
/// owns (`O(m·N)`, no redundant full scans), rank 0 gathers the probes,
/// merges + decides, and broadcasts either the reference-dual command
/// or a hold; every node applies the command to its own block operator.
/// Uses protocol rounds `base − 1` (gather) and `base` (broadcast) on
/// [`TagKind::Gref`] — both messages priced by the α–β latency model on
/// their *encoded* frames (probes ride coded stream `stream`, commands
/// `stream + 1`, closing the ROADMAP "Gref traffic compression" item;
/// absorption is exact for any reference, so a quantized `ḡ` only
/// perturbs *when* rebuilds trigger, never the iterates).
#[allow(clippy::too_many_arguments)]
fn fleet_sync(
    ep: &Endpoint,
    base_round: u64,
    stream: u64,
    op: &mut dyn BlockOp,
    x_full: &Mat,
    r0: usize,
    m: usize,
    nh: usize,
    tau: f64,
    iter: u64,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) {
    let payload = timer.comp(|| match op.fleet_probe(x_full, r0, m) {
        Some(p) => fleet::probe_payload(0, &p),
        None => fleet::degraded_payload(0),
    });
    // A dead peer's missing probe is substituted with the degraded
    // payload, which makes `decide` hold — fleet absorption freezes
    // while the fleet is degraded rather than re-absorbing against a
    // partial view (the fleet.rs hold state, reachable from real
    // faults). A dead rank 0 means no commands ever again: survivors
    // keep their current references (absorption stays exact for any
    // reference — only rebuild cadence degrades).
    let parts: Option<Vec<Vec<f64>>> = match rec {
        None => timer
            .comm(|| gather_coded(ep, 0, TagKind::Gref, base_round - 1, stream, &payload, iter)),
        Some(rec) => timer
            .comm(|| {
                gather_resilient(
                    ep,
                    0,
                    TagKind::Gref,
                    base_round - 1,
                    Some(stream),
                    &payload,
                    iter,
                    alive,
                    rec,
                )
            })
            .map(|parts| {
                parts
                    .into_iter()
                    .map(|p| p.unwrap_or_else(|| fleet::degraded_payload(0)))
                    .collect()
            }),
    };
    let reply = if let Some(parts) = parts {
        // Rank 0: merge + decide, then broadcast the verdict.
        let refs: Vec<&[f64]> = parts.iter().map(|p| p.as_slice()).collect();
        let decision = timer.comp(|| fleet::decide(&refs, nh, m, tau));
        let payload = match &decision {
            Some(cmd) => fleet::command_payload(0, cmd),
            None => fleet::hold_payload(0),
        };
        match rec {
            None => Some(timer.comm(|| {
                bcast_coded(ep, 0, TagKind::Gref, base_round, stream + 1, Some(&payload), iter)
            })),
            Some(rec) => timer.comm(|| {
                bcast_resilient(
                    ep,
                    0,
                    TagKind::Gref,
                    base_round,
                    Some(stream + 1),
                    Some(&payload),
                    iter,
                    alive,
                    rec,
                )
            }),
        }
    } else {
        match rec {
            None => Some(
                timer
                    .comm(|| bcast_coded(ep, 0, TagKind::Gref, base_round, stream + 1, None, iter)),
            ),
            Some(rec) => timer.comm(|| {
                bcast_resilient(
                    ep,
                    0,
                    TagKind::Gref,
                    base_round,
                    Some(stream + 1),
                    None,
                    iter,
                    alive,
                    rec,
                )
            }),
        }
    };
    if let Some(reply) = reply {
        if let (_, Some((needed, gref))) = fleet::parse_command(&reply) {
            timer.comp(|| op.fleet_absorb(gref, needed));
        }
    }
}

/// Rows `[r0, r0+m)` of `full` as a flat slice (row-major m×N block).
fn slice_of(full: &Mat, r0: usize, m: usize) -> &[f64] {
    let nh = full.cols();
    &full.as_slice()[r0 * nh..(r0 + m) * nh]
}

/// Write a client's block into the full state at row `r0`.
fn copy_slice(full: &mut Mat, block: &Mat, r0: usize) {
    let nh = full.cols();
    let m = block.rows();
    full.as_mut_slice()[r0 * nh..(r0 + m) * nh].copy_from_slice(block.as_slice());
}

/// Assemble AllGather parts (node-indexed, each m×N flat) into `full`.
fn assemble(full: &mut Mat, parts: &[Vec<f64>], m: usize) {
    let nh = full.cols();
    for (j, part) in parts.iter().enumerate() {
        debug_assert_eq!(part.len(), m * nh);
        full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(part);
    }
}

/// [`assemble`] over resilient parts: a dead peer's `None` slot leaves
/// its rows of `full` frozen at the last received value.
fn assemble_opt(full: &mut Mat, parts: &[Option<Vec<f64>>], m: usize) {
    let nh = full.cols();
    for (j, part) in parts.iter().enumerate() {
        if let Some(part) = part {
            debug_assert_eq!(part.len(), m * nh);
            full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(part);
        }
    }
}
