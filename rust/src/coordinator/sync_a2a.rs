//! Algorithm 1 — Synchronous Federated Sinkhorn, All-to-All.
//!
//! Peer-to-peer lock-step: every client updates its `u` slice from the
//! shared `v`, AllGathers the slices, updates its `v` slice from the
//! shared `u`, AllGathers again. With communication frequency `w > 1`
//! (App. A) the compute pair repeats `w` times on local state before
//! each exchange.
//!
//! Proposition 1: this generates exactly the centralized iterate
//! sequence, so the convergence check (an AllGather of per-block error
//! contributions) is an exact global marginal error and every node stops
//! at the same iteration.
//!
//! The entire client loop lives in [`engine::lockstep_client`]; this
//! protocol is the engine's [`engine::AllGatherPlan`] — the flat
//! AllGather (streamed-fold, resilient, or exact lossless barrier) as
//! the per-half-iteration exchange. Under `--exchange greedy` the nodes
//! run [`engine::greedy_lockstep_client`] instead: top-k damped
//! half-iterations with the flat sparse coordinate exchange.

use super::engine;
use super::outcome::NodeOutcome;
use super::RunCtx;

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| {
        if ctx.greedy_on() {
            engine::greedy_lockstep_client(ctx, id, false)
        } else {
            engine::lockstep_client(ctx, id, &engine::AllGatherPlan)
        }
    })
}
