//! Algorithm 1 — Synchronous Federated Sinkhorn, All-to-All.
//!
//! Peer-to-peer lock-step: every client updates its `u` slice from the
//! shared `v`, AllGathers the slices, updates its `v` slice from the
//! shared `u`, AllGathers again. With communication frequency `w > 1`
//! (App. A) the compute pair repeats `w` times on local state before
//! each exchange.
//!
//! Proposition 1: this generates exactly the centralized iterate
//! sequence, so the convergence check (an AllGather of per-block error
//! contributions) is an exact global marginal error and every node stops
//! at the same iteration.

use super::runner::{NodeOutcome, NodeStats, RunCtx, TracePoint};
use crate::linalg::Mat;
use crate::metrics::{Clock, SplitTimer};
use crate::net::{allgather, TagKind};
use crate::runtime::{StabStats, Target};
use crate::sinkhorn::StopReason;

pub fn run(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    super::runner::spawn_nodes(ctx.cfg.clients, |id| client(ctx, id))
}

fn client(ctx: &RunCtx<'_>, id: usize) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let w = ctx.cfg.local_iters.max(1);
    let alpha = ctx.cfg.alpha;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // Block operators: the client's two kernel blocks stay resident in
    // the backend (device memory for XLA) for the whole run. In the log
    // domain the blocks hold `log K` and the op iterates log-scalings —
    // the AllGathered slices below are then exactly the communicated
    // log-scalings the paper's privacy layer measures. The stabilized
    // dispatch may run them on the absorption-hybrid / truncated-sparse
    // schedule; the exchanged slices are identical either way.
    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    // Full scaling state, refreshed by AllGathers.
    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;
    let mut round: u64 = 0;

    'outer: for k in 1..=ctx.policy.max_iters {
        iterations = k;
        // Paper Alg. 1: communicate on iterations with mod(k, w) = 0;
        // in between, clients iterate on locally-refreshed state.
        let communicate = k % w == 0;

        let u_jj = timer.comp(|| u_op.update(&v_full, alpha).clone());
        copy_slice(&mut u_full, &u_jj, shard.r0);
        if communicate {
            round += 1;
            let u_parts = timer.comm(|| {
                allgather(&ep, TagKind::U, round, slice_of(&u_full, shard.r0, m), k as u64)
            });
            assemble(&mut u_full, &u_parts, m);
        }

        let v_jj = timer.comp(|| v_op.update(&u_full, alpha).clone());
        copy_slice(&mut v_full, &v_jj, shard.r0);
        if communicate {
            round += 1;
            let v_parts = timer.comm(|| {
                allgather(&ep, TagKind::V, round, slice_of(&v_full, shard.r0, m), k as u64)
            });
            assemble(&mut v_full, &v_parts, m);
        }

        // Convergence: exact global error via an error AllGather (only
        // on communication rounds — nodes must check in lock-step).
        // Timeout is part of the same exchange: a unilateral break would
        // deadlock the peers inside their blocking collectives, so each
        // node contributes a timed-out flag and everyone honors the OR.
        if communicate && ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            let timed_out = ctx.policy.timeout_secs > 0.0
                && clock.now() > ctx.policy.timeout_secs;
            round += 1;
            let parts = timer.comm(|| {
                allgather(&ep, TagKind::Ctl, round, &[local, timed_out as u8 as f64], k as u64)
            });
            let err: f64 = parts.iter().map(|p| p[0]).sum();
            let any_timeout = parts.iter().any(|p| p[1] > 0.0);
            final_err = err;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err });
            }
            if err < ctx.policy.threshold {
                stop = StopReason::Converged;
                break 'outer;
            }
            if any_timeout {
                stop = StopReason::Timeout;
                break 'outer;
            }
        }
    }

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err, // the AllGathered global error — identical on all nodes
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
        },
        slices: Some((u_op.state().clone(), v_op.state().clone())),
        trace,
    }
}

/// Rows `[r0, r0+m)` of `full` as a flat slice (row-major m×N block).
fn slice_of(full: &Mat, r0: usize, m: usize) -> &[f64] {
    let nh = full.cols();
    &full.as_slice()[r0 * nh..(r0 + m) * nh]
}

/// Write a client's block into the full state at row `r0`.
fn copy_slice(full: &mut Mat, block: &Mat, r0: usize) {
    let nh = full.cols();
    let m = block.rows();
    full.as_mut_slice()[r0 * nh..(r0 + m) * nh].copy_from_slice(block.as_slice());
}

/// Assemble AllGather parts (node-indexed, each m×N flat) into `full`.
fn assemble(full: &mut Mat, parts: &[Vec<f64>], m: usize) {
    let nh = full.cols();
    for (j, part) in parts.iter().enumerate() {
        debug_assert_eq!(part.len(), m * nh);
        full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(part);
    }
}
