//! The protocol core every topology runs on.
//!
//! A coordinator used to be a monolith: exchange + streamed folds,
//! `Recovery` strike-tracking, peer-death handling, and fleet-absorption
//! plumbing were hand-reimplemented per protocol. This module owns all
//! of that once:
//!
//! * [`Topology`] — the dispatch seam: a topology names itself, sizes
//!   its node set, and runs the per-node protocol over a [`RunCtx`].
//! * [`LockstepPlan`] — the synchronous per-iteration exchange plan:
//!   [`lockstep_client`] is the entire lock-step client loop (Alg. 1 —
//!   update, exchange, fleet round, convergence AllGather), generic
//!   over *how* one half-iteration assembles the full state. AllToAll
//!   plugs in the flat AllGather; [`super::ring`] plugs in the
//!   neighbor-pair rotation. Same loop, bit-identical where the plans
//!   deliver identical bits.
//! * Exchange machinery — [`stream_exchange`] (streamed-fold admission
//!   with strike-bounded delivery-order receive), [`fleet_sync`]
//!   (lock-step probe/command routing), [`server_product`] (the star
//!   hub's gather + fold + product), the strike-bounded receive
//!   primitives ([`recv_bounded`], [`recv_any_bounded`]), and the
//!   async machinery ([`FleetCoord`], [`coordinate`],
//!   [`apply_fleet_command`], [`send_fleet_probe`],
//!   [`finish_consistent`]).
//! * Slice plumbing shared by every protocol: [`slice_of`],
//!   [`copy_slice`], [`assemble`], [`write_block`], [`chunk_of`],
//!   [`ClientTargets`], [`block_err`], [`count_alive`], [`lost_of`].
//!
//! Delivery classes are chosen here, not in topologies: lock-step
//! exchanges ride the reliable ARQ streams (`send`/`send_coded` —
//! retransmits priced per frame + NACK), async scaling traffic rides
//! latest-wins (`send_coded_latest` — losses supersede, the delta codec
//! re-keys).

use super::ctx::RunCtx;
use super::fleet;
use super::outcome::{NodeOutcome, NodeStats, TracePoint};
use super::{async_a2a, gossip, ring, star, sync_a2a};
use crate::config::Variant;
use crate::linalg::{Domain, Mat};
use crate::metrics::{Clock, SplitTimer};
use crate::net::{
    allgather, allgather_coded, allgather_resilient, bcast_coded, bcast_resilient, gather_coded,
    gather_resilient, Endpoint, Message, NodeLoss, Recovery, TagKind,
};
use crate::runtime::{BlockOp, GreedyStats, StabStats, Target};
use crate::sinkhorn::StopReason;
use std::time::Duration;

// --------------------------------------------------------------------------
// Topology dispatch
// --------------------------------------------------------------------------

/// A federated exchange topology: the one seam a new protocol has to
/// fill in. Everything else — strike-based recovery, streamed folds,
/// fleet routing, stop aggregation — is engine machinery it calls into.
pub trait Topology: Sync {
    /// Display name (the `topology` column of the experiment grids).
    fn name(&self) -> &'static str;

    /// Node-thread count for `clients` data shards (the star adds its
    /// kernel-owning server; everyone else is client-only).
    fn nodes(&self, clients: usize) -> usize {
        clients
    }

    /// Run the per-node protocol and return one outcome per node.
    fn run(&self, ctx: &RunCtx<'_>) -> Vec<NodeOutcome>;
}

struct AllToAll {
    async_mode: bool,
}

impl Topology for AllToAll {
    fn name(&self) -> &'static str {
        "a2a"
    }

    fn run(&self, ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
        if self.async_mode {
            async_a2a::run(ctx)
        } else {
            sync_a2a::run(ctx)
        }
    }
}

struct Star {
    async_mode: bool,
}

impl Topology for Star {
    fn name(&self) -> &'static str {
        "star"
    }

    fn nodes(&self, clients: usize) -> usize {
        clients + 1 // + the kernel-owning server
    }

    fn run(&self, ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
        star::run(ctx, self.async_mode)
    }
}

struct RingTopo;

impl Topology for RingTopo {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn run(&self, ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
        ring::run(ctx)
    }
}

struct GossipTopo;

impl Topology for GossipTopo {
    fn name(&self) -> &'static str {
        "gossip"
    }

    fn run(&self, ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
        gossip::run(ctx)
    }
}

static SYNC_A2A: AllToAll = AllToAll { async_mode: false };
static ASYNC_A2A: AllToAll = AllToAll { async_mode: true };
static SYNC_STAR: Star = Star { async_mode: false };
static ASYNC_STAR: Star = Star { async_mode: true };
static RING: RingTopo = RingTopo;
static GOSSIP: GossipTopo = GossipTopo;

/// The topology instance behind a federated variant.
pub fn topology_for(variant: Variant) -> &'static dyn Topology {
    match variant {
        Variant::SyncA2A => &SYNC_A2A,
        Variant::AsyncA2A => &ASYNC_A2A,
        Variant::SyncStar => &SYNC_STAR,
        Variant::AsyncStar => &ASYNC_STAR,
        Variant::Ring => &RING,
        Variant::Gossip => &GOSSIP,
        Variant::Centralized => unreachable!("centralized runs have no topology"),
    }
}

/// Entry point the runner calls once the [`RunCtx`] is assembled.
pub fn run_topology(ctx: &RunCtx<'_>) -> Vec<NodeOutcome> {
    topology_for(ctx.cfg.variant).run(ctx)
}

// --------------------------------------------------------------------------
// The lock-step client loop (Alg. 1, topology-generic)
// --------------------------------------------------------------------------

/// Coded-stream ids: each logical stream carries the same quantity
/// round after round, so the wire codec's delta/error-feedback state
/// stays coherent (see [`crate::net::wire`]).
pub const STREAM_U: u64 = 0;
pub const STREAM_V: u64 = 1;
/// Fleet probe/command stream pairs, one per phase (the v-ops'
/// reference lives in u-space and vice versa — their probes are
/// different quantities and must not share a delta stream).
pub const STREAM_GREF_V_OPS: u64 = 2;
pub const STREAM_GREF_U_OPS: u64 = 4;

/// How one half-iteration of a lock-step protocol assembles the full
/// scaling state from the per-node slices. The plan owns its protocol
/// rounds (it advances `round` by however many exchange legs it needs)
/// and reports whether a streamed fold chain into `op` survived.
pub trait LockstepPlan: Sync {
    /// Whether losing any peer tears down the whole exchange graph. A
    /// flat AllGather can freeze a dead peer's rows and keep going
    /// (`--on-node-loss exclude`); a ring cannot — every slice transits
    /// every link, so a strikeout forces the abort path regardless of
    /// the configured policy.
    fn loss_is_fatal(&self) -> bool {
        false
    }

    /// One slice exchange: `full` holds this node's freshly written
    /// rows `[r0, r0+m)`; on return every live peer's rows are
    /// assembled (dead peers' rows frozen at the last received value).
    /// Returns whether a streamed fold chain into `op` survived (the
    /// caller then finishes with `accum_update`); `false` means the
    /// assembled `full` must go through the ordinary barrier update.
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &self,
        ep: &Endpoint,
        kind: TagKind,
        round: &mut u64,
        stream_id: u64,
        full: &mut Mat,
        r0: usize,
        m: usize,
        iter: u64,
        op: &mut dyn BlockOp,
        timer: &mut SplitTimer,
        stream: bool,
        alive: &mut [bool],
        rec: Option<&Recovery>,
    ) -> bool;
}

/// The flat AllGather plan — Alg. 1's exchange, verbatim: streamed
/// fold, resilient barrier, or the exact lossless barrier.
pub struct AllGatherPlan;

impl LockstepPlan for AllGatherPlan {
    #[allow(clippy::too_many_arguments)]
    fn exchange(
        &self,
        ep: &Endpoint,
        kind: TagKind,
        round: &mut u64,
        stream_id: u64,
        full: &mut Mat,
        r0: usize,
        m: usize,
        iter: u64,
        op: &mut dyn BlockOp,
        timer: &mut SplitTimer,
        stream: bool,
        alive: &mut [bool],
        rec: Option<&Recovery>,
    ) -> bool {
        *round += 1;
        if stream {
            stream_exchange(ep, kind, *round, stream_id, full, r0, m, iter, op, timer, alive, rec)
        } else if let Some(rec) = rec {
            let parts = timer.comm(|| {
                allgather_resilient(
                    ep,
                    kind,
                    *round,
                    Some(stream_id),
                    slice_of(full, r0, m),
                    iter,
                    alive,
                    rec,
                )
            });
            assemble_opt(full, &parts, m);
            false
        } else {
            let parts = timer.comm(|| {
                allgather_coded(ep, kind, *round, stream_id, slice_of(full, r0, m), iter)
            });
            assemble(full, &parts, m);
            false
        }
    }
}

/// The whole lock-step client (Alg. 1): damped block updates, the
/// plan's half-iteration exchanges, optional fleet-absorption rounds,
/// and the exact convergence AllGather — every node stops at the same
/// iteration. With [`AllGatherPlan`] this is byte-for-byte the paper's
/// synchronous All-to-All client; other plans reuse the loop unchanged.
pub fn lockstep_client(ctx: &RunCtx<'_>, id: usize, plan: &dyn LockstepPlan) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let w = ctx.cfg.local_iters.max(1);
    let alpha = ctx.cfg.alpha;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    // Block operators: the client's two kernel blocks stay resident in
    // the backend (device memory for XLA) for the whole run. In the log
    // domain the blocks hold `log K` and the op iterates log-scalings —
    // the exchanged slices below are then exactly the communicated
    // log-scalings the paper's privacy layer measures. The stabilized
    // dispatch may run them on the absorption-hybrid / truncated-sparse
    // schedule; the exchanged slices are identical either way.
    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");

    // Full scaling state, refreshed by the plan's exchanges.
    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    // Fleet-synchronized absorption (`--fleet-absorb`, log-domain hybrid
    // runs): rank 0 merges slice probes and broadcasts one reference
    // dual per product space, so every node re-absorbs in lock-step.
    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;
    // Slice-streaming exchange (`--stream-exchange`): peer slices are
    // folded into the consuming operator's pending product as their
    // frames become deliverable, hiding decode + partial compute behind
    // the transfers still in flight. The U exchange feeds the v-op in
    // the same iteration; the V exchange feeds the u-op's *next*
    // update, across the loop boundary (nothing touches `v_full`
    // between the exchange and that update).
    let stream = ctx.stream_on();
    let mut v_accum_live = false;
    let mut u_accum_live = false;

    // Fault-plan resilience: only an *active* plan arms the recovery
    // timeouts — lossless runs keep the unbounded blocking paths
    // byte-for-byte. Under loss the reliable ARQ still delivers every
    // frame, so a strikeout can only mean the sender crashed.
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut alive = vec![true; ctx.cfg.clients];

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;
    let mut round: u64 = 0;

    'outer: for k in 1..=ctx.policy.max_iters {
        // Crash injection: exit cleanly at the iteration boundary —
        // peers see the silence and strike this node dead.
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break 'outer;
        }
        iterations = k;
        // Paper Alg. 1: communicate on iterations with mod(k, w) = 0;
        // in between, clients iterate on locally-refreshed state.
        let communicate = k % w == 0;

        let u_jj = timer.comp(|| {
            if u_accum_live {
                u_op.accum_update(alpha).clone()
            } else {
                u_op.update(&v_full, alpha).clone()
            }
        });
        u_accum_live = false;
        copy_slice(&mut u_full, &u_jj, shard.r0);
        if communicate {
            let was_alive = count_alive(&alive);
            v_accum_live = plan.exchange(
                &ep,
                TagKind::U,
                &mut round,
                STREAM_U,
                &mut u_full,
                shard.r0,
                m,
                k as u64,
                &mut *v_op,
                &mut timer,
                stream,
                &mut alive,
                resilient.then_some(&recovery),
            );
            if resilient
                && count_alive(&alive) < was_alive
                && (plan.loss_is_fatal() || recovery.on_node_loss == NodeLoss::Abort)
            {
                stop = StopReason::PeerLoss;
                break 'outer;
            }
            if fleet {
                // Fleet-synchronized absorption for the v-operators
                // (their reference lives in u-space): probes ride the
                // freshly assembled u state.
                round += 2;
                fleet_sync(
                    &ep,
                    round,
                    STREAM_GREF_V_OPS,
                    &mut *v_op,
                    &u_full,
                    shard.r0,
                    m,
                    nh,
                    tau,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                );
            }
        }

        let v_jj = timer.comp(|| {
            if v_accum_live {
                v_op.accum_update(alpha).clone()
            } else {
                v_op.update(&u_full, alpha).clone()
            }
        });
        v_accum_live = false;
        copy_slice(&mut v_full, &v_jj, shard.r0);
        if communicate {
            let was_alive = count_alive(&alive);
            u_accum_live = plan.exchange(
                &ep,
                TagKind::V,
                &mut round,
                STREAM_V,
                &mut v_full,
                shard.r0,
                m,
                k as u64,
                &mut *u_op,
                &mut timer,
                stream,
                &mut alive,
                resilient.then_some(&recovery),
            );
            if resilient
                && count_alive(&alive) < was_alive
                && (plan.loss_is_fatal() || recovery.on_node_loss == NodeLoss::Abort)
            {
                stop = StopReason::PeerLoss;
                break 'outer;
            }
            if fleet {
                // … and for the u-operators (v-space reference).
                round += 2;
                fleet_sync(
                    &ep,
                    round,
                    STREAM_GREF_U_OPS,
                    &mut *u_op,
                    &v_full,
                    shard.r0,
                    m,
                    nh,
                    tau,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                );
            }
        }

        // Convergence: exact global error via an error AllGather (only
        // on communication rounds — nodes must check in lock-step).
        // Timeout is part of the same exchange: a unilateral break would
        // deadlock the peers inside their blocking collectives, so each
        // node contributes a timed-out flag and everyone honors the OR.
        if communicate && ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            let timed_out = ctx.policy.timeout_secs > 0.0
                && clock.now() > ctx.policy.timeout_secs;
            round += 1;
            // Under `exclude`, dead blocks are frozen and drop out of
            // the vote — the error is over the surviving slice.
            let (err, any_timeout) = if resilient {
                let was_alive = count_alive(&alive);
                let parts = timer.comm(|| {
                    allgather_resilient(
                        &ep,
                        TagKind::Ctl,
                        round,
                        None,
                        &[local, timed_out as u8 as f64],
                        k as u64,
                        &mut alive,
                        &recovery,
                    )
                });
                if count_alive(&alive) < was_alive
                    && (plan.loss_is_fatal() || recovery.on_node_loss == NodeLoss::Abort)
                {
                    stop = StopReason::PeerLoss;
                    break 'outer;
                }
                (
                    parts.iter().flatten().map(|p| p[0]).sum(),
                    parts.iter().flatten().any(|p| p[1] > 0.0),
                )
            } else {
                let parts = timer.comm(|| {
                    allgather(
                        &ep,
                        TagKind::Ctl,
                        round,
                        &[local, timed_out as u8 as f64],
                        k as u64,
                    )
                });
                (
                    parts.iter().map(|p| p[0]).sum(),
                    parts.iter().any(|p| p[1] > 0.0),
                )
            };
            final_err = err;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err });
            }
            if err < ctx.policy.threshold {
                stop = StopReason::Converged;
                break 'outer;
            }
            if any_timeout {
                stop = StopReason::Timeout;
                break 'outer;
            }
        }
        // Dequantizing this round's received frames is receiver CPU work.
        timer.add_comp(ep.take_decode_secs());
    }
    timer.add_comp(ep.take_decode_secs());

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err, // the AllGathered global error — identical on all nodes
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            greedy: None,
            lost_peers: lost_of(&alive),
        },
        slices: Some((u_op.state().clone(), v_op.state().clone())),
        trace,
    }
}

// --------------------------------------------------------------------------
// The greedy lock-step client (`--exchange greedy`, Greenkhorn-style)
// --------------------------------------------------------------------------

/// The greedy lock-step client: each half-iteration damps only the
/// top-k rows by marginal violation ([`crate::runtime::GreedySpec`])
/// and ships exactly those coordinates as sparse index+value frames
/// ([`TagKind::SparseU`]/[`TagKind::SparseV`]) instead of the dense
/// slice — the federated Greenkhorn step. Operators maintain their
/// block product incrementally from the declared changed-coordinate
/// sets (own selections plus every peer coordinate received), so a
/// half-iteration costs `O(k·n)` instead of `O(m·n)` between
/// convergence checks. Convergence still rides the exact full-marginal
/// AllGather of [`lockstep_client`], so greedy can never report a
/// converged state the dense protocol would reject. `ring = true`
/// relays the sparse frames around the neighbor ring (per-owner
/// streams, loss fatal) instead of the flat exchange.
pub fn greedy_lockstep_client(ctx: &RunCtx<'_>, id: usize, ring: bool) -> NodeOutcome {
    let shard = &ctx.partition.shards[id];
    let (n, m, nh) = (ctx.problem.n, shard.m(), ctx.problem.hists());
    let w = ctx.cfg.local_iters.max(1);
    let alpha = ctx.cfg.alpha;
    let spec = ctx.cfg.greedy_topk;
    let ep = ctx.net.endpoint(id);
    let clock = Clock::new();
    let mut timer = SplitTimer::new();

    let one = ctx.domain.one();
    let mut u_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_row,
            Target::Vec(&shard.a),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("u-op");
    let mut v_op = ctx
        .backend
        .block_op_in_stabilized(
            ctx.domain,
            &shard.k_col_t,
            Target::Mat(&shard.b),
            Mat::full(m, nh, one),
            &ctx.stab,
        )
        .expect("v-op");
    assert!(
        u_op.supports_greedy() && v_op.supports_greedy(),
        "--exchange greedy needs operators with greedy support (use --backend native)"
    );

    let mut u_full = Mat::full(n, nh, one);
    let mut v_full = Mat::full(n, nh, one);

    let fleet = ctx.fleet_on();
    let tau = ctx.stab.absorb_threshold;
    let resilient = ctx.cfg.faults.is_active();
    let recovery = ctx.cfg.recovery;
    let crash_at = ctx.cfg.faults.crash_at(id);
    let mut alive = vec![true; ctx.cfg.clients];

    // Incremental-maintenance bookkeeping. `changed_u` accumulates the
    // global u-rows that moved since the *v-op's* last greedy call (own
    // selections + scattered peer frames) and vice versa; `None` until
    // the op's first call, which pays its one full refresh. `pending_*`
    // hold this node's locally selected rows awaiting the next exchange
    // (they accumulate across the `w − 1` non-communicating iterations;
    // values are read from the current state at send time).
    let mut changed_u: Option<Vec<u32>> = None;
    let mut changed_v: Option<Vec<u32>> = None;
    let mut pending_u: Vec<u32> = Vec::new();
    let mut pending_v: Vec<u32> = Vec::new();
    let mut gstats = GreedyStats::default();

    let mut trace = Vec::new();
    let mut stop = StopReason::MaxIters;
    let mut final_err = f64::INFINITY;
    let mut iterations = 0;
    let mut round: u64 = 0;

    'outer: for k in 1..=ctx.policy.max_iters {
        if crash_at.is_some_and(|ci| k as u64 >= ci) {
            stop = StopReason::Dead;
            break 'outer;
        }
        iterations = k;
        let communicate = k % w == 0;

        let ou = timer.comp(|| u_op.greedy_update(&v_full, alpha, spec, changed_v.as_deref()));
        changed_v = Some(Vec::new());
        gstats.record(&ou, m);
        copy_slice(&mut u_full, u_op.state(), shard.r0);
        if let Some(ch) = changed_u.as_mut() {
            let own: Vec<u32> = ou.rows.iter().map(|&r| shard.r0 as u32 + r).collect();
            merge_rows(ch, &own);
        }
        merge_rows(&mut pending_u, &ou.rows);
        if communicate {
            let was_alive = count_alive(&alive);
            if ring {
                greedy_ring_exchange(
                    &ep,
                    TagKind::SparseU,
                    &mut round,
                    &mut u_full,
                    m,
                    &pending_u,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                    &mut changed_u,
                );
            } else {
                greedy_allgather(
                    &ep,
                    TagKind::SparseU,
                    &mut round,
                    STREAM_U,
                    &mut u_full,
                    shard.r0,
                    m,
                    &pending_u,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                    &mut changed_u,
                );
            }
            pending_u.clear();
            if resilient
                && count_alive(&alive) < was_alive
                && (ring || recovery.on_node_loss == NodeLoss::Abort)
            {
                stop = StopReason::PeerLoss;
                break 'outer;
            }
            if fleet {
                round += 2;
                fleet_sync(
                    &ep,
                    round,
                    STREAM_GREF_V_OPS,
                    &mut *v_op,
                    &u_full,
                    shard.r0,
                    m,
                    nh,
                    tau,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                );
            }
        }

        let ov = timer.comp(|| v_op.greedy_update(&u_full, alpha, spec, changed_u.as_deref()));
        changed_u = Some(Vec::new());
        gstats.record(&ov, m);
        copy_slice(&mut v_full, v_op.state(), shard.r0);
        if let Some(ch) = changed_v.as_mut() {
            let own: Vec<u32> = ov.rows.iter().map(|&r| shard.r0 as u32 + r).collect();
            merge_rows(ch, &own);
        }
        merge_rows(&mut pending_v, &ov.rows);
        if communicate {
            let was_alive = count_alive(&alive);
            if ring {
                greedy_ring_exchange(
                    &ep,
                    TagKind::SparseV,
                    &mut round,
                    &mut v_full,
                    m,
                    &pending_v,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                    &mut changed_v,
                );
            } else {
                greedy_allgather(
                    &ep,
                    TagKind::SparseV,
                    &mut round,
                    STREAM_V,
                    &mut v_full,
                    shard.r0,
                    m,
                    &pending_v,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                    &mut changed_v,
                );
            }
            pending_v.clear();
            if resilient
                && count_alive(&alive) < was_alive
                && (ring || recovery.on_node_loss == NodeLoss::Abort)
            {
                stop = StopReason::PeerLoss;
                break 'outer;
            }
            if fleet {
                round += 2;
                fleet_sync(
                    &ep,
                    round,
                    STREAM_GREF_U_OPS,
                    &mut *u_op,
                    &v_full,
                    shard.r0,
                    m,
                    nh,
                    tau,
                    k as u64,
                    &mut timer,
                    &mut alive,
                    resilient.then_some(&recovery),
                );
            }
        }

        // Convergence: the exact full-marginal AllGather, identical to
        // the dense lock-step client — the greedy schedule changes what
        // moves per iteration, never what "converged" means.
        if communicate && ctx.policy.check_at(k) {
            let u_now = u_op.state().clone();
            let local: f64 = timer
                .comp(|| u_op.marginal(&v_full, &u_now))
                .iter()
                .cloned()
                .fold(0.0, f64::max);
            let timed_out = ctx.policy.timeout_secs > 0.0
                && clock.now() > ctx.policy.timeout_secs;
            round += 1;
            let (err, any_timeout) = if resilient {
                let was_alive = count_alive(&alive);
                let parts = timer.comm(|| {
                    allgather_resilient(
                        &ep,
                        TagKind::Ctl,
                        round,
                        None,
                        &[local, timed_out as u8 as f64],
                        k as u64,
                        &mut alive,
                        &recovery,
                    )
                });
                if count_alive(&alive) < was_alive
                    && (ring || recovery.on_node_loss == NodeLoss::Abort)
                {
                    stop = StopReason::PeerLoss;
                    break 'outer;
                }
                (
                    parts.iter().flatten().map(|p| p[0]).sum(),
                    parts.iter().flatten().any(|p| p[1] > 0.0),
                )
            } else {
                let parts = timer.comm(|| {
                    allgather(
                        &ep,
                        TagKind::Ctl,
                        round,
                        &[local, timed_out as u8 as f64],
                        k as u64,
                    )
                });
                (
                    parts.iter().map(|p| p[0]).sum(),
                    parts.iter().any(|p| p[1] > 0.0),
                )
            };
            final_err = err;
            if ctx.traced {
                trace.push(TracePoint { iter: k, secs: clock.now(), err });
            }
            if err < ctx.policy.threshold {
                stop = StopReason::Converged;
                break 'outer;
            }
            if any_timeout {
                stop = StopReason::Timeout;
                break 'outer;
            }
        }
        timer.add_comp(ep.take_decode_secs());
    }
    timer.add_comp(ep.take_decode_secs());

    NodeOutcome {
        stats: NodeStats {
            id,
            role: "client",
            timer,
            iterations,
            stop,
            final_err,
            stab: StabStats::merged(u_op.stab_stats(), v_op.stab_stats()),
            greedy: Some(gstats),
            lost_peers: lost_of(&alive),
        },
        slices: Some((u_op.state().clone(), v_op.state().clone())),
        trace,
    }
}

/// Flat sparse AllGather of one greedy half-iteration: send this node's
/// selected coordinates of rows `[r0, r0+m)` to every live peer, then
/// scatter each peer's frame into `full` as it arrives (dead peers'
/// rows frozen). Every received row is recorded into the consuming
/// operator's changed-set accumulator. With `rec = Some` the receive is
/// strike-bounded, mirroring [`stream_exchange`]'s strikeout handling.
#[allow(clippy::too_many_arguments)]
pub fn greedy_allgather(
    ep: &Endpoint,
    kind: TagKind,
    round: &mut u64,
    stream_id: u64,
    full: &mut Mat,
    r0: usize,
    m: usize,
    rows: &[u32],
    iter: u64,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
    changed: &mut Option<Vec<u32>>,
) {
    *round += 1;
    let me = ep.id();
    let c = ep.nodes();
    let nh = full.cols();
    let (idx, vals) = pack_rows(full, r0, rows, nh);
    timer.comm(|| {
        for dst in 0..c {
            if dst != me && alive[dst] {
                ep.send_sparse_coded(
                    dst,
                    kind,
                    *round,
                    stream_id,
                    idx.clone(),
                    vals.clone(),
                    m * nh,
                    iter,
                );
            }
        }
    });
    let mut pending = alive.to_vec();
    pending[me] = false;
    while pending.iter().any(|&p| p) {
        let msg = match rec {
            None => Some(timer.comm(|| ep.recv_any_blocking(&pending, kind, *round))),
            Some(rec) => timer.comm(|| recv_any_bounded(ep, &pending, kind, *round, rec)),
        };
        let Some(msg) = msg else {
            for (j, p) in pending.iter_mut().enumerate() {
                if *p {
                    alive[j] = false;
                    *p = false;
                }
            }
            break;
        };
        pending[msg.src] = false;
        scatter_sparse(full, msg.src * m, &msg.indices, &msg.payload, changed);
    }
}

/// Ring relay of the greedy sparse frames: at hop `h ∈ 1..c` every node
/// forwards the frame it received `h−1` hops ago (hop 1 sends its own)
/// on the originating owner's coded stream and scatters the one
/// arriving from its left. Indices stay owner-slice-local, so any relay
/// can scatter without re-indexing. Loss is fatal exactly as in the
/// dense [`super::ring`] plan — every frame transits every link.
#[allow(clippy::too_many_arguments)]
pub fn greedy_ring_exchange(
    ep: &Endpoint,
    kind: TagKind,
    round: &mut u64,
    full: &mut Mat,
    m: usize,
    rows: &[u32],
    iter: u64,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
    changed: &mut Option<Vec<u32>>,
) {
    let me = ep.id();
    let c = ep.nodes();
    let nh = full.cols();
    let right = (me + 1) % c;
    let left = (me + c - 1) % c;
    let (mut relay_idx, mut relay_val) = pack_rows(full, me * m, rows, nh);
    for h in 1..c {
        *round += 1;
        let send_owner = (me + c - (h - 1)) % c;
        let recv_owner = (me + c - h) % c;
        timer.comm(|| {
            ep.send_sparse_coded(
                right,
                kind,
                *round,
                send_owner as u64,
                relay_idx.clone(),
                relay_val.clone(),
                m * nh,
                iter,
            )
        });
        let msg = match rec {
            None => Some(timer.comm(|| ep.recv_blocking(left, kind, *round))),
            Some(rec) => timer.comm(|| recv_bounded(ep, left, kind, *round, rec)),
        };
        let Some(msg) = msg else {
            alive[left] = false;
            return;
        };
        scatter_sparse(full, recv_owner * m, &msg.indices, &msg.payload, changed);
        relay_idx = msg.indices;
        relay_val = msg.payload;
    }
}

/// Pack the selected local rows of this node's slice (rows `[r0,
/// r0+m)` of `full`) into a sparse frame: indices are flat positions
/// `row·N + h` within the slice (strictly increasing — `rows` is
/// sorted), values the current absolute scalings.
pub fn pack_rows(full: &Mat, r0: usize, rows: &[u32], nh: usize) -> (Vec<u32>, Vec<f64>) {
    let mut idx = Vec::with_capacity(rows.len() * nh);
    let mut vals = Vec::with_capacity(rows.len() * nh);
    for &r in rows {
        for h in 0..nh {
            idx.push(r * nh as u32 + h as u32);
            vals.push(full[(r0 + r as usize, h)]);
        }
    }
    (idx, vals)
}

/// Scatter one received sparse frame into the sender's rows of `full`
/// (slice origin row `row0`) and record the touched global rows into
/// the consuming operator's changed-set accumulator (when live).
pub fn scatter_sparse(
    full: &mut Mat,
    row0: usize,
    indices: &[u32],
    values: &[f64],
    changed: &mut Option<Vec<u32>>,
) {
    let nh = full.cols();
    let flat = full.as_mut_slice();
    let mut rows: Vec<u32> = Vec::new();
    for (&i, &v) in indices.iter().zip(values) {
        flat[row0 * nh + i as usize] = v;
        let row = row0 as u32 + i / nh as u32;
        if rows.last() != Some(&row) {
            rows.push(row);
        }
    }
    if let Some(ch) = changed.as_mut() {
        merge_rows(ch, &rows);
    }
}

/// Merge a sorted row set into an accumulator, keeping it sorted
/// ascending and deduplicated — the invariant every `changed` consumer
/// (and the sparse frame codec) requires.
pub fn merge_rows(dst: &mut Vec<u32>, src: &[u32]) {
    dst.extend_from_slice(src);
    dst.sort_unstable();
    dst.dedup();
}

// --------------------------------------------------------------------------
// Exchange machinery
// --------------------------------------------------------------------------

/// Streamed slice exchange (`--stream-exchange`): send this node's
/// slice of `full` (rows `[r0, r0+m)`) to every peer on the coded
/// stream, then consume peer slices *in delivery order* — each is
/// written into `full` and folded into `op`'s pending product while the
/// remaining transfers are still in flight. Returns whether the fold
/// chain survived (the caller then finishes with `accum_update`); a
/// `false` means the fully assembled `full` must go through the
/// ordinary barrier `update` instead — `full` is always completely
/// assembled on return either way (dead peers' rows frozen). With
/// `rec = Some`, the delivery-order receive is bounded: after `strikes`
/// consecutive empty windows every still-missing peer is declared dead
/// and the fold chain is abandoned (its slices never arrived).
#[allow(clippy::too_many_arguments)]
pub fn stream_exchange(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    stream: u64,
    full: &mut Mat,
    r0: usize,
    m: usize,
    iter: u64,
    op: &mut dyn BlockOp,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) -> bool {
    let me = ep.id();
    let c = ep.nodes();
    let nh = full.cols();
    let mine: Vec<f64> = slice_of(full, r0, m).to_vec();
    timer.comm(|| {
        for dst in 0..c {
            if dst != me && alive[dst] {
                ep.send_coded(dst, kind, round, stream, mine.clone(), iter);
            }
        }
    });
    let mut live = op.supports_streaming();
    if live {
        op.accum_begin();
        // Own slice folds immediately — free overlap while peers' frames
        // are still in flight.
        live = timer.comp(|| op.accum_fold(r0, m, &mine));
    }
    let mut pending = alive.to_vec();
    pending[me] = false;
    while pending.iter().any(|&p| p) {
        let msg = match rec {
            None => Some(timer.comm(|| ep.recv_any_blocking(&pending, kind, round))),
            Some(rec) => timer.comm(|| recv_any_bounded(ep, &pending, kind, round, rec)),
        };
        let Some(msg) = msg else {
            // Strikeout: every still-missing peer is dead. Their rows of
            // `full` stay frozen; the incomplete fold chain is abandoned
            // so the caller re-runs the product on the assembled state.
            for (j, p) in pending.iter_mut().enumerate() {
                if *p {
                    alive[j] = false;
                    *p = false;
                }
            }
            live = false;
            break;
        };
        pending[msg.src] = false;
        let peer_r0 = msg.src * m;
        full.as_mut_slice()[peer_r0 * nh..(peer_r0 + m) * nh].copy_from_slice(&msg.payload);
        if live {
            live = timer.comp(|| op.accum_fold(peer_r0, m, &msg.payload));
        }
    }
    live
}

/// One lock-step fleet-absorption round for `op` against the freshly
/// assembled full state `x_full`: every node probes the `m` rows it
/// owns (`O(m·N)`, no redundant full scans), rank 0 gathers the probes,
/// merges + decides, and broadcasts either the reference-dual command
/// or a hold; every node applies the command to its own block operator.
/// Uses protocol rounds `base − 1` (gather) and `base` (broadcast) on
/// [`TagKind::Gref`] — both messages priced by the α–β latency model on
/// their *encoded* frames (probes ride coded stream `stream`, commands
/// `stream + 1`; absorption is exact for any reference, so a quantized
/// `ḡ` only perturbs *when* rebuilds trigger, never the iterates).
#[allow(clippy::too_many_arguments)]
pub fn fleet_sync(
    ep: &Endpoint,
    base_round: u64,
    stream: u64,
    op: &mut dyn BlockOp,
    x_full: &Mat,
    r0: usize,
    m: usize,
    nh: usize,
    tau: f64,
    iter: u64,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) {
    let payload = timer.comp(|| match op.fleet_probe(x_full, r0, m) {
        Some(p) => fleet::probe_payload(0, &p),
        None => fleet::degraded_payload(0),
    });
    // A dead peer's missing probe is substituted with the degraded
    // payload, which makes `decide` hold — fleet absorption freezes
    // while the fleet is degraded rather than re-absorbing against a
    // partial view (the fleet.rs hold state, reachable from real
    // faults). A dead rank 0 means no commands ever again: survivors
    // keep their current references (absorption stays exact for any
    // reference — only rebuild cadence degrades).
    let parts: Option<Vec<Vec<f64>>> = match rec {
        None => timer
            .comm(|| gather_coded(ep, 0, TagKind::Gref, base_round - 1, stream, &payload, iter)),
        Some(rec) => timer
            .comm(|| {
                gather_resilient(
                    ep,
                    0,
                    TagKind::Gref,
                    base_round - 1,
                    Some(stream),
                    &payload,
                    iter,
                    alive,
                    rec,
                )
            })
            .map(|parts| {
                parts
                    .into_iter()
                    .map(|p| p.unwrap_or_else(|| fleet::degraded_payload(0)))
                    .collect()
            }),
    };
    let reply = if let Some(parts) = parts {
        // Rank 0: merge + decide, then broadcast the verdict.
        let refs: Vec<&[f64]> = parts.iter().map(|p| p.as_slice()).collect();
        let decision = timer.comp(|| fleet::decide(&refs, nh, m, tau));
        let payload = match &decision {
            Some(cmd) => fleet::command_payload(0, cmd),
            None => fleet::hold_payload(0),
        };
        match rec {
            None => Some(timer.comm(|| {
                bcast_coded(ep, 0, TagKind::Gref, base_round, stream + 1, Some(&payload), iter)
            })),
            Some(rec) => timer.comm(|| {
                bcast_resilient(
                    ep,
                    0,
                    TagKind::Gref,
                    base_round,
                    Some(stream + 1),
                    Some(&payload),
                    iter,
                    alive,
                    rec,
                )
            }),
        }
    } else {
        match rec {
            None => Some(
                timer
                    .comm(|| bcast_coded(ep, 0, TagKind::Gref, base_round, stream + 1, None, iter)),
            ),
            Some(rec) => timer.comm(|| {
                bcast_resilient(
                    ep,
                    0,
                    TagKind::Gref,
                    base_round,
                    Some(stream + 1),
                    None,
                    iter,
                    alive,
                    rec,
                )
            }),
        }
    };
    if let Some(reply) = reply {
        if let (_, Some((needed, gref))) = fleet::parse_command(&reply) {
            timer.comp(|| op.fleet_absorb(gref, needed));
        }
    }
}

/// Synchronous server-side product over the gathered client slices.
/// With the streamed exchange live, each client's slice folds into the
/// operator's pending product the moment its frame is deliverable
/// (decode + partial compute hide behind the remaining transfers);
/// otherwise — streaming off, an operator without the accumulation
/// hooks, or a hybrid fold that aborted on a drift trip — the fully
/// assembled state goes through the ordinary barrier `matvec`. Fleet's
/// local decide/apply always runs on the assembled state before a
/// barrier product, exactly as in the pre-streaming protocol.
///
/// With `rec` set (active fault plan), the gather is strikes-bounded:
/// clients still pending after the full death budget are struck dead in
/// `alive`, their rows stay frozen at the last received slice, and the
/// product falls back to the barrier `matvec` (a partial accumulation
/// cannot represent the frozen rows). Already-dead clients are never
/// waited on, so an `exclude` run pays the budget once per loss.
#[allow(clippy::too_many_arguments)]
pub fn server_product(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    op: &mut dyn BlockOp,
    full: &mut Mat,
    m: usize,
    c: usize,
    stream: bool,
    fleet_on: bool,
    tau: f64,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) -> Mat {
    let nh = full.cols();
    let mut folding = stream && op.supports_streaming() && alive.iter().all(|&a| a);
    if folding {
        op.accum_begin();
    }
    let mut pending = alive.to_vec();
    while pending.iter().any(|&p| p) {
        let msg = match rec {
            None => Some(timer.comm(|| ep.recv_any_blocking(&pending, kind, round))),
            Some(rec) => timer.comm(|| recv_any_bounded(ep, &pending, kind, round, rec)),
        };
        let Some(msg) = msg else {
            // Struck out: everyone still pending is dead. Their rows in
            // `full` stay frozen; the caller decides abort vs exclude.
            for (j, p) in pending.iter_mut().enumerate() {
                if *p {
                    alive[j] = false;
                    *p = false;
                }
            }
            folding = false;
            break;
        };
        pending[msg.src] = false;
        let r0 = msg.src * m;
        full.as_mut_slice()[r0 * nh..(r0 + m) * nh].copy_from_slice(&msg.payload);
        if folding {
            folding = timer.comp(|| op.accum_fold(r0, m, &msg.payload));
        }
    }
    if fleet_on {
        timer.comp(|| fleet::local_decide_apply(op, full, tau));
    }
    if folding {
        timer.comp(|| op.accum_matvec().clone())
    } else {
        timer.comp(|| op.matvec(full).clone())
    }
}

/// Star-server gather of the clients' greedy sparse uplink frames: one
/// frame per live client at `round`, each scattered into `full` as it
/// arrives (dead clients' rows frozen at the last received value). With
/// `rec = Some` the receive is strike-bounded and a strikeout marks
/// every still-pending client dead, mirroring [`server_product`].
#[allow(clippy::too_many_arguments)]
pub fn greedy_server_gather(
    ep: &Endpoint,
    kind: TagKind,
    round: u64,
    full: &mut Mat,
    m: usize,
    timer: &mut SplitTimer,
    alive: &mut [bool],
    rec: Option<&Recovery>,
) {
    let mut pending = alive.to_vec();
    while pending.iter().any(|&p| p) {
        let msg = match rec {
            None => Some(timer.comm(|| ep.recv_any_blocking(&pending, kind, round))),
            Some(rec) => timer.comm(|| recv_any_bounded(ep, &pending, kind, round, rec)),
        };
        let Some(msg) = msg else {
            for (j, p) in pending.iter_mut().enumerate() {
                if *p {
                    alive[j] = false;
                    *p = false;
                }
            }
            break;
        };
        pending[msg.src] = false;
        scatter_sparse(full, msg.src * m, &msg.indices, &msg.payload, &mut None);
    }
}

/// Strikes-bounded chunk receive from the star server (the exact path —
/// chunks are round-tagged). `None` only after the full death budget of
/// a resilient run; lossless runs block forever, as before.
pub fn recv_chunk(
    ep: &Endpoint,
    server: usize,
    round: u64,
    resilient: bool,
    rec: &Recovery,
) -> Option<Vec<f64>> {
    if !resilient {
        return Some(ep.recv_blocking(server, TagKind::Ctl, round).payload);
    }
    recv_bounded(ep, server, TagKind::Ctl, round, rec).map(|msg| msg.payload)
}

/// Strike-bounded point-to-point receive: `strikes` windows of
/// `recv_timeout_secs` each; `None` means the sender burned the whole
/// death budget in silence.
pub fn recv_bounded(
    ep: &Endpoint,
    src: usize,
    kind: TagKind,
    round: u64,
    rec: &Recovery,
) -> Option<Message> {
    let per_try = Duration::from_secs_f64(rec.recv_timeout_secs.max(1e-3));
    (0..rec.strikes.max(1)).find_map(|_| ep.recv_timeout(src, kind, round, per_try))
}

/// Strike-bounded any-source receive over the `pending` mask — the
/// delivery-order analogue of [`recv_bounded`].
pub fn recv_any_bounded(
    ep: &Endpoint,
    pending: &[bool],
    kind: TagKind,
    round: u64,
    rec: &Recovery,
) -> Option<Message> {
    let per_try = Duration::from_secs_f64(rec.recv_timeout_secs.max(1e-3));
    (0..rec.strikes.max(1)).find_map(|_| ep.recv_any_timeout(pending, kind, round, per_try))
}

// --------------------------------------------------------------------------
// Async fleet-absorption routing (rank-0 coordinator over latest-wins)
// --------------------------------------------------------------------------

/// Rank 0's per-channel fleet-coordination state.
pub struct FleetCoord {
    /// Latest probe payload per node (rank 0's own at index 0).
    probes: Vec<Option<Vec<f64>>>,
    /// Issued-command count. A probe stamped with an older seq measured
    /// drift against a superseded reference and is held back until the
    /// node reports post-command state — this is what prevents a
    /// command storm from stale probes racing the broadcast.
    seq: u64,
}

impl FleetCoord {
    pub fn new(c: usize) -> Self {
        Self { probes: vec![None; c], seq: 0 }
    }
}

/// Rank 0's fleet pass for one channel: refresh its own probe, drain
/// the latest peer probes, and — once every node has reported
/// current-seq state — merge, decide, broadcast the command and obey it
/// locally. `hold` freezes decisions once any peer announced done (its
/// slice probes stop; the remaining nodes keep their emergency guard).
#[allow(clippy::too_many_arguments)]
pub fn coordinate(
    coord: &mut FleetCoord,
    ep: &Endpoint,
    c: usize,
    probe_tag: u64,
    cmd_tag: u64,
    op: &mut dyn BlockOp,
    x_full: &Mat,
    m: usize,
    nh: usize,
    tau: f64,
    hold: bool,
    k64: u64,
    timer: &mut SplitTimer,
) {
    let seq = coord.seq;
    coord.probes[0] = timer.comp(|| {
        op.fleet_probe(x_full, 0, m)
            .map(|p| fleet::probe_payload(seq, &p))
    });
    timer.comm(|| {
        for j in 1..c {
            if let Some(msg) = ep.try_recv_latest(j, TagKind::Gref, probe_tag) {
                coord.probes[j] = Some(msg.payload);
            }
        }
    });
    if hold {
        return;
    }
    // Full, current-seq coverage required: a missing or stale probe
    // (degraded operator, command still in flight) holds the decision.
    let mut refs: Vec<&[f64]> = Vec::with_capacity(c);
    for probe in &coord.probes {
        match probe {
            // `.round()`: probe frames may ride a lossy wire format,
            // so the integer seq lane carries quantization noise ≪ 0.5.
            Some(pay) if pay.first().copied().unwrap_or(-1.0).round() as u64 == coord.seq => {
                refs.push(pay.as_slice());
            }
            _ => return,
        }
    }
    let Some(cmd) = timer.comp(|| fleet::decide(&refs, nh, m, tau)) else {
        return;
    };
    coord.seq += 1;
    let payload = fleet::command_payload(coord.seq, &cmd);
    timer.comm(|| {
        for j in 1..c {
            ep.send_coded(j, TagKind::Gref, cmd_tag, cmd_tag, payload.clone(), k64);
        }
    });
    timer.comp(|| op.fleet_absorb(&cmd.gref, cmd.needed));
    // Stored probes measured drift against the superseded reference.
    for probe in coord.probes.iter_mut() {
        *probe = None;
    }
}

/// Apply the freshest coordinator command (if any) to `op`, tracking
/// the applied sequence so a command is never obeyed twice.
pub fn apply_fleet_command(
    ep: &Endpoint,
    op: &mut dyn BlockOp,
    cmd_tag: u64,
    applied: &mut u64,
    timer: &mut SplitTimer,
) {
    let msg = timer.comm(|| ep.try_recv_latest(0, TagKind::Gref, cmd_tag));
    if let Some(msg) = msg {
        let (seq, cmd) = fleet::parse_command(&msg.payload);
        if seq > *applied {
            *applied = seq;
            if let Some((needed, gref)) = cmd {
                timer.comp(|| op.fleet_absorb(gref, needed));
            }
        }
    }
}

/// Send this node's slice-local drift probe to rank 0. A degraded
/// operator (dense fallback) stops probing, which silently pauses fleet
/// decisions at the coordinator — the intended degrade path. Probes
/// ride the latest-wins delivery class: a dropped probe is superseded
/// by next iteration's, and a stalled probe channel merely holds the
/// coordinator's decision (the same hold state).
#[allow(clippy::too_many_arguments)]
pub fn send_fleet_probe(
    ep: &Endpoint,
    op: &dyn BlockOp,
    probe_tag: u64,
    x_full: &Mat,
    r0: usize,
    m: usize,
    seq: u64,
    k64: u64,
    timer: &mut SplitTimer,
) {
    if let Some(p) = timer.comp(|| op.fleet_probe(x_full, r0, m)) {
        let payload = fleet::probe_payload(seq, &p);
        timer.comm(|| ep.send_coded_latest(0, TagKind::Gref, probe_tag, probe_tag, payload, k64));
    }
}

/// The asynchronous finish: announce "done" to every peer on the
/// reliable control path, then run the final consistent AllGather pair
/// (paper: "a consistent broadcast ensures that all nodes have the same
/// fully updated u and v") at the reserved rounds `u64::MAX − 1` (U)
/// and `u64::MAX` (V). Under an active fault plan the exchange is
/// crash-tolerant: peers already in `dead` are skipped, and a peer that
/// never shows up within the stretched death budget is struck into
/// `dead` here instead of hanging the run. (The runner assembles the
/// outcome from each node's own slices, so a struck peer only costs us
/// its copy, never correctness.)
#[allow(clippy::too_many_arguments)]
pub fn finish_consistent(
    ep: &Endpoint,
    done_tag: u64,
    u_fin: &Mat,
    v_fin: &Mat,
    iterations: usize,
    resilient: bool,
    recovery: &Recovery,
    dead: &mut [bool],
    timer: &mut SplitTimer,
) {
    let c = ep.nodes();
    let id = ep.id();
    // Announce we stopped, so lagging peers don't wait on us …
    for peer in 0..c {
        if peer != id {
            ep.send(peer, TagKind::Ctl, done_tag, vec![1.0], iterations as u64);
        }
    }
    timer.comm(|| {
        if resilient {
            let fin = Recovery {
                recv_timeout_secs: recovery.death_secs().max(1e-3),
                ..*recovery
            };
            let mut alive: Vec<bool> = dead.iter().map(|&d| !d).collect();
            let _ = allgather_resilient(
                ep,
                TagKind::U,
                u64::MAX - 1,
                None,
                u_fin.as_slice(),
                iterations as u64,
                &mut alive,
                &fin,
            );
            let _ = allgather_resilient(
                ep,
                TagKind::V,
                u64::MAX,
                None,
                v_fin.as_slice(),
                iterations as u64,
                &mut alive,
                &fin,
            );
            for (p, &a) in alive.iter().enumerate() {
                if !a {
                    dead[p] = true;
                }
            }
        } else {
            let _ = allgather(ep, TagKind::U, u64::MAX - 1, u_fin.as_slice(), iterations as u64);
            let _ = allgather(ep, TagKind::V, u64::MAX, v_fin.as_slice(), iterations as u64);
        }
    });
    timer.add_comp(ep.take_decode_secs());
}

// --------------------------------------------------------------------------
// Slice plumbing & client-side element-wise updates
// --------------------------------------------------------------------------

/// Survivor count of a live mask.
pub fn count_alive(alive: &[bool]) -> usize {
    alive.iter().filter(|&&l| l).count()
}

/// The dead peer ids a live mask records.
pub fn lost_of(alive: &[bool]) -> Vec<usize> {
    alive
        .iter()
        .enumerate()
        .filter(|(_, &l)| !l)
        .map(|(j, _)| j)
        .collect()
}

/// Rows `[r0, r0+m)` of `full` as a flat slice (row-major m×N block).
pub fn slice_of(full: &Mat, r0: usize, m: usize) -> &[f64] {
    let nh = full.cols();
    &full.as_slice()[r0 * nh..(r0 + m) * nh]
}

/// Write a client's block into the full state at row `r0`.
pub fn copy_slice(full: &mut Mat, block: &Mat, r0: usize) {
    let nh = full.cols();
    let m = block.rows();
    full.as_mut_slice()[r0 * nh..(r0 + m) * nh].copy_from_slice(block.as_slice());
}

/// Assemble AllGather parts (node-indexed, each m×N flat) into `full`.
pub fn assemble(full: &mut Mat, parts: &[Vec<f64>], m: usize) {
    let nh = full.cols();
    for (j, part) in parts.iter().enumerate() {
        debug_assert_eq!(part.len(), m * nh);
        full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(part);
    }
}

/// [`assemble`] over resilient parts: a dead peer's `None` slot leaves
/// its rows of `full` frozen at the last received value.
pub fn assemble_opt(full: &mut Mat, parts: &[Option<Vec<f64>>], m: usize) {
    let nh = full.cols();
    for (j, part) in parts.iter().enumerate() {
        if let Some(part) = part {
            debug_assert_eq!(part.len(), m * nh);
            full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(part);
        }
    }
}

/// Client `j`'s rows of a full n×N matrix, flattened.
pub fn chunk_of(full: &Mat, j: usize, m: usize) -> &[f64] {
    let nh = full.cols();
    &full.as_slice()[j * m * nh..(j + 1) * m * nh]
}

/// Write client `j`'s m×N flat block into the full state.
pub fn write_block(full: &mut Mat, block: &[f64], j: usize, m: usize) {
    let nh = full.cols();
    debug_assert_eq!(block.len(), m * nh);
    full.as_mut_slice()[j * m * nh..(j + 1) * m * nh].copy_from_slice(block);
}

/// Per-client marginal targets in the run's numerics domain. Linear
/// clients divide by the received product chunk; log clients subtract in
/// log space (`log a`, `log b` precomputed once per run, not per
/// iteration).
pub struct ClientTargets<'a> {
    a: &'a [f64],
    b: &'a Mat,
    log_a: Vec<f64>,
    /// Row-major m×N, only populated in the log domain.
    log_b: Vec<f64>,
    domain: Domain,
}

impl<'a> ClientTargets<'a> {
    pub fn new(shard: &'a crate::workload::ClientShard, domain: Domain) -> Self {
        let (log_a, log_b) = match domain {
            Domain::Linear => (Vec::new(), Vec::new()),
            Domain::Log => (
                shard.a.iter().map(|&x| x.ln()).collect(),
                shard.b.as_slice().iter().map(|&x| x.ln()).collect(),
            ),
        };
        Self { a: &shard.a, b: &shard.b, log_a, log_b, domain }
    }

    /// `u ← α a⊘q + (1−α) u` — division is a log-subtraction in the log
    /// domain (`a` broadcasts across histograms).
    pub fn damped_u_update(&self, u_jj: &mut Mat, q: &[f64], alpha: f64) {
        let (m, nh) = (u_jj.rows(), u_jj.cols());
        let beta = 1.0 - alpha;
        match self.domain {
            Domain::Linear => {
                for i in 0..m {
                    for h in 0..nh {
                        let qv = q[i * nh + h];
                        u_jj[(i, h)] = alpha * (self.a[i] / qv) + beta * u_jj[(i, h)];
                    }
                }
            }
            Domain::Log => {
                for i in 0..m {
                    for h in 0..nh {
                        let qv = q[i * nh + h];
                        u_jj[(i, h)] = alpha * (self.log_a[i] - qv) + beta * u_jj[(i, h)];
                    }
                }
            }
        }
    }

    /// `v ← α b⊘r + (1−α) v` (per-histogram target).
    pub fn damped_v_update(&self, v_jj: &mut Mat, r: &[f64], alpha: f64) {
        let (m, nh) = (v_jj.rows(), v_jj.cols());
        let beta = 1.0 - alpha;
        match self.domain {
            Domain::Linear => {
                for i in 0..m {
                    for h in 0..nh {
                        let rv = r[i * nh + h];
                        v_jj[(i, h)] = alpha * (self.b[(i, h)] / rv) + beta * v_jj[(i, h)];
                    }
                }
            }
            Domain::Log => {
                for i in 0..m {
                    for h in 0..nh {
                        let rv = r[i * nh + h];
                        v_jj[(i, h)] =
                            alpha * (self.log_b[i * nh + h] - rv) + beta * v_jj[(i, h)];
                    }
                }
            }
        }
    }

    /// Per-row violation mass `Σ_h |u∘q − a|_i` of the u-block against
    /// a flat product chunk — the ranking the greedy star client
    /// selects on (log states exponentiate `log u + q`, the log of the
    /// marginal entry).
    pub fn row_violations_u(&self, u_jj: &Mat, q: &[f64]) -> Vec<f64> {
        let (m, nh) = (u_jj.rows(), u_jj.cols());
        let mut viol = vec![0.0; m];
        for (i, vi) in viol.iter_mut().enumerate() {
            let mut s = 0.0;
            for h in 0..nh {
                let entry = match self.domain {
                    Domain::Linear => u_jj[(i, h)] * q[i * nh + h],
                    Domain::Log => (u_jj[(i, h)] + q[i * nh + h]).exp(),
                };
                s += (entry - self.a[i]).abs();
            }
            *vi = s;
        }
        viol
    }

    /// Per-row violation mass of the v-block (per-histogram target b).
    pub fn row_violations_v(&self, v_jj: &Mat, r: &[f64]) -> Vec<f64> {
        let (m, nh) = (v_jj.rows(), v_jj.cols());
        let mut viol = vec![0.0; m];
        for (i, vi) in viol.iter_mut().enumerate() {
            let mut s = 0.0;
            for h in 0..nh {
                let entry = match self.domain {
                    Domain::Linear => v_jj[(i, h)] * r[i * nh + h],
                    Domain::Log => (v_jj[(i, h)] + r[i * nh + h]).exp(),
                };
                s += (entry - self.b[(i, h)]).abs();
            }
            *vi = s;
        }
        viol
    }

    /// [`ClientTargets::damped_u_update`] restricted to the selected
    /// rows — the greedy half-step leaves every other scaling untouched.
    pub fn damped_u_update_rows(&self, u_jj: &mut Mat, q: &[f64], alpha: f64, rows: &[u32]) {
        let nh = u_jj.cols();
        let beta = 1.0 - alpha;
        for &ri in rows {
            let i = ri as usize;
            for h in 0..nh {
                let qv = q[i * nh + h];
                u_jj[(i, h)] = match self.domain {
                    Domain::Linear => alpha * (self.a[i] / qv) + beta * u_jj[(i, h)],
                    Domain::Log => alpha * (self.log_a[i] - qv) + beta * u_jj[(i, h)],
                };
            }
        }
    }

    /// [`ClientTargets::damped_v_update`] restricted to the selected rows.
    pub fn damped_v_update_rows(&self, v_jj: &mut Mat, r: &[f64], alpha: f64, rows: &[u32]) {
        let nh = v_jj.cols();
        let beta = 1.0 - alpha;
        for &ri in rows {
            let i = ri as usize;
            for h in 0..nh {
                let rv = r[i * nh + h];
                v_jj[(i, h)] = match self.domain {
                    Domain::Linear => alpha * (self.b[(i, h)] / rv) + beta * v_jj[(i, h)],
                    Domain::Log => alpha * (self.log_b[i * nh + h] - rv) + beta * v_jj[(i, h)],
                };
            }
        }
    }
}

/// Block a-marginal error `max_h Σ_i |u∘q − a|` from a flat q chunk —
/// always reported in the linear domain (log states exponentiate
/// `log u + q`, the log of the marginal entry).
pub fn block_err(u_jj: &Mat, q: &[f64], a: &[f64], m: usize, nh: usize, domain: Domain) -> f64 {
    let mut best: f64 = 0.0;
    for h in 0..nh {
        let mut e = 0.0;
        for i in 0..m {
            let entry = match domain {
                Domain::Linear => u_jj[(i, h)] * q[i * nh + h],
                Domain::Log => (u_jj[(i, h)] + q[i * nh + h]).exp(),
            };
            e += (entry - a[i]).abs();
        }
        best = best.max(e);
    }
    best
}
