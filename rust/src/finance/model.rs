//! Blanchet–Murthy problem construction (paper §V-A/§V-B).

use crate::linalg::Mat;
use crate::workload::Problem;

/// Shift both return vectors positive by a common `k = max(|min x|,
/// |min x'|) + margin`, then normalize each to the simplex (§V-B4).
/// Returns `(x̃, x̃', k)`.
pub fn normalize_returns(x: &[f64], xp: &[f64], margin: f64) -> (Vec<f64>, Vec<f64>, f64) {
    let min_x = x.iter().cloned().fold(f64::INFINITY, f64::min);
    let min_xp = xp.iter().cloned().fold(f64::INFINITY, f64::min);
    let k = min_x.abs().max(min_xp.abs()) + margin;
    let shift_norm = |v: &[f64]| -> Vec<f64> {
        let shifted: Vec<f64> = v.iter().map(|r| r + k).collect();
        let s: f64 = shifted.iter().sum();
        shifted.into_iter().map(|r| r / s).collect()
    };
    (shift_norm(x), shift_norm(xp), k)
}

/// Worst-case-loss problem specification.
#[derive(Clone, Debug)]
pub struct WorstCaseSpec {
    /// Historical (empirical) returns `x`, one point per scenario.
    pub returns: Vec<f64>,
    /// Analyst target returns `x'` (same length).
    pub targets: Vec<f64>,
    /// Portfolio weights `w` (simplex).
    pub weights: Vec<f64>,
    /// Blanchet–Murthy dual variable λ (start value for searches).
    pub lambda: f64,
    /// Wasserstein budget δ.
    pub delta: f64,
    /// Sinkhorn regularization ε.
    pub eps: f64,
    /// Positivity margin for the shift (paper uses 0.01).
    pub margin: f64,
}

impl WorstCaseSpec {
    /// The paper's §V-B4 3-asset worked example.
    pub fn paper_example() -> Self {
        Self {
            returns: vec![-0.51, -0.66, 4.34],
            targets: vec![0.43, -0.80, 3.86],
            weights: vec![0.4, 0.1, 0.5],
            lambda: 0.1,
            delta: 0.01,
            eps: 0.01,
            margin: 0.01,
        }
    }

    /// Build the OT instance at a given λ.
    pub fn problem(&self, lambda: f64) -> FinanceProblem {
        let n = self.returns.len();
        assert_eq!(self.targets.len(), n);
        let (xt, xpt, shift) = normalize_returns(&self.returns, &self.targets, self.margin);

        // Portfolio loss at the (normalized) target points: the paper's
        // example uses the whole-portfolio return wᵀx̃ spread uniformly
        // (so C_ij = λ c + wᵀx̃/n); we keep that convention.
        let wx: f64 = self.weights.iter().zip(&xt).map(|(w, x)| w * x).sum();
        let loss: Vec<f64> = vec![wx; n];

        // Ground cost c(x̃_i, x̃'_j) = (x̃_i − x̃'_j)², symmetrized:
        // the paper's worked example prints a symmetric C and §V-B4
        // relies on it ("the cost matrix is symmetrical, which means the
        // offices have respective access for the partial cost matrices
        // C_iᵀ"), so c ← (c + cᵀ)/2.
        let mut ground = Mat::zeros(n, n);
        let mut cost = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let dij = xt[i] - xpt[j];
                let dji = xt[j] - xpt[i];
                ground[(i, j)] = 0.5 * (dij * dij + dji * dji);
                // C = λ c + l/n (the −l(x') of §V-A7, sign-folded as the
                // paper's worked example does: "wᵀx divided by n").
                cost[(i, j)] = lambda * ground[(i, j)] + loss[j] / n as f64;
            }
        }

        let mut b = Mat::zeros(n, 1);
        for i in 0..n {
            b[(i, 0)] = xpt[i];
        }
        let problem = Problem::from_parts(xt.clone(), b, cost, self.eps);
        FinanceProblem { problem, ground, loss, shift, x_norm: xt, xp_norm: xpt }
    }
}

/// The OT instance at a fixed λ plus the finance-side data needed for
/// ρ_worst and the Wasserstein-cost evaluation.
#[derive(Clone, Debug)]
pub struct FinanceProblem {
    pub problem: Problem,
    /// Ground transport cost c (squared distance), independent of λ.
    pub ground: Mat,
    /// Per-target-point portfolio loss l(x̃'_j).
    pub loss: Vec<f64>,
    /// The positivity shift k applied to both return vectors.
    pub shift: f64,
    pub x_norm: Vec<f64>,
    pub xp_norm: Vec<f64>,
}

impl FinanceProblem {
    /// `⟨P, c⟩` — the transported Wasserstein cost (not the consolidated
    /// Sinkhorn cost).
    pub fn transport_cost(&self, plan: &Mat) -> f64 {
        let n = self.problem.n;
        let mut total = 0.0;
        for i in 0..n {
            for j in 0..n {
                total += plan[(i, j)] * self.ground[(i, j)];
            }
        }
        total
    }

    /// `ρ_worst = −Σ_ij P_ij l_j` (§V-B4 prints the negative of the
    /// expected loss as the worst-case return).
    pub fn rho_worst(&self, plan: &Mat) -> f64 {
        let n = self.problem.n;
        let mut total = 0.0;
        for i in 0..n {
            for j in 0..n {
                total += plan[(i, j)] * self.loss[j];
            }
        }
        -total
    }
}
