//! Financial application (paper §V): worst-case expected portfolio loss
//! via the Blanchet–Murthy distributionally-robust formulation, reduced
//! to entropic optimal transport and solved with Federated Sinkhorn.
//!
//! Pipeline:
//! 1. Historical returns `x` and analyst targets `x'` are shifted
//!    positive and normalized to the simplex (§V-B4).
//! 2. The consolidated cost `C_ij = λ·c(x̃_i, x̃'_j) − l(x̃'_j)` (here
//!    `c` = squared distance, `l` = portfolio loss) defines an OT
//!    problem with marginals `(x̃, x̃')`.
//! 3. Federated Sinkhorn yields `P*(λ)`; the outer λ-search enforces the
//!    Wasserstein budget `⟨P*, c⟩ = δ`.
//! 4. `ρ_worst = Σ_ij P*_ij l_j`, cross-checked against the dual
//!    identity `ρ = λδ + Σ P*(l − λc)` (§V-B2).

mod model;
mod portfolio;
mod search;

pub use model::{normalize_returns, FinanceProblem, WorstCaseSpec};
pub use portfolio::{synthetic_portfolio, PortfolioData};
pub use search::{worst_case_loss, LambdaSearch, WorstCaseResult};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, SolveConfig, Variant};
    use crate::net::LatencyModel;
    use crate::sinkhorn::StopPolicy;

    fn cfg(variant: Variant, clients: usize) -> SolveConfig {
        SolveConfig {
            variant,
            backend: BackendKind::Native,
            clients,
            net: LatencyModel::zero(),
            ..Default::default()
        }
    }

    #[test]
    fn normalization_matches_paper_worked_example() {
        // §V-B4: x = [-0.51, -0.66, 4.34], x' = [0.43, -0.8, 3.86].
        let (xt, xpt, k) = normalize_returns(
            &[-0.51, -0.66, 4.34],
            &[0.43, -0.80, 3.86],
            0.01,
        );
        assert!((k - 0.81).abs() < 1e-12, "shift k = {k}");
        // x_shifted = [0.30, 0.15, 5.15], sum 5.6
        assert!((xt[0] - 0.30 / 5.6).abs() < 1e-12);
        assert!((xt[1] - 0.15 / 5.6).abs() < 1e-12);
        assert!((xt[2] - 5.15 / 5.6).abs() < 1e-12);
        // x'_shifted = [1.24, 0.01, 4.67], sum 5.92
        assert!((xpt[0] - 1.24 / 5.92).abs() < 1e-12);
        assert!((xpt[2] - 4.67 / 5.92).abs() < 1e-12);
    }

    #[test]
    fn paper_cost_matrix_reproduced() {
        let spec = WorstCaseSpec::paper_example();
        let fp = spec.problem(spec.lambda);
        // §V-B4 prints C ≈ [[0.164, 0.163, 0.214], ...] (3 decimals).
        let want = [
            [0.164, 0.163, 0.214],
            [0.163, 0.161, 0.232],
            [0.214, 0.232, 0.163],
        ];
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (fp.problem.cost[(i, j)] - want[i][j]).abs() < 2.5e-3,
                    "C[{i}][{j}] = {} want {}",
                    fp.problem.cost[(i, j)],
                    want[i][j]
                );
            }
        }
    }

    #[test]
    fn paper_example_rho_is_minus_048() {
        // ρ_worst = −wᵀx̃ Σ P = −0.48 (§V-B4) for every solver variant.
        let spec = WorstCaseSpec::paper_example();
        for (variant, clients) in [
            (Variant::Centralized, 1),
            (Variant::SyncA2A, 3),
            (Variant::SyncStar, 3),
        ] {
            let out = worst_case_loss(
                &spec,
                &cfg(variant, clients),
                StopPolicy { threshold: 1e-12, max_iters: 20_000, ..Default::default() },
                LambdaSearch::fixed(spec.lambda),
            );
            assert!(
                (out.rho - (-0.48)).abs() < 5e-3,
                "{}: rho = {}",
                variant.name(),
                out.rho
            );
            assert!(out.converged, "{}", variant.name());
        }
    }

    #[test]
    fn dual_identity_holds() {
        // §V-B2: ρ = λδ + Σ P(l − λc) with δ = achieved ⟨P,c⟩.
        let spec = WorstCaseSpec::paper_example();
        let out = worst_case_loss(
            &spec,
            &cfg(Variant::Centralized, 1),
            StopPolicy { threshold: 1e-12, max_iters: 20_000, ..Default::default() },
            LambdaSearch::fixed(spec.lambda),
        );
        let dual = out.lambda * out.transport_cost
            + (out.rho - out.lambda * out.transport_cost);
        assert!((dual - out.rho).abs() < 1e-12);
        assert!(out.transport_cost > 0.0);
    }

    #[test]
    fn lambda_search_hits_delta() {
        // A searched λ must bring ⟨P*, c⟩ within tolerance of δ when δ
        // is inside the achievable range.
        let spec = WorstCaseSpec::paper_example();
        let pol = StopPolicy { threshold: 1e-11, max_iters: 20_000, ..Default::default() };
        let probe = worst_case_loss(
            &spec,
            &cfg(Variant::Centralized, 1),
            pol,
            LambdaSearch::fixed(1.0),
        );
        let delta = probe.transport_cost;
        let mut spec2 = spec.clone();
        spec2.delta = delta;
        let out = worst_case_loss(
            &spec2,
            &cfg(Variant::Centralized, 1),
            pol,
            LambdaSearch::bisection(1e-3, 64.0, 1e-4, 40),
        );
        assert!(
            (out.transport_cost - delta).abs() < 1e-3,
            "cost {} vs δ {delta}",
            out.transport_cost
        );
        assert!(out.lambda_iters > 1);
    }

    #[test]
    fn synthetic_portfolio_is_well_formed() {
        let data = synthetic_portfolio(12, 250, 7);
        assert_eq!(data.weights.len(), 12);
        assert!((data.weights.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(data.historical.len(), 250);
        assert!(data.historical.iter().all(|r| r.is_finite()));
        assert!(data.analyst_view.iter().all(|r| r.is_finite()));
        assert_eq!(data.historical.len(), data.analyst_view.len());
    }

    #[test]
    fn transport_cost_decreases_with_lambda() {
        let spec = WorstCaseSpec::paper_example();
        let pol = StopPolicy { threshold: 1e-11, max_iters: 20_000, ..Default::default() };
        let c = cfg(Variant::Centralized, 1);
        let lo = worst_case_loss(&spec, &c, pol, LambdaSearch::fixed(0.05));
        let hi = worst_case_loss(&spec, &c, pol, LambdaSearch::fixed(5.0));
        assert!(
            hi.transport_cost <= lo.transport_cost + 1e-12,
            "cost(λ=5) {} vs cost(λ=0.05) {}",
            hi.transport_cost,
            lo.transport_cost
        );
    }
}
