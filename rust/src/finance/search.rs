//! Outer λ-search enforcing the Wasserstein budget (§V-A9).

use super::model::WorstCaseSpec;
use crate::config::SolveConfig;
use crate::coordinator::run_federated;
use crate::sinkhorn::{transport_plan, StopPolicy};

/// λ-search strategy. `⟨P*(λ), c⟩` is monotone non-increasing in λ
/// (higher λ penalizes transport), so bisection brackets δ.
#[derive(Clone, Copy, Debug)]
pub enum LambdaSearch {
    /// Solve once at the given λ (the paper's worked example).
    Fixed(f64),
    /// Bisection over `[lo, hi]` until `|⟨P,c⟩ − δ| < tol` or maxiter.
    Bisection { lo: f64, hi: f64, tol: f64, max_iter: usize },
}

impl LambdaSearch {
    pub fn fixed(lambda: f64) -> Self {
        LambdaSearch::Fixed(lambda)
    }

    pub fn bisection(lo: f64, hi: f64, tol: f64, max_iter: usize) -> Self {
        LambdaSearch::Bisection { lo, hi, tol, max_iter }
    }
}

/// Worst-case-loss outcome.
#[derive(Clone, Debug)]
pub struct WorstCaseResult {
    pub lambda: f64,
    /// ρ_worst (the worst-case *return*; negative = loss).
    pub rho: f64,
    /// ⟨P*, c⟩ at the returned λ.
    pub transport_cost: f64,
    /// Sinkhorn iterations of the final inner solve.
    pub inner_iters: usize,
    /// Outer λ-search evaluations.
    pub lambda_iters: usize,
    pub converged: bool,
    pub secs: f64,
}

/// Run the (federated) Sinkhorn inner solver inside the λ-search.
pub fn worst_case_loss(
    spec: &WorstCaseSpec,
    cfg: &SolveConfig,
    policy: StopPolicy,
    search: LambdaSearch,
) -> WorstCaseResult {
    let t0 = std::time::Instant::now();
    let mut evals = 0usize;

    let mut solve_at = |lambda: f64| {
        evals += 1;
        let fp = spec.problem(lambda);
        let out = run_federated(&fp.problem, cfg, policy, false);
        let plan = transport_plan(&fp.problem, &out.state, 0);
        let cost = fp.transport_cost(&plan);
        let rho = fp.rho_worst(&plan);
        (cost, rho, out.iterations, out.converged)
    };

    let (lambda, cost, rho, iters, conv) = match search {
        LambdaSearch::Fixed(lambda) => {
            let (cost, rho, iters, conv) = solve_at(lambda);
            (lambda, cost, rho, iters, conv)
        }
        LambdaSearch::Bisection { lo, hi, tol, max_iter } => {
            let mut lo = lo;
            let mut hi = hi;
            // cost(λ) is non-increasing: cost(lo) ≥ cost(hi).
            let (mut cost_mid, mut rho_mid, mut it_mid, mut conv_mid) = solve_at(lo);
            let mut lambda_mid = lo;
            for _ in 0..max_iter {
                let mid = 0.5 * (lo + hi);
                let (cost, rho, it, conv) = solve_at(mid);
                lambda_mid = mid;
                cost_mid = cost;
                rho_mid = rho;
                it_mid = it;
                conv_mid = conv;
                if (cost - spec.delta).abs() < tol {
                    break;
                }
                if cost > spec.delta {
                    lo = mid; // transporting too much → raise the penalty
                } else {
                    hi = mid;
                }
            }
            (lambda_mid, cost_mid, rho_mid, it_mid, conv_mid)
        }
    };

    WorstCaseResult {
        lambda,
        rho,
        transport_cost: cost,
        inner_iters: iters,
        lambda_iters: evals,
        converged: conv,
        secs: t0.elapsed().as_secs_f64(),
    }
}
