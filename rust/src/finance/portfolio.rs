//! Synthetic financial time-series generator.
//!
//! Stand-in for the paper's proprietary HSBC data (DESIGN.md §3): a
//! portfolio of `assets` with lognormal-ish daily returns (drift +
//! clustered volatility), a random simplex weight vector, and an
//! "analyst view" series produced by perturbing the historical one —
//! exactly the inputs §V's pipeline consumes, at any scale.

use crate::rng::Rng;

/// Generated portfolio scenario data.
#[derive(Clone, Debug)]
pub struct PortfolioData {
    /// Per-asset portfolio weights (simplex).
    pub weights: Vec<f64>,
    /// Historical portfolio returns, one per scenario day (%).
    pub historical: Vec<f64>,
    /// Analyst next-day view per scenario (%).
    pub analyst_view: Vec<f64>,
}

/// Generate `scenarios` daily portfolio returns over `assets` assets.
pub fn synthetic_portfolio(assets: usize, scenarios: usize, seed: u64) -> PortfolioData {
    let mut rng = Rng::seed_from(seed);
    let weights = rng.dirichlet(assets, 1.0);

    // Per-asset params: small drift, 1–3% daily vol.
    let drift: Vec<f64> = (0..assets).map(|_| rng.normal_ms(0.03, 0.05)).collect();
    let vol: Vec<f64> = (0..assets).map(|_| rng.uniform_range(1.0, 3.0)).collect();

    let mut historical = Vec::with_capacity(scenarios);
    let mut analyst_view = Vec::with_capacity(scenarios);
    // Volatility clustering: an AR(1) multiplier on the vol level.
    let mut regime = 1.0;
    for _ in 0..scenarios {
        regime = (0.9 * regime + 0.1 * rng.uniform_range(0.5, 2.0)).clamp(0.25, 4.0);
        let mut port = 0.0;
        for a in 0..assets {
            port += weights[a] * rng.normal_ms(drift[a], vol[a] * regime);
        }
        historical.push(port);
        // Analysts see a noisy, slightly optimistic version.
        analyst_view.push(port * rng.uniform_range(0.7, 1.1) + rng.normal_ms(0.05, 0.3));
    }

    PortfolioData { weights, historical, analyst_view }
}
